"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth: independent implementations (no Pallas, no
shared kernel-body code paths beyond jnp itself) that pytest compares
against kernel outputs with ``assert_allclose``.
"""

import jax.numpy as jnp

BLOCK = 8


def sobel_stats_ref(x):
    """Reference for ``preprocess.sobel_stats``."""
    x = x.astype(jnp.float32)
    xp = jnp.pad(x, 1, mode="edge")
    # Explicit convolution-style accumulation (different formulation from
    # the kernel's slice arithmetic on purpose).
    kx = jnp.array([[-1.0, 0.0, 1.0], [-2.0, 0.0, 2.0], [-1.0, 0.0, 1.0]], jnp.float32)
    ky = kx.T
    h, w = x.shape
    gx = jnp.zeros((h, w), jnp.float32)
    gy = jnp.zeros((h, w), jnp.float32)
    for di in range(3):
        for dj in range(3):
            window = xp[di : di + h, dj : dj + w]
            gx = gx + kx[di, dj] * window
            gy = gy + ky[di, dj] * window
    gmag = jnp.sqrt(gx * gx + gy * gy)
    stats = gmag.reshape(h // BLOCK, BLOCK, w // BLOCK, BLOCK).mean(axis=(1, 3))
    return gmag, stats


def change_detect_ref(cur, hist):
    """Reference for ``preprocess.change_detect``."""
    cur = cur.astype(jnp.float32)
    hist = hist.astype(jnp.float32)
    diff = jnp.abs(cur - hist)
    h, w = diff.shape
    dstats = diff.reshape(h // BLOCK, BLOCK, w // BLOCK, BLOCK).mean(axis=(1, 3))
    return diff, dstats
