"""Layer-1 Pallas kernels: the LiDAR pre-processing hot-spot.

The paper's disaster-recovery pipeline (§II, §V-B) pre-processes LiDAR
images on the edge device and scores them to decide (rule engine, §IV-D2)
whether further cloud processing is needed. The per-tile compute is:

- ``sobel_stats``: fused Sobel gradient magnitude + per-block mean
  statistics. One HBM read of the tile, one write of the gradient map and
  one small write of the (H/8, W/8) block means.
- ``change_detect``: fused |current - historical| difference + per-block
  means, for change detection against pre-Hurricane data.

TPU mapping (DESIGN.md §Hardware-Adaptation): a 256×256 f32 tile is
256 KiB — tile + gradient output + temporaries fit VMEM (≈16 MiB) with
>10× headroom, so the kernels use a single-block grid and fuse all
per-tile math into one VMEM-resident pass (the HBM↔VMEM schedule is one
load + two stores per tile). Larger tiles would row-block with a halo;
the block-stat reduction maps to the VPU (this is a stencil workload —
the MXU has nothing to multiply).

Kernels MUST run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls that the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-block statistic granularity (paper pipeline scores 8×8 blocks).
BLOCK = 8


def _sobel_gmag(x):
    """Sobel gradient magnitude with edge-replicated borders (pure jnp,
    shared by the kernel body and the reference oracle)."""
    xp = jnp.pad(x, 1, mode="edge")
    # 3x3 Sobel stencils.
    gx = (
        (xp[2:, 2:] + 2.0 * xp[1:-1, 2:] + xp[:-2, 2:])
        - (xp[2:, :-2] + 2.0 * xp[1:-1, :-2] + xp[:-2, :-2])
    )
    gy = (
        (xp[2:, 2:] + 2.0 * xp[2:, 1:-1] + xp[2:, :-2])
        - (xp[:-2, 2:] + 2.0 * xp[:-2, 1:-1] + xp[:-2, :-2])
    )
    return jnp.sqrt(gx * gx + gy * gy)


def _block_means(x):
    """Mean over non-overlapping BLOCK×BLOCK tiles → (H/B, W/B)."""
    h, w = x.shape
    return x.reshape(h // BLOCK, BLOCK, w // BLOCK, BLOCK).mean(axis=(1, 3))


def _sobel_stats_kernel(x_ref, gmag_ref, stats_ref):
    """Fused: gradient magnitude + block-mean stats, one VMEM pass."""
    x = x_ref[...]
    gmag = _sobel_gmag(x)
    gmag_ref[...] = gmag
    stats_ref[...] = _block_means(gmag)


@functools.partial(jax.jit, static_argnames=())
def sobel_stats(x):
    """Pallas entry: ``x (H, W) f32 -> (gmag (H, W), stats (H/8, W/8))``.

    H and W must be multiples of ``BLOCK``.
    """
    h, w = x.shape
    assert h % BLOCK == 0 and w % BLOCK == 0, (h, w)
    return pl.pallas_call(
        _sobel_stats_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((h, w), jnp.float32),
            jax.ShapeDtypeStruct((h // BLOCK, w // BLOCK), jnp.float32),
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32))


def _change_detect_kernel(cur_ref, hist_ref, diff_ref, dstats_ref):
    """Fused: absolute difference + block-mean change statistics."""
    cur = cur_ref[...]
    hist = hist_ref[...]
    diff = jnp.abs(cur - hist)
    diff_ref[...] = diff
    dstats_ref[...] = _block_means(diff)


@functools.partial(jax.jit, static_argnames=())
def change_detect(cur, hist):
    """Pallas entry: ``(cur, hist) (H, W) f32 -> (diff (H, W),
    dstats (H/8, W/8))``."""
    h, w = cur.shape
    assert cur.shape == hist.shape, (cur.shape, hist.shape)
    assert h % BLOCK == 0 and w % BLOCK == 0, (h, w)
    return pl.pallas_call(
        _change_detect_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((h, w), jnp.float32),
            jax.ShapeDtypeStruct((h // BLOCK, w // BLOCK), jnp.float32),
        ),
        interpret=True,
    )(cur.astype(jnp.float32), hist.astype(jnp.float32))
