"""Layer-2 JAX model: the disaster-recovery pipeline's compute graph.

Three AOT entry points (each lowered to HLO text by ``aot.py`` and
executed from the Rust coordinator's stream operators):

- ``preprocess(x)``: Pallas Sobel+stats kernel, then the edge decision
  features the rule engine consumes — ``RESULT`` (edge-density score) and
  ``QUALITY`` (tile contrast), as scalars.
- ``change_detect(cur, hist)``: Pallas difference kernel + change score.
- ``quality_score(stats)``: cheap re-scoring of stored block statistics
  (the serving-layer query path on the core).

The pipeline's contract with L3: scalars feed `Tuple` fields RESULT /
QUALITY / CHANGE that drive the paper's Listing-4 rule
``IF(RESULT >= 10)``.
"""

import jax.numpy as jnp

from compile.kernels import preprocess as k


# Tile geometry fixed at AOT time (the Rust side tiles images to this).
TILE = 256
STATS = TILE // k.BLOCK


def preprocess(x):
    """``x (256,256) f32 -> (gmag (256,256), stats (32,32), result f32,
    quality f32)``."""
    gmag, stats = k.sobel_stats(x)
    # Edge density score: mean gradient, scaled so typical LiDAR tiles
    # land in [0, 100] — the paper's rule threshold (RESULT >= 10) sits
    # mid-range.
    result = 100.0 * jnp.tanh(jnp.mean(gmag) / 4.0)
    # Quality: contrast (std) of the raw tile, as the data-quality input
    # for the quality/complexity trade-off rules (§IV-D2).
    quality = jnp.std(x)
    return gmag, stats, result, quality


def change_detect(cur, hist):
    """``(cur, hist) (256,256) f32 -> (dstats (32,32), change f32)``."""
    _, dstats = k.change_detect(cur, hist)
    # Change score: fraction of blocks whose mean abs-difference exceeds
    # a detection threshold, in [0, 100].
    changed = jnp.mean((dstats > 0.25).astype(jnp.float32))
    return dstats, 100.0 * changed


def quality_score(stats):
    """``stats (32,32) f32 -> f32`` — re-score stored block statistics."""
    return 100.0 * jnp.tanh(jnp.mean(stats) / 4.0)
