"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
Python runs ONCE here; it is never on the Rust request path.
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_points():
    """(name, function, example-args) for every artifact."""
    tile = jax.ShapeDtypeStruct((model.TILE, model.TILE), jnp.float32)
    stats = jax.ShapeDtypeStruct((model.STATS, model.STATS), jnp.float32)
    return [
        ("preprocess", model.preprocess, (tile,)),
        ("change_detect", model.change_detect, (tile, tile)),
        ("quality_score", model.quality_score, (stats,)),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    for name, fn, example_args in entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
