"""L2 model tests: entry-point shapes, score semantics, AOT lowering."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model


def rand_tile(seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((model.TILE, model.TILE)) * scale).astype(np.float32)


def test_preprocess_shapes_and_ranges():
    gmag, stats, result, quality = model.preprocess(rand_tile(0))
    assert gmag.shape == (model.TILE, model.TILE)
    assert stats.shape == (model.STATS, model.STATS)
    assert 0.0 <= float(result) <= 100.0
    assert float(quality) >= 0.0


def test_result_monotonic_in_edge_content():
    flat = np.zeros((model.TILE, model.TILE), np.float32)
    _, _, r_flat, _ = model.preprocess(flat)
    edgy = rand_tile(1, scale=10.0)
    _, _, r_edgy, _ = model.preprocess(edgy)
    assert float(r_flat) < 1e-3
    assert float(r_edgy) > float(r_flat)


def test_change_detect_scores():
    x = rand_tile(2)
    _, score_same = model.change_detect(x, x)
    assert float(score_same) == 0.0
    y = x + 5.0  # uniform large change
    _, score_diff = model.change_detect(y, x)
    assert float(score_diff) > 90.0
    assert float(score_diff) <= 100.0


def test_quality_score_consistent_with_preprocess():
    x = rand_tile(3)
    _, stats, result, _ = model.preprocess(x)
    requeried = model.quality_score(stats)
    # Same formula over the same stats → identical scores.
    assert_allclose(float(requeried), float(result), rtol=1e-5)


def test_entry_points_cover_all_artifacts():
    names = [name for name, _, _ in aot.entry_points()]
    assert names == ["preprocess", "change_detect", "quality_score"]


@pytest.mark.parametrize("name", ["preprocess", "change_detect", "quality_score"])
def test_aot_lowering_produces_hlo_text(name):
    import jax

    entry = {n: (f, a) for n, f, a in aot.entry_points()}[name]
    fn, example_args = entry
    lowered = jax.jit(fn).lower(*example_args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple return (return_tuple=True) so the Rust side can to_tuple().
    assert "tuple" in text.lower()


def test_aot_main_writes_artifacts(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    for name in ["preprocess", "change_detect", "quality_score"]:
        path = tmp_path / f"{name}.hlo.txt"
        assert path.exists()
        assert "HloModule" in path.read_text()[:200]
