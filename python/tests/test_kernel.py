"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps tile shapes and content distributions; assert_allclose
against ref.py is THE correctness signal for the kernels that end up in
the AOT artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import preprocess as k
from compile.kernels import ref

SHAPES = [(8, 8), (16, 64), (64, 64), (128, 256), (256, 256)]


def rand_tile(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
def test_sobel_stats_matches_ref(shape):
    x = rand_tile(shape, 0)
    gmag, stats = k.sobel_stats(x)
    gmag_ref, stats_ref = ref.sobel_stats_ref(x)
    assert_allclose(np.asarray(gmag), np.asarray(gmag_ref), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(stats), np.asarray(stats_ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_change_detect_matches_ref(shape):
    cur = rand_tile(shape, 1)
    hist = rand_tile(shape, 2)
    diff, dstats = k.change_detect(cur, hist)
    diff_ref, dstats_ref = ref.change_detect_ref(cur, hist)
    assert_allclose(np.asarray(diff), np.asarray(diff_ref), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(dstats), np.asarray(dstats_ref), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    h=st.sampled_from([8, 16, 32, 64]),
    w=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(min_value=1e-3, max_value=1e3),
)
def test_sobel_stats_hypothesis_sweep(h, w, seed, scale):
    x = rand_tile((h, w), seed, scale)
    gmag, stats = k.sobel_stats(x)
    gmag_ref, stats_ref = ref.sobel_stats_ref(x)
    assert_allclose(np.asarray(gmag), np.asarray(gmag_ref), rtol=1e-4, atol=1e-4 * scale)
    assert_allclose(np.asarray(stats), np.asarray(stats_ref), rtol=1e-4, atol=1e-4 * scale)
    assert gmag.shape == (h, w)
    assert stats.shape == (h // k.BLOCK, w // k.BLOCK)


@settings(max_examples=25, deadline=None)
@given(
    h=st.sampled_from([8, 32, 64]),
    w=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_change_detect_hypothesis_sweep(h, w, seed):
    cur = rand_tile((h, w), seed)
    hist = rand_tile((h, w), seed + 1)
    diff, dstats = k.change_detect(cur, hist)
    diff_ref, dstats_ref = ref.change_detect_ref(cur, hist)
    assert_allclose(np.asarray(diff), np.asarray(diff_ref), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(dstats), np.asarray(dstats_ref), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_input_dtypes_are_coerced(dtype):
    # Kernels cast to f32 internally; any numeric dtype is accepted.
    x = (np.arange(64 * 64).reshape(64, 64) % 7).astype(dtype)
    gmag, stats = k.sobel_stats(x)
    gmag_ref, stats_ref = ref.sobel_stats_ref(np.asarray(x, np.float32))
    assert_allclose(np.asarray(gmag), np.asarray(gmag_ref), rtol=1e-5, atol=1e-5)
    assert np.asarray(gmag).dtype == np.float32
    assert np.asarray(stats).dtype == np.float32


def test_constant_tile_has_zero_gradient():
    x = np.full((64, 64), 3.25, np.float32)
    gmag, stats = k.sobel_stats(x)
    assert_allclose(np.asarray(gmag), 0.0, atol=1e-6)
    assert_allclose(np.asarray(stats), 0.0, atol=1e-6)


def test_vertical_edge_detected():
    x = np.zeros((64, 64), np.float32)
    x[:, 32:] = 10.0
    gmag, _ = k.sobel_stats(x)
    g = np.asarray(gmag)
    # Strong response at the edge columns, none far away.
    assert g[:, 31].min() > 1.0
    assert g[:, 32].min() > 1.0
    assert_allclose(g[:, :30], 0.0, atol=1e-6)
    assert_allclose(g[:, 34:], 0.0, atol=1e-6)


def test_change_detect_identical_is_zero():
    x = rand_tile((64, 64), 3)
    diff, dstats = k.change_detect(x, x)
    assert_allclose(np.asarray(diff), 0.0, atol=1e-7)
    assert_allclose(np.asarray(dstats), 0.0, atol=1e-7)


def test_unaligned_shape_rejected():
    with pytest.raises(AssertionError):
        k.sobel_stats(np.zeros((10, 10), np.float32))
    with pytest.raises(AssertionError):
        k.change_detect(np.zeros((8, 8), np.float32), np.zeros((16, 16), np.float32))
