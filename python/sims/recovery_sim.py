#!/usr/bin/env python3
"""Behavioral pre-validation of the checkpoint/recovery protocol
(PR 10) — no cargo in the dev container, so the epoch-barrier /
global-rollback / replay sequencing is fuzzed here before the Rust
implementation.

Model
-----
Same route shape as migration_sim: a linear chain of stages split into
contiguous fragments with staging queues between them, one stateful
keyed tumbling window. Three durability layers, mirroring the Rust
design:

- **volatile**: fragment operator state, staged batches, delivered
  inboxes, and *uncommitted* collected outputs — all lost on a crash;
- **durable journal** (the LSM `ckpt/` + `ilog/` keyspace): every fed
  batch is appended to a write-ahead ingest log *before* it enters the
  route, and each checkpoint persists an atomic epoch record
  `(epoch, cursor, per-fragment per-stage states)`;
- **committed outputs**: outputs released to the consumer only at a
  checkpoint commit (or at clean stop) — never retracted.

Checkpoint protocol under test (the Rust `checkpoint_route` contract):

1. stop feeding, halt the shipper (a barrier frame crosses each hop),
2. quiesce front-to-back: deliver every staged batch and pump every
   fragment dry, shipping trailing outputs downstream — the aligned
   epoch barrier (inside a fragment the engine's Export markers align
   parallel replicas the same way),
3. snapshot every stage's per-key state *in place* (open windows move
   out and are reseeded — processing continues afterwards),
4. persist `(epoch, cursor=tuples-fed, states)` atomically; GC the
   superseded epoch and the ingest-log prefix below the cursor,
5. commit the pending outputs: everything collected so far becomes
   visible to the consumer exactly once.

Crash/recovery protocol under test (`recover_stream`):

- a crash at ANY interleaving point wipes all volatile state;
- recovery restores every fragment (survivors included — global
  rollback, no divergent epochs) from the latest committed epoch,
  clears staging, and replays the ingest log from the checkpointed
  cursor; log entries below the cursor are never replayed (sequence
  dedup) and committed outputs are never re-released (epoch dedup).

Invariants fuzzed:

- committed outputs after clean stop are multiset-equal to a
  never-crashed single-node reference run,
- per-key output order matches the reference exactly,
- no divergent epochs: epoch numbers strictly increase and recovery
  always lands on the latest committed epoch,
- replay accounting: replayed tuples == tuples fed since the last
  checkpoint at crash time,
- no output is ever delivered twice (committed set only grows),
- bounded steps (no livelock).
"""

import random
import sys
from collections import defaultdict

WINDOW = 3


class KeyedWindow:
    def __init__(self):
        self.bufs = defaultdict(list)

    def process(self, t):
        k, v = t
        buf = self.bufs[k]
        buf.append(v)
        if len(buf) == WINDOW:
            out = (k, sum(buf))
            self.bufs[k] = []
            return [out]
        return []

    def export_state(self):
        state = {k: list(b) for k, b in self.bufs.items() if b}
        self.bufs = defaultdict(list)
        return state

    def import_state(self, state):
        for k, b in state.items():
            self.bufs[k].extend(b)

    def finish(self):
        outs = [(k, sum(b)) for k, b in sorted(self.bufs.items()) if b]
        self.bufs = defaultdict(list)
        return outs


class Mapper:
    def __init__(self, delta):
        self.delta = delta

    def process(self, t):
        return [(t[0], t[1] + self.delta)]

    def export_state(self):
        return {}

    def import_state(self, state):
        assert not state

    def finish(self):
        return []


def make_stage(spec):
    return KeyedWindow() if spec == "kwin" else Mapper(int(spec[3:]))


class Fragment:
    def __init__(self, specs):
        self.specs = specs
        self.inbox = []
        self.stages = [make_stage(s) for s in specs]

    def run_batch(self, batch):
        for stage in self.stages:
            nxt = []
            for t in batch:
                nxt.extend(stage.process(t))
            batch = nxt
        return batch

    def drain_inbox(self):
        out = []
        while self.inbox:
            out.extend(self.run_batch(self.inbox.pop(0)))
        return out

    def snapshot(self):
        """Non-destructive state snapshot: export, then reseed in place
        (the Rust `Control::Snapshot` — replicas respawn with the same
        state)."""
        states = [s.export_state() for s in self.stages]
        for stage, st in zip(self.stages, states):
            stage.import_state(st)
        return states

    def restore(self, states):
        self.stages = [make_stage(s) for s in self.specs]
        for stage, st in zip(self.stages, states):
            stage.import_state(st)
        self.inbox = []

    def finish(self):
        out = self.drain_inbox()
        for i, stage in enumerate(self.stages):
            flushed = stage.finish()
            for later in self.stages[i + 1:]:
                nxt = []
                for t in flushed:
                    nxt.extend(later.process(t))
                flushed = nxt
            out.extend(flushed)
        return out


class Route:
    """The durable/volatile split: `journal`, `ilog`, `committed` live;
    everything else dies with a crash."""

    def __init__(self, frag_specs):
        self.frag_specs = frag_specs
        self.frags = [Fragment(s) for s in frag_specs]
        self.staged = [[] for _ in frag_specs]
        self.pending = []          # collected but uncommitted outputs
        self.committed = []        # released to the consumer
        # Durable journal.
        self.ilog = []             # [(start_seq, batch)] append-only
        self.journal = None        # (epoch, cursor, [frag states])
        self.epoch = 0
        self.input_seq = 0         # tuples fed (and ilogged) so far
        self.replayed = 0
        self.recoveries = 0
        self.epochs_seen = [0]

    # -- data path -----------------------------------------------------
    def feed(self, batch):
        self.ilog.append((self.input_seq, list(batch)))
        self.input_seq += len(batch)
        self.staged[0].append(list(batch))

    def deliver_one(self, i):
        if not self.staged[i]:
            return False
        self.frags[i].inbox.append(self.staged[i].pop(0))
        return True

    def pump_one(self, i):
        if not self.frags[i].inbox:
            return False
        out = self.frags[i].run_batch(self.frags[i].inbox.pop(0))
        self.route_out(i, out)
        return True

    def route_out(self, i, out):
        if not out:
            return
        if i + 1 == len(self.frags):
            self.pending.extend(out)
        else:
            self.staged[i + 1].append(out)

    # -- checkpoint barrier -------------------------------------------
    def quiesce(self):
        for i in range(len(self.frags)):
            while self.deliver_one(i) or self.pump_one(i):
                pass

    def checkpoint(self):
        self.quiesce()
        states = [f.snapshot() for f in self.frags]
        self.epoch += 1
        self.epochs_seen.append(self.epoch)
        # Atomic epoch record + GC of the superseded epoch and the
        # ingest-log prefix at/below the cursor.
        self.journal = (self.epoch, self.input_seq, states)
        self.ilog = [(s, b) for s, b in self.ilog if s >= self.input_seq]
        # Commit: pending outputs become visible exactly once.
        self.committed.extend(self.pending)
        self.pending = []

    # -- crash / recovery ---------------------------------------------
    def crash(self):
        """kill -9: all volatile state gone."""
        self.frags = [None] * len(self.frag_specs)
        self.staged = [[] for _ in self.frag_specs]
        self.pending = []

    def recover(self):
        self.recoveries += 1
        if self.journal is None:
            epoch, cursor, states = 0, 0, [None] * len(self.frag_specs)
        else:
            epoch, cursor, states = self.journal
        assert epoch == self.epoch, (
            f"divergent epochs: journal at {epoch}, route saw {self.epoch}"
        )
        # Global rollback: every fragment restored from the same epoch.
        self.frags = [Fragment(s) for s in self.frag_specs]
        for frag, st in zip(self.frags, states):
            if st is not None:
                frag.restore(st)
        # Replay the backlog; entries below the cursor were GC'd (and
        # would be skipped by the seq guard anyway).
        expect_replay = self.input_seq - cursor
        replayed = 0
        for start_seq, batch in self.ilog:
            if start_seq < cursor:
                continue
            self.staged[0].append(list(batch))
            replayed += len(batch)
        assert replayed == expect_replay, (
            f"replay accounting: {replayed} != {expect_replay}"
        )
        self.replayed += replayed

    def stop(self):
        """Clean stop: quiesce, flush partial windows, commit all."""
        for i in range(len(self.frags)):
            while self.deliver_one(i) or self.pump_one(i):
                pass
            self.route_out(i, self.frags[i].finish())
        self.committed.extend(self.pending)
        self.pending = []
        return self.committed


def reference_run(specs, tuples):
    frag = Fragment(specs)
    out = frag.run_batch(list(tuples))
    return out + frag.finish()


def run_case(seed):
    rng = random.Random(seed)
    nstages = rng.randint(2, 5)
    specs = [f"map{rng.randint(1, 9)}" for _ in range(nstages - 1)]
    specs.insert(rng.randrange(nstages), "kwin")
    cuts = sorted(rng.sample(range(1, nstages), rng.randint(0, nstages - 1)))
    bounds = [0] + cuts + [nstages]
    route = Route([specs[a:b] for a, b in zip(bounds, bounds[1:])])
    nfrags = len(route.frags)

    nkeys = rng.randint(1, 5)
    seqs = defaultdict(int)
    tuples = []
    for _ in range(rng.randint(5, 140)):
        k = rng.randrange(nkeys)
        seqs[k] += 1
        tuples.append((k, seqs[k] * 1000 + rng.randint(0, 9)))

    fed = 0
    steps = 0
    committed_watermark = 0
    while fed < len(tuples) or rng.random() < 0.3:
        steps += 1
        assert steps < 20_000, f"seed {seed}: livelock"
        action = rng.random()
        if action < 0.35 and fed < len(tuples):
            n = min(rng.randint(1, 7), len(tuples) - fed)
            route.feed(tuples[fed:fed + n])
            fed += n
        elif action < 0.55:
            route.deliver_one(rng.randrange(nfrags))
        elif action < 0.75:
            route.pump_one(rng.randrange(nfrags))
        elif action < 0.88:
            route.checkpoint()
        else:
            # kill -9 at an arbitrary interleaving point, then recover.
            route.crash()
            route.recover()
        # Committed outputs only ever grow (no retraction, no dupes).
        assert len(route.committed) >= committed_watermark, (
            f"seed {seed}: committed outputs shrank"
        )
        committed_watermark = len(route.committed)
        if fed == len(tuples) and rng.random() < 0.4:
            break

    got = route.stop()
    want = reference_run(specs, tuples)

    assert sorted(got) == sorted(want), (
        f"seed {seed}: multiset diverged after {route.recoveries} recoveries\n"
        f" got {sorted(got)}\nwant {sorted(want)}"
    )
    per_key_got = defaultdict(list)
    per_key_want = defaultdict(list)
    for k, v in got:
        per_key_got[k].append(v)
    for k, v in want:
        per_key_want[k].append(v)
    assert per_key_got == per_key_want, f"seed {seed}: per-key order diverged"
    # No divergent epochs: strictly increasing, no forks.
    assert route.epochs_seen == sorted(set(route.epochs_seen)), (
        f"seed {seed}: epoch fork {route.epochs_seen}"
    )
    return route.recoveries, route.epoch, route.replayed, len(got)


def main():
    cases = int(sys.argv[1]) if len(sys.argv) > 1 else 3000
    recoveries = epochs = replayed = outputs = 0
    for seed in range(cases):
        r, e, rp, o = run_case(seed)
        recoveries += r
        epochs += e
        replayed += rp
        outputs += o
    print(
        f"recovery_sim OK: {cases} randomized crash×interleaving schedules, "
        f"{recoveries} recoveries over {epochs} epochs, "
        f"{replayed} tuples replayed, {outputs} outputs verified "
        f"(exactly-once multiset, per-key order, no divergent epochs, "
        f"replay accounting, bounded steps)"
    )


if __name__ == "__main__":
    main()
