"""Behavioral simulation of the distributed stream route orchestration
(`rust/src/stream/dist.rs`): feed_route / pump_route / stop_route over
fragments with bounded inbound/outbound queues and asynchronously
scheduled workers.

Mirrors the Rust algorithm step for step:

- each fragment is an executor with a bounded inbound batch queue, an
  operator chain (maps and keyed tumbling windows with finish flush),
  and a bounded outbound batch queue; a worker only consumes inbound
  when the outbound has room (backpressure);
- `try_send` rejects (hands the batch back) when inbound is full;
- `pump` passes: deliver staged -> poll egress -> "ship" (identity
  codec round-trip here) -> stage for the next fragment, until a whole
  pass makes no progress;
- `feed` blocks into fragment 0, pumping between chunks;
- `stop` cascades front-to-back, delivering staged tuples before each
  fragment closes and forwarding its trailing flush downstream.

Workers advance at random interleaving points (a `sched` hook invoked
wherever the Rust orchestrator would lose the CPU to worker threads).

Checked per case: output multiset == serial reference, per-key order
for pass-through chains, zero loss, and termination (livelock bound).

Run: python3 python/sims/dist_stream_sim.py [cases]
"""

import random
import sys
from collections import deque

SHIP_CHUNK = 8       # scaled down from 64 to stress boundaries
PUMP_POLL = 32       # scaled down from 256
CH_DEPTH = 4         # scaled down from 256 (stress backpressure)
STAGE_WINDOW = 64    # scaled down from 4096


class MapOp:
    def __init__(self, f):
        self.f = f

    def process(self, t):
        return [self.f(dict(t))]

    def finish(self):
        return []


class KeyedWindowOp:
    def __init__(self, window):
        self.window = window
        self.bufs = {}

    def process(self, t):
        k = t["K"]
        buf = self.bufs.setdefault(k, [])
        buf.append(t["V"])
        if len(buf) >= self.window:
            del self.bufs[k]
            return [{"K": k, "COUNT": len(buf), "SUM": sum(buf)}]
        return []

    def finish(self):
        outs = []
        for k in sorted(self.bufs):
            buf = self.bufs[k]
            if buf:
                outs.append({"K": k, "COUNT": len(buf), "SUM": sum(buf)})
        self.bufs = {}
        return outs


def make_chain(names, window):
    ops = []
    for n in names:
        if n == "a":
            ops.append(MapOp(lambda t: {**t, "V": t["V"] * 2 + 1}))
        elif n == "b":
            ops.append(MapOp(lambda t: {**t, "V": t["V"] + 10}))
        elif n == "w":
            ops.append(KeyedWindowOp(window))
    return ops


class Fragment:
    """One fragment: bounded inbound -> operator chain -> bounded outbound.

    The operator chain runs "inside" the worker: a worker step takes one
    inbound batch, runs it through every operator, and appends the result
    to outbound — but only when outbound has room (the executor's
    transitive backpressure, collapsed to fragment granularity)."""

    def __init__(self, names, window):
        self.ops = make_chain(names, window)
        self.inbound = deque()
        self.outbound = deque()
        self.closed = False
        self.flushed = False

    def try_send(self, batch):
        if len(self.inbound) >= CH_DEPTH:
            return batch  # full: hand it back
        self.inbound.append(batch)
        return None

    def send_blocking(self, batch, sched):
        while self.try_send(batch) is not None:
            sched()  # workers (incl. ours) advance while we block

    def worker_step(self):
        """One scheduling quantum. Returns True when it made progress."""
        if len(self.outbound) >= CH_DEPTH:
            return False  # downstream of this fragment is our outbound
        if self.inbound:
            batch = self.inbound.popleft()
            out = []
            for t in batch:
                outs = [t]
                for op in self.ops:
                    nxt = []
                    for x in outs:
                        nxt.extend(op.process(x))
                    outs = nxt
                out.extend(outs)
            if out:
                self.outbound.append(out)
            return True
        if self.closed and not self.flushed:
            flush = []
            for i, op in enumerate(self.ops):
                outs = op.finish()
                for x in outs:
                    cur = [x]
                    for later in self.ops[i + 1:]:
                        nxt = []
                        for y in cur:
                            nxt.extend(later.process(y))
                        cur = nxt
                    flush.extend(cur)
            if flush:
                self.outbound.append(flush)
            self.flushed = True
            return True
        return False

    def poll_outputs(self, maxn):
        out = []
        while self.outbound and len(out) < maxn:
            batch = self.outbound[0]
            take = min(len(batch), maxn - len(out))
            out.extend(batch[:take])
            rest = batch[take:]
            self.outbound.popleft()
            if rest:
                self.outbound.appendleft(rest)
        return out

    def drained(self):
        return self.closed and self.flushed and not self.inbound

    def stop(self, sched):
        """Close the input and drain fully; returns the trailing output.

        Mirrors `EngineHandle::finish`: the caller thread consumes the
        output channel *while* the workers drain, so a full outbound
        can never wedge the teardown."""
        self.closed = True
        trailing = []
        guard = 0
        while not self.drained():
            while self.outbound:
                trailing.extend(self.outbound.popleft())
            self.worker_step()
            sched()
            guard += 1
            if guard > 100000:
                raise RuntimeError("fragment stop livelocked")
        while self.outbound:
            trailing.extend(self.outbound.popleft())
        return trailing


class Route:
    def __init__(self, fragments):
        self.frags = fragments
        self.staged = [deque() for _ in fragments]
        self.collected = []
        self.shipped = 0  # batches crossing node boundaries

    def staged_total(self):
        return sum(len(q) for q in self.staged)


def offer_staged(route, i):
    progress = False
    while route.staged[i]:
        take = min(SHIP_CHUNK, len(route.staged[i]))
        batch = [route.staged[i].popleft() for _ in range(take)]
        back = route.frags[i].try_send(batch)
        if back is None:
            progress = True
        else:
            for t in reversed(back):
                route.staged[i].appendleft(t)
            break
    return progress


def pump_route(route, sched):
    while True:
        progress = False
        for i in range(len(route.frags)):
            sched()
            if i > 0:
                progress |= offer_staged(route, i)
            if route.frags[i].drained() and not route.frags[i].outbound:
                continue
            outs = route.frags[i].poll_outputs(PUMP_POLL)
            if not outs:
                continue
            progress = True
            if i + 1 == len(route.frags):
                route.collected.extend(outs)
            else:
                for j in range(0, len(outs), SHIP_CHUNK):
                    route.shipped += 1
                    route.staged[i + 1].extend(outs[j:j + SHIP_CHUNK])
        if not progress:
            return


def feed_route(route, batch, sched):
    for j in range(0, len(batch), SHIP_CHUNK):
        # Non-blocking offer retried around pumps (mirrors the Rust:
        # the feeder keeps the route moving while the first fragment
        # is saturated).
        pending = batch[j:j + SHIP_CHUNK]
        guard = 0
        while pending is not None:
            pending = route.frags[0].try_send(pending)
            if pending is not None:
                pump_route(route, sched)
                sched()  # RETRY_PAUSE: workers get the core
                guard += 1
                if guard > 100000:
                    raise RuntimeError("feed livelocked offering to hop 0")
        pump_route(route, sched)
    guard = 0
    while route.staged_total() > STAGE_WINDOW:
        pump_route(route, sched)
        guard += 1
        if guard > 100000:
            raise RuntimeError("feed livelocked on the staging window")


def stop_route(route, sched):
    for i in range(len(route.frags)):
        guard = 0
        while True:
            pump_route(route, sched)
            if not route.staged[i]:
                break
            sched()
            guard += 1
            if guard > 100000:
                raise RuntimeError("stop livelocked delivering staged tuples")
        trailing = route.frags[i].stop(sched)
        if i + 1 == len(route.frags):
            route.collected.extend(trailing)
        else:
            for j in range(0, len(trailing), SHIP_CHUNK):
                route.shipped += 1
                route.staged[i + 1].extend(trailing[j:j + SHIP_CHUNK])
    return route.collected


def serial_reference(names, window, tuples):
    ops = make_chain(names, window)
    outs = []
    for t in tuples:
        cur = [t]
        for op in ops:
            nxt = []
            for x in cur:
                nxt.extend(op.process(x))
            cur = nxt
        outs.extend(cur)
    for i, op in enumerate(ops):
        for x in op.finish():
            cur = [x]
            for later in ops[i + 1:]:
                nxt = []
                for y in cur:
                    nxt.extend(later.process(y))
                cur = nxt
            outs.extend(cur)
    return outs


CHAINS = [["a"], ["a", "b"], ["a", "w"], ["a", "b", "w"]]


def run_case(rng):
    chain = rng.choice(CHAINS)
    window = rng.randint(1, 4)
    n = rng.randint(0, 60)
    keys = rng.randint(1, 5)
    tuples = []
    per_key = {}
    for i in range(n):
        k = rng.randint(0, keys - 1)
        seqn = per_key.get(k, 0)
        per_key[k] = seqn + 1
        tuples.append({"K": k, "V": rng.randint(0, 31), "SEQN": seqn})

    # Random contiguous cuts -> fragments.
    cuts = sorted({c for c in range(1, len(chain)) if rng.random() < 0.6})
    bounds = [0] + cuts + [len(chain)]
    frags = [Fragment(chain[a:b], window) for a, b in zip(bounds, bounds[1:])]
    route = Route(frags)

    def sched():
        # Random worker interleaving: any fragment may advance.
        for _ in range(rng.randint(0, 4)):
            f = rng.choice(frags)
            f.worker_step()

    batch = rng.randint(1, 16)
    for j in range(0, len(tuples), batch):
        feed_route(route, tuples[j:j + batch], sched)
    out = stop_route(route, sched)

    want = serial_reference(chain, window, tuples)
    canon = lambda ts: sorted(repr(sorted(t.items())) for t in ts)
    assert canon(out) == canon(want), (
        f"multiset mismatch chain={chain} cuts={cuts} n={n}\n"
        f"got {canon(out)}\nwant {canon(want)}"
    )
    # Per-key order for pass-through chains.
    if "w" not in chain:
        last = {}
        for t in out:
            k = t["K"]
            if k in last:
                assert last[k] < t["SEQN"], f"per-key order violated: {out}"
            last[k] = t["SEQN"]
        assert len(out) == len(tuples), "loss/duplication in pass-through chain"
    if len(frags) > 1 and out:
        assert route.shipped > 0, "split route never shipped a batch"


def main():
    cases = int(sys.argv[1]) if len(sys.argv) > 1 else 4000
    rng = random.Random(0xD157)
    for case in range(cases):
        run_case(rng)
        if (case + 1) % 500 == 0:
            print(f"  {case + 1}/{cases} cases ok")
    print(f"dist_stream_sim: {cases} randomized cases passed "
          f"(multiset equivalence, per-key order, zero loss, no livelock)")


if __name__ == "__main__":
    main()
