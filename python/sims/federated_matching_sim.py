#!/usr/bin/env python3
"""Behavioral sim for the federated matching plane (PR 7).

The container has no cargo, so the sharding/federation design is validated
here first, with the exact hash arithmetic `ar/shard.rs` implements:

  1. HRW (rendezvous) shard map: removing a shard moves ONLY the keys it
     owned; adding a shard moves ONLY the keys the new shard wins.  This is
     the property the churn fuzz suite asserts in Rust.
  2. TTL register -> expire -> re-register lifecycle: a swept registration
     never receives a match ("no stale matches after expiry"), and
     re-registration resumes delivery.
  3. The satellite-3 bug: after shard churn moves topic ownership, an
     owner-routed retire_topic misses the old shard and leaves a stale
     match cache behind; the fixed all-shard retire does not.

All arithmetic is u64 (masked), mirroring wrapping Rust ops.
"""

import random

MASK = (1 << 64) - 1


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK
    return h


def mix(z: int) -> int:
    """splitmix64 finalizer, as in util/prng.rs."""
    z = (z + 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return z ^ (z >> 31)


def weight(shard: str, key: str) -> int:
    return mix(fnv1a64(shard.encode()) ^ mix(fnv1a64(key.encode())))


def owner(shards, key):
    # Tie-break on name for determinism (weights are u64 so ties are
    # astronomically unlikely, but the Rust code breaks ties the same way).
    return max(shards, key=lambda s: (weight(s, key), s))


def check_hrw_stability(rng):
    shards = [f"shard-{i}" for i in range(rng.randint(2, 9))]
    keys = [f"topic{rng.randrange(10**6):06}" for _ in range(500)]
    before = {k: owner(shards, k) for k in keys}

    # Remove-stability: only keys owned by the removed shard move.
    victim = rng.choice(shards)
    rest = [s for s in shards if s != victim]
    for k in keys:
        after = owner(rest, k)
        if before[k] != victim:
            assert after == before[k], (
                f"key {k} moved {before[k]} -> {after} though {victim} removed"
            )
        else:
            assert after != victim

    # Add-stability: only keys the new shard wins move.
    newcomer = f"shard-{rng.randrange(100, 200)}"
    grown = shards + [newcomer]
    for k in keys:
        after = owner(grown, k)
        assert after in (before[k], newcomer)

    # Balance sanity: HRW spreads load; no shard should be pathological.
    if len(shards) >= 4:
        counts = {s: 0 for s in shards}
        for k in keys:
            counts[before[k]] += 1
        assert max(counts.values()) < len(keys) * 0.75


class Plane:
    """Sharded matching plane, topic-granular (profiles abstracted to
    exact topic keys here; the Rust side layers real matching on top)."""

    def __init__(self, shards):
        self.shards = {s: {"topics": {}, "cache": {}} for s in shards}
        self.regs = {}  # consumer -> dict(pattern, ttl, registered_at, cursor)

    def register(self, consumer, pattern, ttl, now):
        # Fan-out idiom: the registration exists at every shard; only the
        # TTL watermark is plane-level.  Cursors survive re-registration.
        prev = self.regs.get(consumer)
        cursor = prev["cursor"] if prev else {}
        self.regs[consumer] = {
            "pattern": pattern, "ttl": ttl, "registered_at": now, "cursor": cursor,
        }
        for sh in self.shards.values():
            sh["cache"][consumer] = [
                t for t in sh["topics"] if pattern in t
            ]

    def sweep(self, now):
        expired = [
            c for c, r in self.regs.items()
            if r["ttl"] is not None and now - r["registered_at"] >= r["ttl"]
        ]
        for c in expired:
            del self.regs[c]
            for sh in self.shards.values():
                sh["cache"].pop(c, None)
        return expired

    def publish(self, topic, now):
        own = owner(list(self.shards), topic)
        sh = self.shards[own]
        if topic not in sh["topics"]:
            sh["topics"][topic] = []
            for c, r in self.regs.items():
                if r["pattern"] in topic:
                    sh["cache"].setdefault(c, []).append(topic)
        sh["topics"][topic].append(now)

    def fetch(self, consumer):
        if consumer not in self.regs:
            return []
        out = []
        cur = self.regs[consumer]["cursor"]
        for sh in self.shards.values():
            for t in sh["cache"].get(consumer, []):
                q = sh["topics"].get(t, [])
                seen = cur.get(t, 0)
                out.extend(q[seen:])
                cur[t] = len(q)
        return out

    def retire_topic(self, topic, all_shards):
        if all_shards:
            targets = list(self.shards.values())
        else:  # the buggy owner-only route
            targets = [self.shards[owner(list(self.shards), topic)]]
        hit = False
        for sh in targets:
            if topic in sh["topics"]:
                del sh["topics"][topic]
                for cached in sh["cache"].values():
                    if topic in cached:
                        cached.remove(topic)
                hit = True
        return hit


def check_ttl_lifecycle(rng):
    plane = Plane([f"shard-{i}" for i in range(rng.randint(2, 5))])
    now = 0.0
    live = set()
    for _ in range(60):
        now += rng.random()
        op = rng.random()
        c = f"consumer-{rng.randrange(6)}"
        if op < 0.35:
            plane.register(c, rng.choice(["drone", "lidar", "cam"]), rng.uniform(0.5, 3.0), now)
            live.add(c)
        elif op < 0.7:
            plane.publish(f"{rng.choice(['drone', 'lidar', 'cam'])}{rng.randrange(40):02}", now)
        else:
            for e in plane.sweep(now):
                live.discard(e)
        # Invariant: a consumer whose TTL has lapsed and been swept gets
        # nothing; a never-registered consumer gets nothing.
        dead = f"consumer-{rng.randrange(6)}"
        if dead not in plane.regs:
            assert plane.fetch(dead) == [], "stale match after expiry"
    # Expiry then re-register resumes delivery without replay:
    plane = Plane(["a", "b"])
    plane.register("c1", "drone", 1.0, 0.0)
    plane.publish("drone01", 0.1)
    got = plane.fetch("c1")
    assert len(got) == 1
    assert plane.sweep(2.0) == ["c1"]
    plane.publish("drone01", 2.1)
    assert plane.fetch("c1") == [], "delivered to expired registration"
    # Re-register after a sweep is a FRESH subscription: cursors restart at 0
    # and the retained backlog replays (the Broker's at-least-once contract;
    # cursors survive only live re-registration, i.e. renew-before-expiry).
    plane.register("c1", "drone", 1.0, 2.5)
    got = plane.fetch("c1")
    assert got == [0.1, 2.1], f"re-register should replay retained backlog, got {got}"
    # Renew-before-expiry DOES preserve the cursor:
    plane.publish("drone01", 2.6)
    plane.register("c1", "drone", 1.0, 2.7)
    got = plane.fetch("c1")
    assert got == [2.6], f"live re-register should resume past cursor, got {got}"


def check_cross_shard_retire(rng):
    # Ownership of `topic` must move when a shard is added; find such a case.
    for attempt in range(200):
        shards = [f"shard-{rng.randrange(1000)}" for _ in range(3)]
        topic = f"drone{rng.randrange(10**4):04}"
        extra = f"shard-{rng.randrange(1000, 2000)}"
        if owner(shards + [extra], topic) == extra:
            break
    else:
        raise AssertionError("no ownership-moving churn case found")

    for fixed in (False, True):
        plane = Plane(shards)
        plane.register("c1", "drone", None, 0.0)
        plane.publish(topic, 0.0)
        plane.shards[extra] = {"topics": {}, "cache": {}}
        plane.register("c1", "drone", None, 0.1)  # re-register reaches new shard
        plane.retire_topic(topic, all_shards=fixed)
        stale = plane.fetch("c1")
        if fixed:
            assert stale == [], "all-shard retire left a stale match"
        else:
            assert stale != [], "expected the owner-only route to exhibit the bug"


def main():
    rng = random.Random(0xA11CE)
    for i in range(300):
        check_hrw_stability(rng)
    for i in range(300):
        check_ttl_lifecycle(rng)
    check_cross_shard_retire(rng)
    print("federated_matching_sim: all checks passed")
    print("  - HRW add/remove stability x300 (only owned keys move)")
    print("  - TTL register/expire/re-register x300 (no stale matches)")
    print("  - cross-shard retirement: owner-only route exhibits the bug,")
    print("    all-shard retire fixes it")


if __name__ == "__main__":
    main()
