"""Behavioral simulation of the stream executor's live-rescale protocol.

The container building this repo has no Rust toolchain, so — as with the
PR 2 executor — the protocol in `rust/src/stream/engine.rs` is validated
here first: a faithful sequential model of routers, replica queues,
batching buffers, the export/import state handoff, and the direct
replica→replica exchange, driven by a randomized scheduler that
interleaves router steps, replica steps, producer sends and mid-stream
rescales.

Checked properties (vs the serial reference execution):

1. Output multiset equivalence for every chain (map / filter / keyed
   window / combinations) across arbitrary rescale sequences — zero
   tuple loss, zero duplication, keyed-window aggregates identical
   (state handoff moves every open window to the right replica).
2. Per-key order preservation for pass-through chains (SEQN strictly
   increasing within a key) across handoffs.
3. Same properties for static chains using the direct exchange (no
   router hop on downstream keyed stages).

Run: python3 python/sims/rescale_sim.py [cases]
"""

import random
import struct
import sys
from collections import Counter, defaultdict

MASK = (1 << 64) - 1


def splitmix64(bits):
    """The Rust side's Tuple::hash_bits (SplitMix64 finalizer)."""
    z = (bits + 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return z ^ (z >> 31)


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", float(x)))[0]


def key_hash(x):
    return splitmix64(f64_bits(x))


# ---- Operators (mirroring OperatorKind) ----------------------------------


class Map:
    stateful = False

    def process(self, t):
        t = dict(t)
        t["V"] = t.get("V", 0.0) * 2.0 + 1.0
        return [t]

    def finish(self):
        return []

    def export(self):
        return []

    def import_(self, state):
        assert not state


class Filter(Map):
    def process(self, t):
        return [t] if t.get("V", 0.0) >= 8.0 else []


class KeyedWindow:
    """window_by: per-key tumbling window, aggregates carry the key."""

    stateful = True

    def __init__(self, window):
        self.window = window
        self.bufs = {}  # key_bits -> [values]

    def process(self, t):
        if "K" not in t or "V" not in t:
            return []
        bits = f64_bits(t["K"])
        buf = self.bufs.setdefault(bits, [])
        buf.append(t["V"])
        if len(buf) >= self.window:
            del self.bufs[bits]
            return [aggregate(buf, key_bits=bits)]
        return []

    def finish(self):
        out = []
        for bits in sorted(self.bufs):  # key-bits order: deterministic
            buf = self.bufs[bits]
            if buf:
                out.append(aggregate(buf, key_bits=bits))
        self.bufs = {}
        return out

    def export(self):
        state = [(bits, list(buf)) for bits, buf in sorted(self.bufs.items()) if buf]
        self.bufs = {}
        return state

    def import_(self, state):
        for bits, values in state:
            self.bufs.setdefault(bits, []).extend(values)


def aggregate(values, key_bits=None):
    out = {
        "COUNT": float(len(values)),
        "MEAN": sum(values) / max(len(values), 1),
        "MIN": min(values),
        "MAX": max(values),
    }
    if key_bits is not None:
        out["K"] = struct.unpack("<d", struct.pack("<Q", key_bits))[0]
    return out


CHAINS = [
    ["map"],
    ["filter"],
    ["window"],
    ["map", "window"],
    ["filter", "map"],
    ["map", "filter", "window"],
]


def make_op(kind, window):
    return {"map": Map, "filter": Filter}[kind]() if kind != "window" else KeyedWindow(window)


# ---- Serial reference -----------------------------------------------------


def run_serial(chain, window, tuples):
    ops = [make_op(k, window) for k in chain]
    stream = list(tuples)
    for op in ops:
        nxt = []
        for t in stream:
            nxt.extend(op.process(t))
        nxt.extend(op.finish())
        stream = nxt
    return stream


# ---- Parallel elastic model ----------------------------------------------


class Stage:
    """One routed stage: inbound FIFO, per-replica queues + out-buffers."""

    def __init__(self, kind, window, degree, cap, routed=True):
        self.kind = kind
        self.window = window
        self.cap = cap
        self.routed = routed  # False = direct exchange (no router inbound)
        self.inbound = []  # router inbound (batches flattened: one msg = one tuple)
        self.router_bufs = None
        self.reset(degree)

    def reset(self, degree):
        self.degree = degree
        self.queues = [[] for _ in range(degree)]  # router→replica (tuples)
        self.ops = [make_op(self.kind, self.window) for _ in range(degree)]
        # per-replica output buffer (models the worker's partial batch)
        self.out_bufs = [[] for _ in range(degree)]
        self.router_bufs = [[] for _ in range(degree)]

    def route_target(self, t):
        if "K" not in t:
            return 0
        return key_hash(t["K"]) % self.degree


class Topo:
    def __init__(self, chain, window, degree, cap, rng, elastic=True):
        # elastic=True: every stage routed. elastic=False: downstream
        # keyed stages are direct-linked (no router) like the static path.
        self.rng = rng
        self.cap = cap
        self.stages = []
        for i, kind in enumerate(chain):
            routed = elastic or i == 0
            self.stages.append(Stage(kind, window, degree, cap, routed=routed))
        self.out = []

    # -- scheduler actions --

    def enabled(self):
        acts = []
        for si, st in enumerate(self.stages):
            if st.routed and st.inbound:
                acts.append(("route", si))
            if st.routed and any(st.router_bufs[r] for r in range(st.degree)):
                acts.append(("rflush", si))
            for r in range(st.degree):
                if st.queues[r]:
                    acts.append(("work", si, r))
                if st.out_bufs[r]:
                    acts.append(("wflush", si, r))
        return acts

    def emit_downstream(self, si, batch):
        """A flushed batch arrives downstream atomically (one channel msg)."""
        if not batch:
            return
        if si + 1 == len(self.stages):
            self.out.extend(batch)
            return
        nxt = self.stages[si + 1]
        if nxt.routed:
            nxt.inbound.extend(batch)
        else:
            # Direct exchange: the producer partitions straight into the
            # downstream replica queues. (Batches are per-target in the
            # real emitter; order within a key is preserved either way
            # because a key has a single producer and a single target.)
            for t in batch:
                nxt.queues[nxt.route_target(t)].append(t)

    def step(self, act):
        if act[0] == "route":
            st = self.stages[act[1]]
            t = st.inbound.pop(0)
            r = st.route_target(t)
            st.router_bufs[r].append(t)
            if len(st.router_bufs[r]) >= self.cap:
                st.queues[r].extend(st.router_bufs[r])
                st.router_bufs[r] = []
        elif act[0] == "rflush":
            st = self.stages[act[1]]
            r = self.rng.choice([r for r in range(st.degree) if st.router_bufs[r]])
            st.queues[r].extend(st.router_bufs[r])
            st.router_bufs[r] = []
        elif act[0] == "work":
            si, r = act[1], act[2]
            st = self.stages[si]
            t = st.queues[r].pop(0)
            outs = st.ops[r].process(t)
            st.out_bufs[r].extend(outs)
            if len(st.out_bufs[r]) >= self.cap:
                self.emit_downstream(si, st.out_bufs[r])
                st.out_bufs[r] = []
        elif act[0] == "wflush":
            si, r = act[1], act[2]
            st = self.stages[si]
            self.emit_downstream(si, st.out_bufs[r])
            st.out_bufs[r] = []

    def run_until_quiet(self, budget=1_000_000):
        while budget:
            acts = self.enabled()
            if not acts:
                return
            self.step(self.rng.choice(acts))
            budget -= 1
        raise RuntimeError("scheduler did not quiesce")

    # -- the rescale protocol (mirrors apply_rescale) --

    def rescale(self, si, new_degree):
        st = self.stages[si]
        assert st.routed, "only routed (elastic) stages rescale"
        if new_degree == st.degree:
            return
        # 1. Router flushes its partition buffers (marker ordering).
        for r in range(st.degree):
            st.queues[r].extend(st.router_bufs[r])
            st.router_bufs[r] = []
        # 2. Each replica drains its queue, flushes outputs downstream,
        #    then exports state. Replica drain order is racy in reality —
        #    randomize it (keys never span replicas, so per-key order
        #    is unaffected).
        moved = []
        for r in self.rng.sample(range(st.degree), st.degree):
            while st.queues[r]:
                t = st.queues[r].pop(0)
                st.out_bufs[r].extend(st.ops[r].process(t))
            self.emit_downstream(si, st.out_bufs[r])
            st.out_bufs[r] = []
            moved.extend(st.ops[r].export())
        # 3. Re-partition the key space; seed fresh replicas.
        st.reset(new_degree)
        per = defaultdict(list)
        for bits, values in moved:
            per[splitmix64(bits) % new_degree].append((bits, values))
        for r, state in per.items():
            st.ops[r].import_(state)
        # NOTE: tuples already sitting in the router inbound are routed
        # under the new partitioning after resume — exactly the Rust
        # behavior (the router was "busy" during the handoff).

    def drain(self):
        """End-of-stream: quiesce, then per stage flush finish outputs in
        replica order (the gate), letting downstream interleave."""
        for si, st in enumerate(self.stages):
            self.run_until_quiet()
            # router has no inbound left; flush its partition buffers
            for r in range(st.degree):
                st.queues[r].extend(st.router_bufs[r])
                st.router_bufs[r] = []
            self.run_until_quiet()
            for r in range(st.degree):  # gate: replica order
                outs = st.ops[r].finish()
                st.out_bufs[r].extend(outs)
                self.emit_downstream(si, st.out_bufs[r])
                st.out_bufs[r] = []
        self.run_until_quiet()
        return self.out


# ---- Harness --------------------------------------------------------------


def canon(stream):
    return Counter(tuple(sorted(t.items())) for t in stream)


def gen_tuples(rng, n, keys, with_missing=True):
    out = []
    seqn = defaultdict(int)
    for _ in range(n):
        if with_missing and rng.random() < 0.05:
            t = {"V": float(rng.randrange(32))}  # no key: pins to replica 0
        else:
            k = float(rng.randrange(keys))
            t = {"K": k, "V": float(rng.randrange(32)), "SEQN": float(seqn[k])}
            seqn[k] += 1
        out.append(t)
    return out


def check_per_key_order(out):
    last = {}
    for t in out:
        if "K" not in t or "SEQN" not in t:
            continue
        k = t["K"]
        if k in last:
            assert last[k] < t["SEQN"], f"key {k} reordered"
        last[k] = t["SEQN"]


def one_case(rng, elastic):
    chain = rng.choice(CHAINS)
    window = rng.randrange(1, 6)
    degree = rng.randrange(1, 5) if elastic else rng.randrange(2, 5)
    cap = rng.randrange(1, 8)
    n = rng.randrange(0, 64)
    keys = rng.randrange(1, 9)
    tuples = gen_tuples(rng, n, keys, with_missing=not elastic or rng.random() < 0.5)

    topo = Topo(chain, window, degree, cap, rng, elastic=elastic)
    # Interleave sends, scheduler steps, and (elastic only) rescales.
    n_rescales = rng.randrange(0, 4) if elastic else 0
    rescale_at = sorted(rng.randrange(0, n + 1) for _ in range(n_rescales))
    for i, t in enumerate(tuples):
        while rescale_at and rescale_at[0] == i:
            rescale_at.pop(0)
            topo.rescale(rng.randrange(len(chain)), rng.randrange(1, 6))
        topo.stages[0].inbound.append(t)
        for _ in range(rng.randrange(0, 4)):  # concurrent progress
            acts = topo.enabled()
            if acts:
                topo.step(rng.choice(acts))
    while rescale_at:
        rescale_at.pop(0)
        topo.rescale(rng.randrange(len(chain)), rng.randrange(1, 6))
    out = topo.drain()

    expect = run_serial(chain, window, tuples)
    assert canon(out) == canon(expect), (
        f"multiset diverged: chain={chain} window={window} degree={degree} "
        f"cap={cap} n={n} keys={keys} elastic={elastic}\n"
        f"got  {sorted(canon(out).items())}\nwant {sorted(canon(expect).items())}"
    )
    if all(k in ("map",) for k in chain) or chain == ["filter", "map"]:
        check_per_key_order(out)


def main():
    cases = int(sys.argv[1]) if len(sys.argv) > 1 else 3000
    rng = random.Random(0x5EED)
    for i in range(cases):
        one_case(rng, elastic=True)
        one_case(rng, elastic=False)  # static path incl. direct exchange
        if (i + 1) % 500 == 0:
            print(f"  {i + 1}/{cases} case pairs OK")
    print(f"rescale_sim: {cases} elastic + {cases} static case pairs passed")


if __name__ == "__main__":
    main()
