#!/usr/bin/env python3
"""Behavioral simulation of the PR-6 async net plane (rust/src/stream/dist.rs).

The container has no cargo, so — like dist_stream_sim.py did for PR 4 —
this ports the shipper/staging semantics to Python and fuzzes them under
randomized interleavings of: producer feeds, shipper passes (deliver /
encode / collect), arbitrary ingress rejections (backpressure), and the
halt → restage → front-to-back cascade teardown.

Checked invariants, per randomized case:
  1. zero loss / zero duplication: output multiset == expected multiset;
  2. per-key order: outputs of one key appear in their feed order;
  3. encode-once: every batch is encoded exactly once, regardless of
     how many times ingress rejected it (WireBatch keeps its bytes);
  4. encodes == shipped messages at the end of a clean run;
  5. the staging window bounds staged tuples (backpressure, no runaway).
"""

import random
import sys
from collections import deque

STAGE_WINDOW = 4096
SHIP_CHUNK = 64


class WireBatch:
    """Encoded batch: counts its encode exactly once at construction."""

    def __init__(self, tuples, counters):
        self.tuples = list(tuples)
        counters["encodes"] += 1


class Fragment:
    """Identity fragment: per-key FIFO (models the executor's per-key
    order guarantee); egress is drained in arrival order."""

    def __init__(self):
        self.egress = deque()

    def ingest(self, tuples):
        self.egress.extend(tuples)

    def drain(self, maxn):
        out = []
        while self.egress and len(out) < maxn:
            out.append(self.egress.popleft())
        return out


class Route:
    def __init__(self, nfrags, counters, rng):
        self.frags = [Fragment() for _ in range(nfrags)]
        self.staged = [deque() for _ in range(nfrags - 1)]
        self.staged_count = 0
        self.collected = []
        self.counters = counters
        self.rng = rng

    def feed(self, batch):
        self.frags[0].ingest(batch)

    def shipper_pass(self):
        """One pass over every boundary, mirroring shipper_pass():
        deliver staged (random rejection re-fronts, no re-encode),
        then drain upstream egress into fresh encodes bounded by the
        window, then sweep the last fragment."""
        for b in range(len(self.frags) - 1):
            q = self.staged[b]
            while q:
                wb = q.popleft()
                if self.rng.random() < 0.4:  # ingress full: give_back
                    q.appendleft(wb)
                    break
                self.frags[b + 1].ingest(wb.tuples)
                self.staged_count -= len(wb.tuples)
                self.counters["messages"] += 1
            while self.staged_count < STAGE_WINDOW:
                chunk = self.frags[b].drain(SHIP_CHUNK)
                if not chunk:
                    break
                self.staged[b].append(WireBatch(chunk, self.counters))
                self.staged_count += len(chunk)
        self.collected.extend(self.frags[-1].drain(256))

    def stop(self):
        """halt (staged stays in order) + front-to-back cascade: every
        boundary is fully drained and delivered before the next closes.
        Teardown retries rejections until admitted (downstream is
        draining, so it always eventually admits)."""
        for b in range(len(self.frags) - 1):
            while True:
                chunk = self.frags[b].drain(SHIP_CHUNK)
                if not chunk:
                    break
                self.staged[b].append(WireBatch(chunk, self.counters))
            for wb in self.staged[b]:
                self.frags[b + 1].ingest(wb.tuples)
                self.counters["messages"] += 1
            self.staged[b].clear()
        self.collected.extend(self.frags[-1].drain(1 << 30))
        return self.collected


def run_case(seed):
    rng = random.Random(seed)
    nfrags = rng.randint(2, 4)
    nkeys = rng.randint(1, 6)
    counters = {"encodes": 0, "messages": 0}
    route = Route(nfrags, counters, rng)
    n = rng.randint(0, 600)
    inputs = [(rng.randrange(nkeys), i) for i in range(n)]
    i = 0
    while i < len(inputs):
        step = rng.randrange(3)
        if step == 0:
            k = rng.randint(1, 48)
            route.feed(inputs[i : i + k])
            i += k
        else:
            route.shipper_pass()
        assert route.staged_count <= STAGE_WINDOW + SHIP_CHUNK, "window blown"
    for _ in range(rng.randrange(4)):
        route.shipper_pass()
    out = route.stop()

    assert sorted(out) == sorted(inputs), f"loss/dup: {len(out)} vs {len(inputs)}"
    last = {}
    for k, s in out:
        assert last.get(k, -1) < s, f"key {k} reordered: {last[k]} then {s}"
        last[k] = s
    assert counters["encodes"] == counters["messages"], (
        f"encode-once broken: {counters}"
    )


def main():
    cases = int(sys.argv[1]) if len(sys.argv) > 1 else 4000
    for seed in range(cases):
        run_case(seed)
    print(f"netplane sim OK: {cases} randomized cases "
          "(zero loss, per-key order, encode-once == messages, bounded window)")


if __name__ == "__main__":
    main()
