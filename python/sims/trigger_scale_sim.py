#!/usr/bin/env python3
"""Behavioral simulation of the scaled trigger plane (pipeline/trigger.rs,
pipeline/concurrent.rs, pipeline/pool.rs) ahead of the Rust implementation.

Models the PR-9 design:
  - admission control: bounded in-flight activations; a refused binding
    is *not fetched* (its broker cursor never advances), so refusal +
    retry loses nothing;
  - per-tenant fair scheduling: pass order = tenants ascending by
    lifetime admitted activations (deficit), rotation breaking ties,
    one binding per tenant per interleave round, each tenant's own
    binding list also rotated;
  - warm pools: a stateless pipeline is parked live on decommission; a
    stateful one is stopped (partial windows flush — the engine's
    finish() contract) and a fresh standby is pre-deployed in its
    place. Capacity-bound, coldest-first eviction, reclaim-under-
    pressure evicts down to a floor;
  - concurrent pump: per-binding work items are independent (bindings
    share nothing), so any completion interleaving of one pass must
    equal the sequential pass — modeled by executing step results in a
    shuffled order.

Invariants checked over randomized schedules:
  1. Zero loss under admission pressure: every published tuple is
     delivered after the drain, for every (cap, warm) configuration.
  2. warm-path == cold-path output multiset, including keyed-window
     (stateful) pipelines — the flush-on-park rule is what makes this
     hold.
  3. Pool residency never exceeds capacity; evictions are counted and
     evicted in-flight outputs are not lost.
  4. Fairness: under symmetric continuous backlog, per-tenant admitted
     activation counts stay within a spread of 2.
  5. Concurrent (shuffled completion) pass == sequential pass outputs.

Run: python3 python/sims/trigger_scale_sim.py  (exit 0 = all hold)
"""

import random
import sys

FETCH_MAX = 1024


class Broker:
    """Per-topic FIFO with one cursor per consumer (at-least-once)."""

    def __init__(self):
        self.topics = {}
        self.cursors = {}

    def publish(self, topic, item):
        self.topics.setdefault(topic, []).append(item)

    def subscribe(self, consumer, topic):
        # One topic per binding is enough for the scale model.
        self.cursors[consumer] = {"topic": topic, "i": 0}

    def lag(self, consumer):
        cur = self.cursors[consumer]
        return len(self.topics.get(cur["topic"], [])) - cur["i"]

    def fetch(self, consumer, maximum):
        cur = self.cursors[consumer]
        log = self.topics.get(cur["topic"], [])
        out = log[cur["i"]:cur["i"] + maximum]
        cur["i"] += len(out)
        return list(out)


class Instance:
    """One deployed pipeline instance. kind: 'relay' (stateless) or
    ('window', W) (keyed window of W, emits per-key sums, partials
    flushed on stop)."""

    def __init__(self, kind):
        self.kind = kind
        self.windows = {}  # key -> [values]
        self.inflight = []  # processed but not yet polled

    def feed(self, batch):
        for item in batch:
            if self.kind == "relay":
                self.inflight.append(("out", item["val"]))
            else:
                w = self.kind[1]
                buf = self.windows.setdefault(item["key"], [])
                buf.append(item["val"])
                if len(buf) == w:
                    self.inflight.append(("agg", item["key"], sum(buf), w))
                    self.windows[item["key"]] = []

    def poll(self, rng):
        # The engine surfaces outputs asynchronously: a poll sees some
        # prefix of what has been processed.
        n = rng.randint(0, len(self.inflight))
        out, self.inflight = self.inflight[:n], self.inflight[n:]
        return out

    def stop(self):
        # Zero-loss drain; finish() flushes partial windows (key order).
        out, self.inflight = self.inflight, []
        for key in sorted(self.windows):
            buf = self.windows[key]
            if buf:
                out.append(("agg", key, sum(buf), len(buf)))
        self.windows = {}
        return out


class WarmPool:
    def __init__(self, capacity):
        self.capacity = capacity
        self.entries = {}  # name -> (instance, parked_seq)
        self.seq = 0
        self.evictions = 0
        self.max_resident = 0

    def take(self, name):
        entry = self.entries.pop(name, None)
        return entry[0] if entry else None

    def park(self, name, inst, stateful):
        """Returns (tail_outputs_for_name, [(other_name, tail), ...])."""
        if self.capacity == 0:
            return inst.stop(), []
        if stateful:
            tail = inst.stop()  # flush => warm == cold semantics
            inst = Instance(inst.kind)  # pre-built standby
        else:
            tail = []
        self.seq += 1
        self.entries[name] = (inst, self.seq)
        evicted = []
        while len(self.entries) > self.capacity:
            coldest = min(self.entries, key=lambda n: self.entries[n][1])
            ev_inst, _ = self.entries.pop(coldest)
            self.evictions += 1
            evicted.append((coldest, ev_inst.stop()))
        self.max_resident = max(self.max_resident, len(self.entries))
        return tail, evicted

    def reclaim(self, keep):
        evicted = []
        while len(self.entries) > keep:
            coldest = min(self.entries, key=lambda n: self.entries[n][1])
            ev_inst, _ = self.entries.pop(coldest)
            self.evictions += 1
            evicted.append((coldest, ev_inst.stop()))
        return evicted

    def drain(self):
        out = [(n, inst.stop()) for n, (inst, _) in self.entries.items()]
        self.entries = {}
        return out


class Binding:
    def __init__(self, name, tenant, kind):
        self.name = name
        self.tenant = tenant
        self.kind = kind
        self.stateful = kind != "relay"
        self.active = None
        self.outputs = []
        self.activations = 0
        self.rejections = 0


class Manager:
    def __init__(self, broker, cap, warm_capacity, concurrent, rng):
        self.broker = broker
        self.bindings = {}
        self.cap = cap
        self.warm = WarmPool(warm_capacity)
        self.concurrent = concurrent
        self.rng = rng
        self.rr = 0
        self.rr_tenant = {}
        self.admitted = {}
        self.rejected = 0
        self.warm_hits = 0

    def bind(self, name, tenant, kind, topic):
        self.bindings[name] = Binding(name, tenant, kind)
        self.broker.subscribe("trigger:" + name, topic)

    def order(self):
        groups = {}
        for b in self.bindings.values():  # dict is insertion-ordered;
            groups.setdefault(b.tenant, []).append(b.name)  # model BTreeMap:
        tenants = sorted(groups)  # sorted names
        for t in tenants:
            groups[t].sort()
        if tenants:
            rot = self.rr % len(tenants)
            tenants = tenants[rot:] + tenants[:rot]
        self.rr += 1
        tenants.sort(key=lambda t: self.admitted.get(t, 0))  # stable: deficit
        for t in tenants:
            r = self.rr_tenant.get(t, 0) % len(groups[t])
            groups[t] = groups[t][r:] + groups[t][:r]
            self.rr_tenant[t] = self.rr_tenant.get(t, 0) + 1
        out, i = [], 0
        while True:
            row = [groups[t][i] for t in tenants if i < len(groups[t])]
            if not row:
                return out
            out.extend(row)
            i += 1

    def route(self, evicted):
        for name, tail in evicted:
            if name in self.bindings:
                self.bindings[name].outputs.extend(tail)

    def step(self, b, msgs):
        """The runner's per-binding work item. Returns nothing; mutates b."""
        if msgs:
            if b.active is None:
                inst = self.warm.take(b.name)
                if inst is not None:
                    self.warm_hits += 1
                else:
                    inst = Instance(b.kind)
                b.active = inst
                b.activations += 1
            b.active.feed(msgs)
        if b.active is not None:
            b.outputs.extend(b.active.poll(self.rng))
            if not msgs:  # eager idle policy: decommission now
                tail, evicted = self.warm.park(b.name, b.active, b.stateful)
                b.active = None
                b.outputs.extend(tail)
                self.route(evicted)

    def pump(self):
        active_now = sum(1 for b in self.bindings.values() if b.active)
        work = []
        for name in self.order():
            b = self.bindings[name]
            consumer = "trigger:" + name
            if b.active is None:
                if self.broker.lag(consumer) == 0:
                    continue
                if active_now >= self.cap:
                    self.rejected += 1
                    b.rejections += 1
                    continue  # cursor untouched: retry loses nothing
                active_now += 1
                self.admitted[b.tenant] = self.admitted.get(b.tenant, 0) + 1
            msgs = self.broker.fetch(consumer, FETCH_MAX)
            work.append((b, msgs))
            if self.concurrent:
                continue  # dispatch everything, then "complete" shuffled
            self.step(b, msgs)
            # NOTE: a mid-pass decommission does NOT free an admission
            # slot until the next pass — pass-start snapshot semantics,
            # chosen so sequential and concurrent modes make identical
            # admission decisions (the pool only learns of
            # decommissions when it collects step results).
        if self.concurrent:
            self.rng.shuffle(work)  # any completion order must be fine
            for b, msgs in work:
                self.step(b, msgs)

    def drain(self, limit=10_000):
        for _ in range(limit):
            self.pump()
            if all(b.active is None for b in self.bindings.values()) and all(
                self.broker.lag("trigger:" + n) == 0 for n in self.bindings
            ):
                return
        raise AssertionError("drain did not converge")

    def shutdown(self):
        for b in self.bindings.values():
            if b.active is not None:
                b.outputs.extend(b.active.stop())
                b.active = None
        self.route(self.warm.drain())


def run_schedule(seed, cap, warm_capacity, concurrent):
    """One randomized burst schedule; returns (manager, published)."""
    rng = random.Random(seed)
    broker = Broker()
    mgr = Manager(broker, cap, warm_capacity, concurrent, random.Random(seed + 1))
    n_tenants = rng.randint(1, 4)
    n_bindings = rng.randint(2, 10)
    published = {}
    for i in range(n_bindings):
        kind = "relay" if rng.random() < 0.5 else ("window", rng.randint(2, 4))
        name = f"b{i:02d}"
        mgr.bind(name, f"t{i % n_tenants}", kind, f"topic{i}")
        published[name] = []
    for _ in range(rng.randint(2, 6)):  # rounds of bursts + idle gaps
        for i in range(n_bindings):
            name = f"b{i:02d}"
            for _ in range(rng.randint(0, 12)):
                item = {"val": len(published[name]), "key": rng.randint(0, 2)}
                broker.publish(f"topic{i}", item)
                published[name].append(item)
        for _ in range(rng.randint(1, 6)):
            mgr.pump()
    mgr.drain()
    mgr.shutdown()
    return mgr, published


def expected_outputs(items, kind):
    """What a single cold activation fed everything at once would emit —
    NOT the reference (burst boundaries flush windows); used only for
    the relay zero-loss check."""
    inst = Instance(kind)
    inst.feed(items)
    return inst.stop()


def check_zero_loss_and_warm_equivalence():
    for seed in range(120):
        for cap in (1, 2, 10**9):
            baseline = None
            for warm_capacity in (0, 3, 10**9):
                for concurrent in (False, True):
                    mgr, published = run_schedule(seed, cap, warm_capacity, concurrent)
                    for name, b in mgr.bindings.items():
                        if b.kind == "relay":
                            got = sorted(v for tag, v in b.outputs)
                            want = sorted(
                                i["val"] for i in published[name]
                            )
                            assert got == want, (
                                f"seed {seed} cap {cap} warm {warm_capacity} "
                                f"conc {concurrent} {name}: relay lost tuples"
                            )
                    # Full-run output multiset must be identical across
                    # every (warm, concurrent) config — warm pooling and
                    # concurrency are lifecycle choices, not semantics.
                    snap = {
                        n: sorted(map(repr, b.outputs))
                        for n, b in mgr.bindings.items()
                    }
                    if baseline is None:
                        baseline = snap
                    else:
                        assert snap == baseline, (
                            f"seed {seed} cap {cap} warm {warm_capacity} "
                            f"conc {concurrent}: output multiset diverged"
                        )
                    assert mgr.warm.max_resident <= max(warm_capacity, 0) or (
                        warm_capacity == 10**9
                    ), "pool exceeded capacity"
    print("zero loss + warm==cold + concurrent==sequential: OK")


def check_admission_pressure_counts():
    saw_rejections = False
    for seed in range(40):
        mgr, published = run_schedule(seed, 1, 0, False)
        if mgr.rejected:
            saw_rejections = True
        total_out = sum(len(b.outputs) for b in mgr.bindings.values())
        assert total_out > 0 or all(len(v) == 0 for v in published.values())
    assert saw_rejections, "cap=1 schedules must actually refuse activations"
    print("admission refusals happen and still lose nothing: OK")


def check_eviction_and_reclaim():
    rng = random.Random(7)
    broker = Broker()
    mgr = Manager(broker, 10**9, 2, False, rng)
    for i in range(5):
        mgr.bind(f"b{i}", "t0", "relay", f"topic{i}")
        broker.publish(f"topic{i}", {"val": i, "key": 0})
    mgr.drain()
    assert len(mgr.warm.entries) <= 2
    assert mgr.warm.evictions >= 3, mgr.warm.evictions
    evicted = mgr.warm.reclaim(0)
    mgr.route(evicted)
    assert len(mgr.warm.entries) == 0
    mgr.shutdown()
    got = sorted(v for b in mgr.bindings.values() for _, v in b.outputs)
    assert got == [0, 1, 2, 3, 4], got
    print("eviction bounds residency, reclaim drains, nothing lost: OK")


def check_fairness():
    # Symmetric continuous backlog: T tenants x K bindings, cap 1.
    # Deficit order must keep per-tenant admitted counts within 2.
    for tenants, per in ((2, 3), (3, 2), (4, 1)):
        rng = random.Random(11)
        broker = Broker()
        mgr = Manager(broker, 1, 0, False, rng)
        n = 0
        for t in range(tenants):
            for k in range(per):
                mgr.bind(f"b{t}{k}", f"t{t}", "relay", f"topic{n}")
                for v in range(50):
                    broker.publish(f"topic{n}", {"val": v, "key": 0})
                n += 1
        for _ in range(40):
            mgr.pump()
        counts = [mgr.admitted.get(f"t{t}", 0) for t in range(tenants)]
        assert all(c > 0 for c in counts), f"starved tenant: {counts}"
        assert max(counts) - min(counts) <= 2, f"unfair spread: {counts}"
    print("per-tenant deficit scheduling keeps admissions balanced: OK")


def check_rotation_prevents_fixed_order_starvation():
    # The PR-9 bugfix scenario: cap 1, bindings a..e of one tenant plus
    # a late-sorting binding z of another. Fixed map order would always
    # grant the slot inside the a* block; rotation + deficit must let z
    # through early.
    rng = random.Random(3)
    broker = Broker()
    mgr = Manager(broker, 1, 0, False, rng)
    for i, name in enumerate(["a0", "a1", "a2", "a3"]):
        mgr.bind(name, "ta", "relay", f"topic{i}")
        for v in range(5):
            broker.publish(f"topic{i}", {"val": v, "key": 0})
    mgr.bind("z0", "tz", "relay", "topicz")
    for v in range(5):
        broker.publish("topicz", {"val": v, "key": 0})
    passes_until_z = None
    for p in range(1, 20):
        mgr.pump()
        if mgr.bindings["z0"].activations > 0:
            passes_until_z = p
            break
    assert passes_until_z is not None and passes_until_z <= 4, passes_until_z
    print(f"rotation/deficit admits the late-sorting tenant by pass "
          f"{passes_until_z}: OK")


def main():
    check_zero_loss_and_warm_equivalence()
    check_admission_pressure_counts()
    check_eviction_and_reclaim()
    check_fairness()
    check_rotation_prevents_fixed_order_starvation()
    print("trigger_scale_sim: all invariants hold")


if __name__ == "__main__":
    sys.exit(main())
