#!/usr/bin/env python3
"""Behavioral pre-validation of the live fragment-migration protocol
(PR 8) — no cargo in the dev container, so the drain/handoff/re-route
sequencing is fuzzed here before the Rust implementation.

Model
-----
A topology is a linear chain of stages split into contiguous fragments,
each fragment hosted on a node. Tuples are (key, value) pairs; the one
stateful stage is a keyed tumbling window (per-key buffer, emits the
sum every W samples, flushes partials only at stream end). Between
fragments sits a staging queue of batches: `staged[i]` holds batches
shipped toward fragment i but not yet admitted (the Rust RouteState's
per-hop VecDeque + the shipper's in-flight set).

Migration protocol under test (the Rust `migrate_fragment` contract):

1. stop feeding the route, halt the shipper (in-flight batches restage
   in order — modeled by `staged` never reordering),
2. freeze the old fragment: everything already *delivered* to it is
   processed and its outputs shipped downstream, then every stage
   exports its per-key state (open windows move, they do NOT flush),
3. the state ships to the destination node and is imported into a
   freshly started fragment (hash re-partitioning is a no-op here: the
   model keeps one logical operator per stage, as does the per-key
   merge in Rust),
4. `staged[i]` batches — never delivered to the old fragment — are
   re-routed to the new fragment unchanged and in order,
5. feeding resumes.

Invariants fuzzed (multiset + order + liveness + accounting):

- outputs across any schedule of feeds/deliveries/pumps/migrations are
  multiset-equal to a single-node reference run,
- per-key output order matches the reference exactly,
- every schedule terminates (no livelock): bounded step count,
- encode-once accounting: data batches are encoded exactly once when
  first shipped; migrations add only state frames, so
  `data_encodes + state_frames == messages`.
"""

import random
import sys
from collections import defaultdict

WINDOW = 3


class KeyedWindow:
    """Per-key tumbling sum window (the stateful stage)."""

    def __init__(self):
        self.bufs = defaultdict(list)

    def process(self, t):
        k, v = t
        buf = self.bufs[k]
        buf.append(v)
        if len(buf) == WINDOW:
            out = (k, sum(buf))
            self.bufs[k] = []
            return [out]
        return []

    def export_state(self):
        state = {k: list(b) for k, b in self.bufs.items() if b}
        self.bufs = defaultdict(list)
        return state

    def import_state(self, state):
        for k, b in state.items():
            self.bufs[k].extend(b)

    def finish(self):
        outs = [(k, sum(b)) for k, b in sorted(self.bufs.items()) if b]
        self.bufs = defaultdict(list)
        return outs


class Mapper:
    """Stateless stage: value transform keeps per-key order observable."""

    def __init__(self, delta):
        self.delta = delta

    def process(self, t):
        return [(t[0], t[1] + self.delta)]

    def export_state(self):
        return {}

    def import_state(self, state):
        assert not state

    def finish(self):
        return []


def make_stage(spec):
    return KeyedWindow() if spec == "kwin" else Mapper(int(spec[3:]))


class Fragment:
    """One placed fragment: delivered-but-unprocessed inbox + stages."""

    def __init__(self, specs, node):
        self.specs = specs
        self.node = node
        self.inbox = []  # delivered batches, FIFO
        self.stages = [make_stage(s) for s in specs]

    def run_batch(self, batch):
        for stage in self.stages:
            nxt = []
            for t in batch:
                nxt.extend(stage.process(t))
            batch = nxt
        return batch

    def drain_inbox(self):
        out = []
        while self.inbox:
            out.extend(self.run_batch(self.inbox.pop(0)))
        return out

    def freeze(self):
        """Drain delivered input, then move (not flush) all state."""
        trailing = self.drain_inbox()
        states = [s.export_state() for s in self.stages]
        return trailing, states

    def finish(self):
        out = self.drain_inbox()
        for i, stage in enumerate(self.stages):
            flushed = stage.finish()
            for later in self.stages[i + 1 :]:
                nxt = []
                for t in flushed:
                    nxt.extend(later.process(t))
                flushed = nxt
            out.extend(flushed)
        return out


class Route:
    def __init__(self, fragments):
        self.frags = fragments
        n = len(fragments)
        self.staged = [[] for _ in range(n)]  # staged[i] feeds frag i
        self.collected = []
        self.data_encodes = 0
        self.state_frames = 0
        self.messages = 0
        self.migrations = 0

    def feed(self, batch):
        # Encode-once: a batch is encoded when it first ships a hop.
        self.staged[0].append(list(batch))

    def deliver_one(self, i, rng):
        """Admit one staged batch into fragment i (the offer path)."""
        if not self.staged[i]:
            return False
        batch = self.staged[i].pop(0)
        if i > 0:  # hop 0 is local ingress; hops 1.. cross the network
            self.data_encodes += 1
            self.messages += 1
        self.frags[i].inbox.append(batch)
        return True

    def pump_one(self, i):
        """Process one delivered batch through fragment i."""
        if not self.frags[i].inbox:
            return False
        out = self.frags[i].run_batch(self.frags[i].inbox.pop(0))
        self.route_out(i, out)
        return True

    def route_out(self, i, out):
        if not out:
            return
        if i + 1 == len(self.frags):
            self.collected.extend(out)
        else:
            self.staged[i + 1].append(out)

    def migrate(self, i, to_node):
        """The protocol under test (steps 2–4 of the module docstring)."""
        frag = self.frags[i]
        trailing, states = frag.freeze()
        self.route_out(i, trailing)
        # Ship one state frame per stage holding state.
        for st in states:
            if st:
                self.state_frames += 1
                self.messages += 1
        fresh = Fragment(frag.specs, to_node)
        for stage, st in zip(fresh.stages, states):
            stage.import_state(st)
        self.frags[i] = fresh  # staged[i] re-routes untouched, in order
        self.migrations += 1

    def stop(self):
        """Zero-loss teardown: drain staged + inboxes upstream-first."""
        for i in range(len(self.frags)):
            while self.deliver_one(i, None) or self.pump_one(i):
                pass
            self.route_out(i, self.frags[i].finish())
        return self.collected


def reference_run(specs, tuples):
    frag = Fragment(specs, "ref")
    out = frag.run_batch(list(tuples))
    return out + frag.finish()


def run_case(seed):
    rng = random.Random(seed)
    nstages = rng.randint(2, 5)
    specs = [f"map{rng.randint(1, 9)}" for _ in range(nstages - 1)]
    specs.insert(rng.randrange(nstages), "kwin")
    # Random contiguous fragmentation into 1..n fragments.
    cuts = sorted(rng.sample(range(1, nstages), rng.randint(0, nstages - 1)))
    bounds = [0] + cuts + [nstages]
    frags = [
        Fragment(specs[a:b], f"node{j}")
        for j, (a, b) in enumerate(zip(bounds, bounds[1:]))
    ]
    route = Route(frags)

    nkeys = rng.randint(1, 5)
    seqs = defaultdict(int)
    tuples = []
    for i in range(rng.randint(5, 120)):
        k = rng.randrange(nkeys)
        seqs[k] += 1
        tuples.append((k, seqs[k] * 1000 + rng.randint(0, 9)))

    fed = 0
    steps = 0
    budget = 10_000
    while fed < len(tuples) or rng.random() < 0.3:
        steps += 1
        assert steps < budget, f"seed {seed}: livelock (no progress bound hit)"
        action = rng.random()
        if action < 0.4 and fed < len(tuples):
            n = min(rng.randint(1, 7), len(tuples) - fed)
            route.feed(tuples[fed : fed + n])
            fed += n
        elif action < 0.65:
            route.deliver_one(rng.randrange(len(frags)), rng)
        elif action < 0.9:
            route.pump_one(rng.randrange(len(frags)))
        else:
            # Migrate a random fragment to a fresh node mid-stream.
            i = rng.randrange(len(frags))
            route.migrate(i, f"node{rng.randint(100, 999)}")
        if fed == len(tuples) and rng.random() < 0.5:
            break

    got = route.stop()
    want = reference_run(specs, tuples)

    assert sorted(got) == sorted(want), (
        f"seed {seed}: multiset diverged\n got {sorted(got)}\nwant {sorted(want)}"
    )
    per_key_got = defaultdict(list)
    per_key_want = defaultdict(list)
    for k, v in got:
        per_key_got[k].append(v)
    for k, v in want:
        per_key_want[k].append(v)
    assert per_key_got == per_key_want, f"seed {seed}: per-key order diverged"
    assert route.data_encodes + route.state_frames == route.messages, (
        f"seed {seed}: encode-once accounting broke"
    )
    return route.migrations, len(got)


def main():
    cases = int(sys.argv[1]) if len(sys.argv) > 1 else 3000
    migrations = outputs = 0
    for seed in range(cases):
        m, o = run_case(seed)
        migrations += m
        outputs += o
    print(
        f"migration_sim OK: {cases} randomized schedules, "
        f"{migrations} migrations, {outputs} outputs verified "
        f"(multiset, per-key order, encode-once, bounded steps)"
    )


if __name__ == "__main__":
    main()
