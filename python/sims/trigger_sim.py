#!/usr/bin/env python3
"""Behavioral simulation of the trigger plane (pipeline/trigger.rs).

Models the interaction of:
  - broker topics (per-topic FIFO queues with an at-least-once consumer
    cursor per binding),
  - the single-threaded pump loop (fetch -> activate-if-data ->
    feed -> poll -> decommission-if-idle),
  - a keyed parallel pipeline abstracted as a per-key FIFO (the
    executor's proven guarantee: per-key order, zero loss on stop),

over randomized schedules of publish bursts, idle gaps (zero-threshold
idle policy => every no-data pump decommissions), and mid-activation
faults (which drop in-flight tuples of the faulted activation only —
the documented at-least-once boundary).

Invariants checked per schedule:
  1. Without faults: every published tuple is delivered exactly once.
  2. Per-key order: each key's ORD sequence replays in publish order.
  3. Activation/decommission counters balance after the final drain.
  4. Data published while idle survives the gap (cursor holds it).
  5. With faults: only tuples fed to the faulted activation may be
     lost; everything published after the fault is still delivered.

Run: python3 python/sims/trigger_sim.py  (exit 0 = all invariants hold)
"""

import random
import sys


class Broker:
    """Per-topic FIFO with one cursor per consumer (at-least-once)."""

    def __init__(self):
        self.topics = {}  # name -> list of tuples
        self.cursors = {}  # consumer -> {topic: index}

    def publish(self, topic, item):
        self.topics.setdefault(topic, []).append(item)

    def subscribe(self, consumer):
        self.cursors[consumer] = {}

    def fetch(self, consumer, maximum):
        out = []
        cur = self.cursors[consumer]
        for topic in sorted(self.topics):  # deterministic round order
            log = self.topics[topic]
            i = cur.get(topic, 0)
            while i < len(log) and len(out) < maximum:
                out.append(log[i])
                i += 1
            cur[topic] = i
        return out


class Pipeline:
    """Keyed relay abstraction: per-key FIFO, zero-loss stop, optional
    poison item that faults the activation and drops what was fed to it
    and not yet polled."""

    def __init__(self):
        self.buffers = []  # fed, not yet polled
        self.faulted = False

    def feed(self, batch):
        for item in batch:
            if item.get("poison"):
                self.faulted = True
            self.buffers.append(item)

    def poll(self):
        if self.faulted:
            return []
        out, self.buffers = self.buffers, []
        return out

    def stop(self):
        if self.faulted:
            raise RuntimeError("activation faulted")
        out, self.buffers = self.buffers, []
        return out


class TriggerManager:
    def __init__(self, broker):
        self.broker = broker
        self.broker.subscribe("trigger")
        self.active = None
        self.outputs = []
        self.stats = {"activations": 0, "decommissions": 0, "faults": 0, "fed": 0}

    def pump(self):
        msgs = self.broker.fetch("trigger", 1024)
        if msgs:
            if self.active is None:
                self.active = Pipeline()
                self.stats["activations"] += 1
            self.active.feed(msgs)
            self.stats["fed"] += len(msgs)
        if self.active is not None:
            self.outputs.extend(self.active.poll())
            if self.active.faulted:
                # stop() raises -> fail_binding path: discard, idle.
                self.active = None
                self.stats["faults"] += 1
                return
            if not msgs:  # zero-threshold idle policy
                self.outputs.extend(self.active.stop())
                self.active = None
                self.stats["decommissions"] += 1


def run_schedule(seed, with_faults):
    rng = random.Random(seed)
    broker = Broker()
    trig = TriggerManager(broker)
    keys = rng.randint(1, 4)
    ord_counter = [0] * keys
    published = []
    poisoned_round = rng.randrange(2, 5) if with_faults else None
    rounds = rng.randint(2, 6)
    fault_seen = False
    lost_candidates = set()  # seqs fed to the faulted activation
    seq = 0
    for r in range(rounds):
        burst = rng.randint(1, 24)
        for _ in range(burst):
            k = rng.randrange(keys)
            ord_counter[k] += 1
            item = {"seq": seq, "k": k, "ord": ord_counter[k]}
            if with_faults and r == poisoned_round and not fault_seen:
                item["poison"] = True
                fault_seen = True
            broker.publish(f"sensor{k}", item)
            published.append(item)
            seq += 1
        # Pump with data, then pump to idle (decommission or fault).
        before_fault = trig.stats["faults"]
        trig.pump()
        if trig.stats["faults"] > before_fault:
            # Everything fetched into the faulted activation and not
            # polled out may legitimately be lost.
            got = {t["seq"] for t in trig.outputs}
            lost_candidates |= {t["seq"] for t in published} - got
        while trig.active is not None:
            trig.pump()

    got = [t["seq"] for t in trig.outputs]
    assert len(got) == len(set(got)), f"seed {seed}: duplicate delivery"
    missing = {t["seq"] for t in published} - set(got)
    if not with_faults:
        assert not missing, f"seed {seed}: lost {missing} without any fault"
        assert trig.stats["activations"] == rounds
        assert trig.stats["activations"] == trig.stats["decommissions"]
        assert trig.stats["fed"] == len(published)
    else:
        assert missing <= lost_candidates, (
            f"seed {seed}: lost tuples {missing - lost_candidates} that were "
            "never fed to a faulted activation"
        )
        assert (
            trig.stats["activations"]
            == trig.stats["decommissions"] + trig.stats["faults"]
        )
    # Per-key order over delivered tuples.
    last = {}
    for t in trig.outputs:
        k, o = t["k"], t["ord"]
        assert o > last.get(k, 0), f"seed {seed}: key {k} order broken"
        last[k] = o


def main():
    for seed in range(4000):
        run_schedule(seed, with_faults=False)
        run_schedule(10_000 + seed, with_faults=True)
    print("trigger_sim: 8000 schedules OK (no-loss, per-key order, counters)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
