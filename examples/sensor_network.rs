//! Sensor-network scenario: many geographically distributed producers,
//! pattern-based consumers, quadtree growth, replication and failover.
//!
//! Exercises: overlay self-organisation (region splits as RPs join),
//! SFC content routing for 2-D profiles, DHT replication surviving an
//! RP crash, and master re-election (Hirschberg–Sinclair).
//!
//! Run: `cargo run --release --example sensor_network -- [--nodes N]`

use rpulsar::ar::message::{Action, ArMessage};
use rpulsar::ar::profile::Profile;
use rpulsar::cli::Args;
use rpulsar::config::DeviceKind;
use rpulsar::coordinator::Cluster;
use rpulsar::util::prng::Prng;

fn main() -> rpulsar::Result<()> {
    rpulsar::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.opt_usize("nodes", 16)?;

    let mut cluster = Cluster::new("sensors", n, DeviceKind::Native)?;
    let origin = cluster.ids()[0];
    println!(
        "overlay: {} RPs self-organised into {} region(s)",
        cluster.len(),
        cluster.quadtree().regions().count()
    );

    // 50 sensors stream readings under distinct 2-D profiles.
    let mut rng = Prng::seeded(7);
    let kinds = ["temp", "humidity", "lidar", "air", "seismic"];
    let mut stored = 0usize;
    for i in 0..50 {
        let kind = kinds[i % kinds.len()];
        let profile = Profile::builder()
            .add_single(&format!("{}{}", rng.ascii_lower(4), i))
            .add_single(kind)
            .build();
        let reading = format!("{:.3}", rng.gen_f64() * 40.0);
        let msg = ArMessage::builder()
            .set_header(profile)
            .set_sender(&format!("sensor-{i}"))
            .set_action(Action::Store)
            .set_data(reading.into_bytes())
            .build()?;
        cluster.store_replicated(origin, &msg, 2)?;
        stored += 1;
    }
    println!("{stored} sensor readings stored with 2× replication");

    // A consumer queries every temperature sensor with one wildcard.
    let hits = cluster.query_wildcard(origin, &Profile::parse("*,temp")?)?;
    println!("wildcard `*,temp` → {} readings", hits.len());
    assert_eq!(hits.len(), 10);

    // Crash an RP; data must survive via replicas.
    let victim = cluster.ids()[n / 2];
    println!("crashing RP {victim} ...");
    cluster.crash(&victim)?;
    let hits_after = cluster.query_wildcard(origin, &Profile::parse("*,temp")?)?;
    println!("after crash: wildcard `*,temp` → {} readings", hits_after.len());

    // Re-elect a master for the crashed RP's region.
    let region = cluster
        .quadtree()
        .regions()
        .find(|r| cluster.quadtree().members_of(*r).map(|m| !m.is_empty()).unwrap_or(false))
        .expect("some region still has members");
    let leader = cluster.elect_master(region)?;
    println!("region {region}: new master elected = {leader}");

    println!(
        "network totals: {} msgs / {} bytes / {:?} simulated",
        cluster.network().messages(),
        cluster.network().bytes(),
        cluster.network().virtual_elapsed()
    );
    cluster.shutdown()?;
    println!("sensor_network OK");
    Ok(())
}
