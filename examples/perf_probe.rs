//! Perf probe: L3 hot paths.
use rpulsar::util::crc32;
fn main() {
    // crc32 throughput (mmq's per-record cost)
    let buf = vec![0xA5u8; 1024];
    let n = 500_000;
    let t = std::time::Instant::now();
    let mut acc = 0u32;
    for _ in 0..n { acc ^= crc32(&buf); }
    let e = t.elapsed().as_secs_f64();
    println!("crc32 1KiB: {:.2}µs/op ({:.2} GB/s) acc={acc}", e/n as f64*1e6, n as f64*1024.0/e/1e9);

    // routing latency (simple 2-D profile, 64-node ring)
    use rpulsar::overlay::node_id::NodeId;
    use rpulsar::overlay::ring::build_converged_tables;
    use rpulsar::routing::router::ContentRouter;
    use rpulsar::ar::profile::Profile;
    let ids: Vec<NodeId> = (0..64).map(|i| NodeId::from_name(&format!("p-{i}"))).collect();
    let tables = build_converged_tables(&ids, 8);
    let router = ContentRouter::new();
    let p = Profile::parse("drone,lidar").unwrap();
    let n = 100_000;
    let t = std::time::Instant::now();
    for i in 0..n {
        std::hint::black_box(router.route(&p, &tables, ids[i % 64]).unwrap());
    }
    println!("route simple 2D @64 nodes: {:.2}µs/op", t.elapsed().as_secs_f64()/n as f64*1e6);

    let complex = Profile::parse("dr*,li*").unwrap();
    let n = 20_000;
    let t = std::time::Instant::now();
    for i in 0..n {
        std::hint::black_box(router.route(&complex, &tables, ids[i % 64]).unwrap());
    }
    println!("route complex 2D: {:.2}µs/op", t.elapsed().as_secs_f64()/n as f64*1e6);

    // LSM put/get native
    let dir = std::env::temp_dir().join("perf-lsm");
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = rpulsar::storage::lsm::LsmStore::open_native(rpulsar::storage::lsm::LsmOptions {
        dir: dir.clone(), memtable_bytes: 64<<20, bloom_bits_per_key: 10, max_tables: 6 }).unwrap();
    let n = 200_000;
    let t = std::time::Instant::now();
    for i in 0..n {
        store.put(format!("key-{i:08}").as_bytes(), &[0u8; 128]).unwrap();
    }
    println!("lsm put 128B: {:.2}µs/op", t.elapsed().as_secs_f64()/n as f64*1e6);
    let t = std::time::Instant::now();
    for i in 0..n {
        std::hint::black_box(store.get(format!("key-{i:08}").as_bytes()).unwrap());
    }
    println!("lsm get (memtable): {:.2}µs/op", t.elapsed().as_secs_f64()/n as f64*1e6);
    let _ = std::fs::remove_dir_all(&dir);

    // PJRT preprocess per tile
    let rt = rpulsar::runtime::PreprocessRuntime::load(std::path::Path::new("artifacts")).unwrap();
    let tile = vec![0.5f32; 256*256];
    rt.preprocess(&tile).unwrap();
    let n = 100;
    let t = std::time::Instant::now();
    for _ in 0..n { std::hint::black_box(rt.preprocess(&tile).unwrap()); }
    println!("pjrt preprocess 256x256: {:.2}ms/tile", t.elapsed().as_secs_f64()/n as f64*1e3);
}
