//! On-demand data-driven topologies (paper §IV-C2 + §IV-D2): a stream
//! of sensor tuples is scored by the rule engine; when the content
//! crosses a threshold, the rule *triggers a stored topology* on demand
//! (`start_function`), which windows and aggregates subsequent tuples —
//! the paper's "dynamic data-driven pipelines over the edge and the
//! cloud". Mid-stream, the running topology is *re-scaled live*
//! (§IV-C2 "scaling up or down"): the keyed spike-filter stage grows
//! from 2 to 4 replicas with zero tuple loss and per-sensor order
//! preserved across the key-range handoff.
//!
//! Run: `cargo run --release --example ondemand_topology`

use rpulsar::ar::message::{Action, ArMessage};
use rpulsar::ar::profile::Profile;
use rpulsar::ar::rendezvous::Reaction;
use rpulsar::config::DeviceKind;
use rpulsar::coordinator::Cluster;
use rpulsar::rules::engine::{Consequence, Rule, RuleEngine, RuleOutcome};
use rpulsar::stream::operator::OperatorKind;
use rpulsar::stream::pipeline::{Pipeline, PipelineStage};
use rpulsar::stream::tuple::Tuple;
use rpulsar::util::prng::Prng;

fn main() -> rpulsar::Result<()> {
    rpulsar::logging::init();
    let mut cluster = Cluster::new("ondemand", 4, DeviceKind::Native)?;
    let origin = cluster.ids()[0];

    // The typed pipeline definition: two spike-filter replicas fed by
    // a SENSOR-keyed shuffle (per-sensor order is preserved into the
    // window stage), and a serial keyed window grouping per SENSOR —
    // the parallel filter interleaves sensor streams
    // nondeterministically, so the window must group by key. Stage
    // factories travel with the definition; misuse (an unkeyed
    // parallel stateful stage, a key mismatch) would be rejected right
    // here at `build`, before anything is stored on the cluster.
    let pipeline = Pipeline::builder("hotspot_aggregator")
        .stage(PipelineStage::new("spike-filter").parallel(2).keyed("SENSOR").operator(|| {
            Box::new(OperatorKind::filter("spike-filter", |t| {
                t.get("READING").unwrap_or(0.0) > 30.0
            }))
        }))
        .stage(PipelineStage::new("window-mean").operator(|| {
            Box::new(OperatorKind::window_by("window-mean", "READING", 5, "SENSOR"))
        }))
        .build()?;

    // Register the pipeline's stage factories on every RP.
    for id in cluster.ids() {
        let node = cluster.node_mut(&id).unwrap();
        for s in pipeline.stages() {
            if let Some(f) = s.factory_ref() {
                node.topologies_mut().register_stage_factory(s.name(), f.clone());
            }
        }
    }

    // Store the on-demand topology under a function profile: the
    // profile carries the pipeline's spec rendering (`Pipeline::parse`
    // round-trips it on the deploying node).
    let spec = pipeline.to_spec();
    let func = Profile::parse("hotspot_aggregator")?;
    let store_fn = ArMessage::builder()
        .set_header(func.clone())
        .set_sender("operator")
        .set_action(Action::StoreFunction)
        .set_topology(&spec)
        .build()?;
    cluster.post_from(origin, &store_fn)?;
    println!("stored on-demand topology `{spec}`");

    // The data-driven rule: trigger when a reading exceeds 35.
    let trigger = ArMessage::builder()
        .set_header(func)
        .set_sender("rule-engine")
        .set_action(Action::StartFunction)
        .build()?;
    let mut rules = RuleEngine::new();
    rules.add(
        Rule::builder()
            .with_name("hotspot")
            .with_condition("IF(READING >= 35)")?
            .with_consequence(Consequence::TriggerTopology(trigger))
            .with_priority(0)
            .build()?,
    );

    // Stream 100 readings; the 1st spike deploys the topology; later
    // spikes are fed into the running instance.
    let mut rng = Prng::seeded(11);
    let mut running_on: Option<rpulsar::overlay::NodeId> = None;
    let key = "hotspot_aggregator".to_string();
    let mut fed = 0u32;
    let mut rescaled = false;
    for seq in 0..100u64 {
        // Load grows mid-mission: scale the filter stage up, live.
        if seq == 60 && !rescaled {
            if let Some(target) = running_on {
                let node = cluster.node_mut(&target).unwrap();
                let report = node.topologies_mut().rescale(&key, "spike-filter", 4)?;
                println!(
                    "seq {seq}: live rescale `spike-filter` {} → {} replicas \
                     ({} key snapshot(s) moved, stream uninterrupted)",
                    report.from, report.to, report.moved_keys
                );
                rescaled = true;
            }
        }
        let reading = 20.0 + rng.gen_f64() * 20.0; // 20..40
        let tuple = Tuple::new(seq, vec![])
            .with("READING", reading)
            .with("SENSOR", (seq % 3) as f64); // partition key for the keyed shuffle
        match rules.evaluate(&tuple.eval_context()) {
            RuleOutcome::Fired { consequence: Consequence::TriggerTopology(msg), .. } => {
                if running_on.is_none() {
                    let results = cluster.post_from(origin, &msg)?;
                    for (target, reactions) in &results {
                        if reactions.iter().any(|r| matches!(r, Reaction::StartTopology { .. })) {
                            println!(
                                "seq {seq}: reading {reading:.1} fired `hotspot` → topology deployed on {target}"
                            );
                            running_on = Some(*target);
                        }
                    }
                }
                if let Some(target) = running_on {
                    let node = cluster.node_mut(&target).unwrap();
                    node.topologies_mut().send(&key, tuple)?;
                    fed += 1;
                }
            }
            _ => {
                // Below threshold — still feed the running window if any.
                if let Some(target) = running_on {
                    let node = cluster.node_mut(&target).unwrap();
                    node.topologies_mut().send(&key, tuple)?;
                    fed += 1;
                }
            }
        }
    }
    println!("fed {fed} tuples into the on-demand topology");

    // Stop the topology and collect its windowed aggregates.
    if let Some(target) = running_on {
        let node = cluster.node_mut(&target).unwrap();
        let out = node.topologies_mut().stop(&key)?;
        println!("topology drained: {} window aggregate(s)", out.len());
        for t in out.iter().take(5) {
            println!(
                "  window: count={:.0} mean={:.2} max={:.2}",
                t.get("COUNT").unwrap_or(0.0),
                t.get("MEAN").unwrap_or(0.0),
                t.get("MAX").unwrap_or(0.0)
            );
        }
        assert!(!out.is_empty(), "spiky stream must produce aggregates");
    }

    cluster.shutdown()?;
    println!("ondemand_topology OK");
    Ok(())
}
