//! End-to-end driver (paper §V-B, Figs. 13–14): the disaster-recovery
//! workflow on a Hurricane-Sandy-shaped synthetic LiDAR trace, with the
//! full three-layer stack — drone capture → mmap collection → **PJRT
//! pre-processing (AOT-compiled Pallas kernel)** → IF-THEN decision →
//! edge store / core forward — compared against the paper's two
//! baseline pipelines.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example disaster_recovery -- [--images N] [--device pi]`

use rpulsar::cli::Args;
use rpulsar::config::DeviceKind;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::workflow::{BaselineKind, DisasterRecoveryPipeline};
use std::path::PathBuf;

fn main() -> rpulsar::Result<()> {
    rpulsar::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let images = args.opt_usize("images", 150)?;
    let device = DeviceKind::parse(&args.opt_or("device", "pi"))?;
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));

    println!("== Disaster-recovery workflow (paper §V-B) ==");
    let trace = LidarTrace::generate(42, images, 16.0);
    println!(
        "trace: {} images, {:.1} MB nominal (paper: 741 images, 3.7 GB)",
        trace.len(),
        trace.total_bytes() as f64 / 1e6
    );

    let pipeline =
        DisasterRecoveryPipeline::new(&artifacts, DeviceProfile::for_kind(device))?;

    let rp = pipeline.run_rpulsar(&trace)?;
    println!(
        "\nR-Pulsar        : total={:?} (per image {:?})  edge={} core={} dropped={}",
        rp.total(),
        rp.per_image(),
        rp.stored_at_edge,
        rp.forwarded_to_core,
        rp.dropped
    );

    let sq = pipeline.run_baseline(&trace, BaselineKind::KafkaEdgentSqlite)?;
    println!(
        "Kafka+Edgent+SQLite : total={:?} (per image {:?})",
        sq.total(),
        sq.per_image()
    );
    let nit = pipeline.run_baseline(&trace, BaselineKind::KafkaEdgentNitrite)?;
    println!(
        "Kafka+Edgent+Nitrite: total={:?} (per image {:?})",
        nit.total(),
        nit.per_image()
    );

    let gain_sq = 100.0 * (1.0 - rp.total().as_secs_f64() / sq.total().as_secs_f64());
    let gain_nit = 100.0 * (1.0 - rp.total().as_secs_f64() / nit.total().as_secs_f64());
    println!("\nresponse-time gain: {gain_sq:.1}% vs SQLite stack, {gain_nit:.1}% vs Nitrite stack");
    println!("paper (Fig. 14): up to 36% gain — see EXPERIMENTS.md");
    Ok(())
}
