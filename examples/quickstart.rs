//! Quickstart: the paper's Listings 1–5 in ten minutes.
//!
//! Boots an in-process R-Pulsar cluster, registers a drone data
//! producer (Listing 1), a consumer interest (Listing 2), stores a
//! processing function (Listing 3), and triggers it with an IF-THEN
//! rule (Listings 4–5).
//!
//! Run: `cargo run --release --example quickstart`

use rpulsar::ar::message::{Action, ArMessage};
use rpulsar::ar::profile::Profile;
use rpulsar::ar::rendezvous::Reaction;
use rpulsar::config::DeviceKind;
use rpulsar::coordinator::Cluster;
use rpulsar::rules::ast::EvalContext;
use rpulsar::rules::engine::{Consequence, Rule, RuleEngine, RuleOutcome};
use rpulsar::stream::pipeline::{Pipeline, PipelineStage};

fn main() -> rpulsar::Result<()> {
    rpulsar::logging::init();

    // An 8-RP edge cluster (geographically placed, quadtree-organised).
    let mut cluster = Cluster::new("quickstart", 8, DeviceKind::Native)?;
    let origin = cluster.ids()[0];
    println!(
        "cluster: {} RPs in {} region(s)",
        cluster.len(),
        cluster.quadtree().regions().count()
    );

    // ---- Listing 1: drone announces LiDAR data (notify_interest) ----
    let producer_profile =
        Profile::builder().add_single("Drone").add_single("LiDAR").build();
    let announce = ArMessage::builder()
        .set_header(producer_profile.clone())
        .set_sender("drone-1")
        .set_action(Action::NotifyInterest)
        .set_latitude(40.0583)
        .set_longitude(-74.4056)
        .build()?;
    cluster.post_from(origin, &announce)?;
    println!("Listing 1: drone registered, waiting for interest");

    // ---- Listing 2: consumer declares interest (notify_data) ----
    let consumer_profile = Profile::builder()
        .add_single("Drone")
        .add_single("Li*")
        .add_single("lat:40*")
        .add_single("long:-74*")
        .build();
    let interest = ArMessage::builder()
        .set_header(Profile::builder().add_single("Drone").add_single("Li*").build())
        .set_sender("analytics-app")
        .set_action(Action::NotifyData)
        .build()?;
    let results = cluster.post_from(origin, &interest)?;
    let producer_notified = results
        .iter()
        .flat_map(|(_, rs)| rs)
        .any(|r| matches!(r, Reaction::ProducerNotified { .. }));
    println!(
        "Listing 2: consumer interest posted (profile `{}`); producer notified: {}",
        consumer_profile.render(),
        producer_notified
    );

    // The notified drone starts streaming: store a data record.
    let store = ArMessage::builder()
        .set_header(producer_profile)
        .set_sender("drone-1")
        .set_action(Action::Store)
        .set_data(vec![7u8; 1024])
        .build()?;
    cluster.post_from(origin, &store)?;
    println!("drone streamed one record into the DHT");

    // ---- Listing 3: store a processing function ----
    // The typed builder is the canonical definition: the stage carries
    // its operator factory and the whole pipeline is validated *here*,
    // before anything is stored or deployed. The function profile
    // stores its spec rendering — `Pipeline::parse` round-trips it.
    let noop_pipeline = Pipeline::builder("post_processing")
        .stage(PipelineStage::new("noop").operator(|| {
            Box::new(rpulsar::stream::operator::OperatorKind::map("noop", |t| t))
        }))
        .build()?;
    let func_profile = Profile::builder().add_single("post_processing_func").build();
    let store_func = ArMessage::builder()
        .set_header(func_profile.clone())
        .set_sender("analytics-app")
        .set_action(Action::StoreFunction)
        .set_topology(&noop_pipeline.to_spec())
        .build()?;
    // Register the pipeline's stage factories on every RP so whichever
    // node the profile routes to can host the deployment.
    for id in cluster.ids() {
        let node = cluster.node_mut(&id).unwrap();
        for s in noop_pipeline.stages() {
            if let Some(f) = s.factory_ref() {
                node.topologies_mut().register_stage_factory(s.name(), f.clone());
            }
        }
    }
    cluster.post_from(origin, &store_func)?;
    println!(
        "Listing 3: function stored as `post_processing_func` (spec `{}`)",
        noop_pipeline.to_spec()
    );

    // ---- Listings 4–5: rule triggers the stored function ----
    let trigger_msg = ArMessage::builder()
        .set_header(func_profile)
        .set_sender("rule-engine")
        .set_action(Action::StartFunction)
        .build()?;
    let mut rules = RuleEngine::new();
    rules.add(
        Rule::builder()
            .with_name("rule1")
            .with_condition("IF(RESULT >= 10)")?
            .with_consequence(Consequence::TriggerTopology(trigger_msg))
            .with_priority(0)
            .build()?,
    );
    let tuple_ctx = EvalContext::new().with("RESULT", 12.0);
    match rules.evaluate(&tuple_ctx) {
        RuleOutcome::Fired { rule, consequence: Consequence::TriggerTopology(msg) } => {
            println!("Listing 4: rule `{rule}` fired → posting start_function");
            let results = cluster.post_from(origin, &msg)?;
            for (target, reactions) in results {
                for r in reactions {
                    if let Reaction::StartTopology { topology, .. } = r {
                        println!("Listing 5: topology `{topology}` started on {target}");
                    }
                }
            }
        }
        other => println!("rule did not fire: {other:?}"),
    }

    // Query what we stored.
    let hits = cluster.query_wildcard(origin, &Profile::parse("drone,li*")?)?;
    println!("wildcard query `drone,li*` → {} record(s)", hits.len());

    println!(
        "simulated network: {} messages, {:?}",
        cluster.network().messages(),
        cluster.network().virtual_elapsed()
    );
    cluster.shutdown()?;
    println!("quickstart OK");
    Ok(())
}
