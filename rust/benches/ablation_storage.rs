//! Ablation: memory-first LSM (R-Pulsar §IV-C3) vs write-through disk
//! storage — quantifies the paper's "keep the most recently used data in
//! main memory" design choice on the Pi model.

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, windowed_throughput};
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, Dir, Medium, Pattern, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;
use rpulsar::workload::random_records;

const RECORDS: usize = 1_000;

fn main() {
    header(
        "Ablation — memory-first LSM vs write-through disk store",
        "motivates §IV-C3: absorb writes in RAM, spill sequentially",
    );
    let mut rng = Prng::seeded(9);
    let records = random_records(&mut rng, RECORDS, 512);

    // Memory-first (R-Pulsar): memtable absorbs, flush amortises.
    let disk = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual);
    let dir = std::env::temp_dir()
        .join("rpulsar-bench")
        .join(format!("ablation-storage-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut lsm = LsmStore::open(
        LsmOptions {
            dir: dir.clone(),
            memtable_bytes: 1 << 20,
            bloom_bits_per_key: 10,
            max_tables: 8,
        },
        disk.clone(),
    )
    .unwrap();
    let lsm_win = windowed_throughput(&disk, RECORDS, 5, |i| {
        let (p, v) = &records[i];
        lsm.put(p.render().as_bytes(), v).unwrap();
    });
    let (lsm_tp, _) = mean_std(&lsm_win);

    // Write-through: every put is a synchronous random disk write.
    let disk = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual);
    let wt_win = windowed_throughput(&disk, RECORDS.min(200), 5, |i| {
        let (p, v) = &records[i % records.len()];
        disk.charge(Medium::Disk, Pattern::Random, Dir::Write, p.render().len() + v.len());
    });
    let (wt_tp, _) = mean_std(&wt_win);

    println!("memory-first LSM : {lsm_tp:>12.0} puts/s (Pi model)");
    println!("write-through    : {wt_tp:>12.0} puts/s (Pi model)");
    println!("advantage        : {:>11.0}x", lsm_tp / wt_tp);
    assert!(lsm_tp > 20.0 * wt_tp, "memory-first must dominate write-through");

    // Read side: recently-written keys come from RAM.
    let disk_reads = lsm.disk().clone();
    disk_reads.reset();
    for (p, _) in records.iter().rev().take(100) {
        lsm.get(p.render().as_bytes()).unwrap();
    }
    let recent = disk_reads.virtual_elapsed();
    println!(
        "\n100 reads of recently-written keys: {:?} total ({:.1}µs each) — memtable-resident",
        recent,
        recent.as_secs_f64() * 1e4
    );
    let _ = std::fs::remove_dir_all(&dir);
}
