//! Fig. 17 (beyond the paper): the serverless claim measured — a
//! *trigger-activated* pipeline (deployed only while matching data
//! flows, scale-to-zero when idle) vs the same pipeline pre-deployed
//! as a standing topology.
//!
//! The Fig-13 analytics chain (`score*P@IMG->decide->stats@IMG`) is
//! bound to the `drone,*` profile on an mmap broker. Arms:
//!
//! - **pre-deployed**: classic standing topology; tuples are fed
//!   directly (the floor for steady-state throughput).
//! - **on-demand**: tuples are *published*; the first matching message
//!   cold-starts the pipeline, the broker cursor feeds it, and an idle
//!   watermark decommissions it back to zero. Reported: cold-start
//!   activation latency, end-to-end throughput, and the scale-to-zero
//!   reclaim time after the stream dries up.
//! - **bursts**: the same stream in idle-separated bursts — one cold
//!   start per burst, zero running replicas between bursts, nothing
//!   lost across the gaps (the cursor holds the backlog).
//!
//! Both arms must produce the *same output multiset* — on-demand
//! activation is an execution-lifecycle choice, not a semantics
//! change. `-- --test` runs a seconds-long smoke (CI gate).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::ar::profile::Profile;
use rpulsar::mmq::pubsub::{Broker, RetirePolicy};
use rpulsar::mmq::queue::QueueOptions;
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::trigger::{TriggerManager, TriggerOptions};
use rpulsar::pipeline::workflow::{
    analytics_spec, register_analytics_stages, run_stream_analytics, trace_tuples,
};
use rpulsar::stream::pipeline::Pipeline;
use rpulsar::stream::tuple::Tuple;
use std::time::{Duration, Instant};

const PARALLELISM: usize = 2;

fn broker(name: &str) -> Broker {
    let dir = std::env::temp_dir()
        .join("rpulsar-fig17")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Broker::new(QueueOptions { dir, segment_bytes: 8 << 20, max_segments: 8, sync_every: 0 })
}

fn eager() -> TriggerOptions {
    TriggerOptions {
        idle: RetirePolicy {
            max_publish_idle: Duration::ZERO,
            max_fetch_idle: Duration::ZERO,
            min_age: Duration::ZERO,
        },
        decode_payloads: true,
    }
}

fn canon(outs: &[Tuple]) -> Vec<String> {
    let mut v: Vec<String> = outs.iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}

fn main() {
    header(
        "Fig. 17 — on-demand (data-driven) pipeline vs pre-deployed topology",
        "extends the serverless computing model to the edge: functions run only while data flows",
    );
    let smoke = smoke_mode();
    let (images, work) = if smoke { (4, 2) } else { (32, 24) };
    let trace = LidarTrace::generate(31, images, 1.0);
    let tuples = trace_tuples(&trace, 512);
    let spec = analytics_spec(PARALLELISM);
    println!("{} tile tuples, score work={work}, spec `{spec}`, smoke={smoke}", tuples.len());

    // ---- Arm 1: pre-deployed standing topology ----
    let pre = run_stream_analytics(&spec, tuples.clone(), work).unwrap();
    println!(
        "\npre-deployed   {:>10.0} t/s   outputs {}",
        pre.tuples_per_sec(),
        pre.outputs.len()
    );

    // ---- Arm 2: on-demand activation over the broker ----
    let mut b = broker("ondemand");
    let mut trig = TriggerManager::in_process();
    register_analytics_stages(trig.deployer_mut(), work);
    let pipeline = Pipeline::parse("ondemand", &spec).unwrap();
    trig.bind(&mut b, pipeline, Profile::parse("drone,*").unwrap(), eager()).unwrap();
    let profile = Profile::parse("drone,lidar").unwrap();

    let started = Instant::now();
    for t in &tuples {
        b.publish(&profile, &t.encode()).unwrap();
    }
    // Pump until the backlog is fed and the idle watermark reclaims.
    trig.pump_until_idle(&mut b, Duration::from_secs(600)).unwrap();
    let elapsed = started.elapsed();
    let stats = trig.stats("ondemand").unwrap();
    let cold = stats.last_cold_start.expect("an activation happened");
    let main_run = trig.take_outputs("ondemand");
    // Measure the reclaim edge in isolation: re-activate with a probe
    // tuple, then time the drive back to zero.
    b.publish(&profile, &tuples[0].encode()).unwrap();
    trig.pump(&mut b).unwrap();
    assert!(trig.is_active("ondemand"));
    let reclaim_started = Instant::now();
    trig.pump_until_idle(&mut b, Duration::from_secs(600)).unwrap();
    let reclaim = reclaim_started.elapsed();
    let _probe_out = trig.take_outputs("ondemand");
    println!(
        "on-demand      {:>10.0} t/s   outputs {}   cold-start {:.2?}   reclaim {:.2?}",
        tuples.len() as f64 / elapsed.as_secs_f64().max(1e-9),
        main_run.len(),
        cold,
        reclaim
    );
    println!(
        "               activations {}  decommissions {}  fed {}",
        stats.activations, stats.decommissions, stats.tuples_fed
    );
    assert_eq!(
        canon(&pre.outputs),
        canon(&main_run),
        "on-demand activation must not change pipeline semantics"
    );
    assert!(
        trig.deployer().running().is_empty(),
        "scale-to-zero must leave no standing topology"
    );

    // ---- Arm 3: idle-separated bursts ----
    let mut b2 = broker("bursts");
    let mut trig2 = TriggerManager::in_process();
    register_analytics_stages(trig2.deployer_mut(), work);
    trig2
        .bind(&mut b2, Pipeline::parse("bursty", &spec).unwrap(), Profile::parse("drone,*").unwrap(), eager())
        .unwrap();
    let bursts = 3usize;
    let per = tuples.len().div_ceil(bursts);
    for chunk in tuples.chunks(per) {
        for t in chunk {
            b2.publish(&profile, &t.encode()).unwrap();
        }
        trig2.pump_until_idle(&mut b2, Duration::from_secs(600)).unwrap();
        assert!(
            trig2.deployer().running().is_empty(),
            "each idle gap must reach zero running replicas"
        );
    }
    let s2 = trig2.stats("bursty").unwrap();
    println!(
        "bursts         {} bursts → {} cold starts, {} decommissions, {} tuples fed",
        tuples.chunks(per).count(),
        s2.activations,
        s2.decommissions,
        s2.tuples_fed
    );
    assert_eq!(s2.activations as usize, tuples.chunks(per).count());
    assert_eq!(s2.activations, s2.decommissions);
    assert_eq!(s2.tuples_fed as usize, tuples.len(), "the cursor must lose nothing across gaps");

    println!("\nfig17 OK");
}
