//! Fig. 17 (beyond the paper): the serverless claim measured — a
//! *trigger-activated* pipeline (deployed only while matching data
//! flows, scale-to-zero when idle) vs the same pipeline pre-deployed
//! as a standing topology.
//!
//! The Fig-13 analytics chain (`score*P@IMG->decide->stats@IMG`) is
//! bound to the `drone,*` profile on an mmap broker. Arms:
//!
//! - **pre-deployed**: classic standing topology; tuples are fed
//!   directly (the floor for steady-state throughput).
//! - **on-demand**: tuples are *published*; the first matching message
//!   cold-starts the pipeline, the broker cursor feeds it, and an idle
//!   watermark decommissions it back to zero. Reported: cold-start
//!   activation latency, end-to-end throughput, and the scale-to-zero
//!   reclaim time after the stream dries up.
//! - **bursts**: the same stream in idle-separated bursts — one cold
//!   start per burst, zero running replicas between bursts, nothing
//!   lost across the gaps (the cursor holds the backlog).
//!
//! Both arms must produce the *same output multiset* — on-demand
//! activation is an execution-lifecycle choice, not a semantics
//! change. `-- --test` runs a seconds-long smoke (CI gate).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::ar::profile::Profile;
use rpulsar::mmq::pubsub::{Broker, RetirePolicy};
use rpulsar::mmq::queue::QueueOptions;
use rpulsar::pipeline::concurrent::TriggerPool;
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::pool::WarmPolicy;
use rpulsar::pipeline::trigger::{
    concurrent_default, AdmissionControl, TriggerManager, TriggerOptions,
};
use rpulsar::pipeline::workflow::{
    analytics_spec, register_analytics_stages, run_stream_analytics, trace_tuples,
};
use rpulsar::stream::deploy::TopologyManager;
use rpulsar::stream::engine::StreamEngine;
use rpulsar::stream::operator::{Operator, OperatorKind};
use rpulsar::stream::pipeline::{Deployer, Pipeline, PipelineStage};
use rpulsar::stream::tuple::Tuple;
use std::time::{Duration, Instant};

const PARALLELISM: usize = 2;

fn broker(name: &str) -> Broker {
    let dir = std::env::temp_dir()
        .join("rpulsar-fig17")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Broker::new(QueueOptions { dir, segment_bytes: 8 << 20, max_segments: 8, sync_every: 0 })
}

fn eager() -> TriggerOptions {
    TriggerOptions {
        idle: RetirePolicy {
            max_publish_idle: Duration::ZERO,
            max_fetch_idle: Duration::ZERO,
            min_age: Duration::ZERO,
        },
        decode_payloads: true,
        tenant: None,
    }
}

fn canon(outs: &[Tuple]) -> Vec<String> {
    let mut v: Vec<String> = outs.iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}

fn main() {
    header(
        "Fig. 17 — on-demand (data-driven) pipeline vs pre-deployed topology",
        "extends the serverless computing model to the edge: functions run only while data flows",
    );
    let smoke = smoke_mode();
    let (images, work) = if smoke { (4, 2) } else { (32, 24) };
    let trace = LidarTrace::generate(31, images, 1.0);
    let tuples = trace_tuples(&trace, 512);
    let spec = analytics_spec(PARALLELISM);
    println!("{} tile tuples, score work={work}, spec `{spec}`, smoke={smoke}", tuples.len());

    // ---- Arm 1: pre-deployed standing topology ----
    let pre = run_stream_analytics(&spec, tuples.clone(), work).unwrap();
    println!(
        "\npre-deployed   {:>10.0} t/s   outputs {}",
        pre.tuples_per_sec(),
        pre.outputs.len()
    );

    // ---- Arm 2: on-demand activation over the broker ----
    let mut b = broker("ondemand");
    let mut trig = TriggerManager::in_process();
    register_analytics_stages(trig.deployer_mut(), work);
    let pipeline = Pipeline::parse("ondemand", &spec).unwrap();
    trig.bind(&mut b, pipeline, Profile::parse("drone,*").unwrap(), eager()).unwrap();
    let profile = Profile::parse("drone,lidar").unwrap();

    let started = Instant::now();
    for t in &tuples {
        b.publish(&profile, &t.encode()).unwrap();
    }
    // Pump until the backlog is fed and the idle watermark reclaims.
    trig.pump_until_idle(&mut b, Duration::from_secs(600)).unwrap();
    let elapsed = started.elapsed();
    let stats = trig.stats("ondemand").unwrap();
    let cold = stats.last_cold_start.expect("an activation happened");
    let main_run = trig.take_outputs("ondemand");
    // Measure the reclaim edge in isolation: re-activate with a probe
    // tuple, then time the drive back to zero.
    b.publish(&profile, &tuples[0].encode()).unwrap();
    trig.pump(&mut b).unwrap();
    assert!(trig.is_active("ondemand"));
    let reclaim_started = Instant::now();
    trig.pump_until_idle(&mut b, Duration::from_secs(600)).unwrap();
    let reclaim = reclaim_started.elapsed();
    let _probe_out = trig.take_outputs("ondemand");
    println!(
        "on-demand      {:>10.0} t/s   outputs {}   cold-start {:.2?}   reclaim {:.2?}",
        tuples.len() as f64 / elapsed.as_secs_f64().max(1e-9),
        main_run.len(),
        cold,
        reclaim
    );
    println!(
        "               activations {}  decommissions {}  fed {}",
        stats.activations, stats.decommissions, stats.tuples_fed
    );
    assert_eq!(
        canon(&pre.outputs),
        canon(&main_run),
        "on-demand activation must not change pipeline semantics"
    );
    assert!(
        trig.deployer().running().is_empty(),
        "scale-to-zero must leave no standing topology"
    );

    // ---- Arm 3: idle-separated bursts ----
    let mut b2 = broker("bursts");
    let mut trig2 = TriggerManager::in_process();
    register_analytics_stages(trig2.deployer_mut(), work);
    trig2
        .bind(&mut b2, Pipeline::parse("bursty", &spec).unwrap(), Profile::parse("drone,*").unwrap(), eager())
        .unwrap();
    let bursts = 3usize;
    let per = tuples.len().div_ceil(bursts);
    for chunk in tuples.chunks(per) {
        for t in chunk {
            b2.publish(&profile, &t.encode()).unwrap();
        }
        trig2.pump_until_idle(&mut b2, Duration::from_secs(600)).unwrap();
        assert!(
            trig2.deployer().running().is_empty(),
            "each idle gap must reach zero running replicas"
        );
    }
    let s2 = trig2.stats("bursty").unwrap();
    println!(
        "bursts         {} bursts → {} cold starts, {} decommissions, {} tuples fed",
        tuples.chunks(per).count(),
        s2.activations,
        s2.decommissions,
        s2.tuples_fed
    );
    assert_eq!(s2.activations as usize, tuples.chunks(per).count());
    assert_eq!(s2.activations, s2.decommissions);
    assert_eq!(s2.tuples_fed as usize, tuples.len(), "the cursor must lose nothing across gaps");

    // ---- Arm 4: serverless at scale (PR 9 burst arm) ----
    scale_arm(smoke);

    println!("\nfig17 OK");
}

// ---- Scale arm: thousands of bindings, concurrent plane, warm pools ----

/// Tiny-segment broker for the burst arm: thousands of topics at the
/// default 8 MiB segment size would map tens of GiB; 4 KiB segments
/// keep the whole topic fleet resident in a few hundred MiB.
fn scale_broker(name: &str) -> Broker {
    let dir = std::env::temp_dir()
        .join("rpulsar-fig17")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Broker::new(QueueOptions { dir, segment_bytes: 1 << 12, max_segments: 2, sync_every: 0 })
}

/// Stateless `X += 1` relay — cheap enough that the measured cost is
/// the activation machinery, not the operator.
fn inc_pipeline(name: &str) -> Pipeline {
    Pipeline::builder(name)
        .stage(PipelineStage::new("inc").operator(|| {
            Box::new(OperatorKind::map("inc", |mut t| {
                let v = t.get("X").unwrap_or(0.0);
                t.set("X", v + 1.0);
                t
            })) as Box<dyn Operator>
        }))
        .build()
        .unwrap()
}

fn binding(i: usize) -> String {
    format!("fn{i}")
}

/// One burst: one tuple per binding, X encoding (binding, round) so
/// the union output multiset discriminates both.
fn publish_burst(b: &mut Broker, bindings: usize, round: usize) {
    for i in 0..bindings {
        let x = (i * 100 + round) as f64;
        b.publish(
            &Profile::parse(&format!("t{i},d")).unwrap(),
            &Tuple::new((i * 100 + round) as u64, vec![]).with("X", x).encode(),
        )
        .unwrap();
    }
}

/// The input multiset a run of `rounds` bursts must produce (inc'd).
fn expected(bindings: usize, rounds: usize) -> Vec<String> {
    let mut tuples = Vec::new();
    for round in 0..rounds {
        for i in 0..bindings {
            let x = (i * 100 + round) as f64;
            tuples.push(Tuple::new((i * 100 + round) as u64, vec![]).with("X", x + 1.0));
        }
    }
    canon(&tuples)
}

fn scale_arm(smoke: bool) {
    let bindings = if smoke { 64 } else { 10_000 };
    let rounds = 3usize;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers = cores.min(8);
    let concurrent = concurrent_default();
    println!(
        "\n--- scale: {bindings} bindings, {rounds} warm rounds, {cores} cores, \
         {workers} workers, concurrent={concurrent} ---"
    );

    // Reference: one pre-deployed standing pipeline fed the union of
    // the first burst — the semantics every trigger-plane run below
    // must reproduce.
    let want_one = expected(bindings, 1);
    let mut topo = TopologyManager::new(StreamEngine::new());
    let href = Deployer::deploy(&mut topo, &inc_pipeline("ref")).unwrap();
    let fed: Vec<Tuple> = (0..bindings)
        .map(|i| Tuple::new((i * 100) as u64, vec![]).with("X", (i * 100) as f64))
        .collect();
    Deployer::send_batch(&mut topo, &href, fed).unwrap();
    let ref_out = Deployer::stop(&mut topo, &href).unwrap();
    assert_eq!(canon(&ref_out), want_one, "pre-deployed reference disagrees with the model");

    // (a) Sequential trigger plane, cold every burst.
    let mut bs = scale_broker("scale-seq");
    let mut seq = TriggerManager::in_process();
    seq.set_admission(AdmissionControl::bounded(256));
    for i in 0..bindings {
        seq.bind(
            &mut bs,
            inc_pipeline(&binding(i)),
            Profile::parse(&format!("t{i},*")).unwrap(),
            eager(),
        )
        .unwrap();
    }
    publish_burst(&mut bs, bindings, 0);
    let t0 = Instant::now();
    seq.pump_until_idle(&mut bs, Duration::from_secs(1800)).unwrap();
    let seq_elapsed = t0.elapsed();
    let seq_rate = bindings as f64 / seq_elapsed.as_secs_f64().max(1e-9);
    let seq_cold = seq.metrics().histogram("trigger.cold_start_us").snapshot();
    let mut seq_out = Vec::new();
    for i in 0..bindings {
        seq_out.extend(seq.take_outputs(&binding(i)));
    }
    assert_eq!(canon(&seq_out), want_one, "sequential plane lost or mutated tuples");
    println!(
        "sequential     {seq_rate:>10.0} act/s   cold-start p50/p95/p99 \
         {}/{}/{} µs   admitted {}",
        seq_cold.p50,
        seq_cold.p95,
        seq_cold.p99,
        seq.metrics().counter("trigger.admitted").get()
    );

    // (b) Concurrent pool, same burst. Skipped when the A/B toggle
    // pins the sequential plane (RPULSAR_TRIGGERPLANE=sync).
    let mut conc_rate = None;
    if concurrent {
        let mut bc = scale_broker("scale-conc");
        let mut pool = TriggerPool::in_process(workers);
        pool.set_admission(AdmissionControl::bounded(256));
        for i in 0..bindings {
            pool.bind(
                &mut bc,
                inc_pipeline(&binding(i)),
                Profile::parse(&format!("t{i},*")).unwrap(),
                eager(),
            )
            .unwrap();
        }
        publish_burst(&mut bc, bindings, 0);
        let t0 = Instant::now();
        pool.pump_until_idle(&mut bc, Duration::from_secs(1800)).unwrap();
        let elapsed = t0.elapsed();
        let rate = bindings as f64 / elapsed.as_secs_f64().max(1e-9);
        let mut out = Vec::new();
        for i in 0..bindings {
            out.extend(pool.take_outputs(&binding(i)));
        }
        assert_eq!(canon(&out), want_one, "concurrent plane lost or mutated tuples");
        let ratio = rate / seq_rate.max(1e-9);
        println!("concurrent     {rate:>10.0} act/s   {workers} workers   {ratio:.2}x sequential");
        // The headline perf claim needs real cores behind the workers;
        // smoke sizes and starved runners only print the ratio.
        if !smoke && cores >= 4 {
            assert!(
                ratio >= 2.0,
                "concurrent plane must beat sequential ≥2x on {cores} cores, got {ratio:.2}x"
            );
        }
        conc_rate = Some(rate);
    }

    // (c) Warm pools over repeated bursts: first round cold, the rest
    // must hit the pool; (d) then memory pressure reclaims it.
    let (warm_snap, cold_snap, evictions) = if concurrent {
        let mut bw = scale_broker("scale-warm");
        let mut pool = TriggerPool::in_process(workers);
        pool.set_warm_policy(WarmPolicy::retain(bindings));
        for i in 0..bindings {
            pool.bind(
                &mut bw,
                inc_pipeline(&binding(i)),
                Profile::parse(&format!("t{i},*")).unwrap(),
                eager(),
            )
            .unwrap();
        }
        for round in 0..rounds {
            publish_burst(&mut bw, bindings, round);
            pool.pump_until_idle(&mut bw, Duration::from_secs(1800)).unwrap();
        }
        let cold = pool.metrics().histogram("trigger.cold_start_us").snapshot();
        let warm = pool.metrics().histogram("trigger.warm_start_us").snapshot();
        assert_eq!(cold.count as usize, bindings, "exactly one cold start per binding");
        assert_eq!(
            warm.count as usize,
            bindings * (rounds - 1),
            "every re-activation must be a warm start"
        );
        assert!(
            warm.p99 as f64 <= 0.5 * cold.p99 as f64,
            "warm p99 ({} µs) must be ≤ half of cold p99 ({} µs)",
            warm.p99,
            cold.p99
        );
        // (d) Reclaim under memory pressure: coldest-first eviction
        // down to a handful of residents.
        let resident = pool.warm_resident();
        let keep = workers; // ~1 per worker
        let evicted = pool.reclaim_warm(keep).unwrap();
        assert!(resident > keep, "the fleet must actually have been parked warm");
        assert!(evicted > 0 && pool.warm_resident() <= keep.max(1));
        let evictions = pool.metrics().counter("trigger.pool_evictions").get();
        assert!(evictions as usize >= evicted);
        let resident_after = pool.warm_resident();
        pool.decommission_all().unwrap();
        let mut out = Vec::new();
        for i in 0..bindings {
            out.extend(pool.take_outputs(&binding(i)));
        }
        assert_eq!(
            canon(&out),
            expected(bindings, rounds),
            "warm pooling + reclaim must not change outputs"
        );
        println!(
            "warm pool      cold p99 {} µs → warm p99 {} µs   {} warm hits   \
             reclaim evicted {evicted} (resident {resident} → {resident_after})",
            cold.p99,
            warm.p99,
            pool.metrics().counter("trigger.warm_hits").get(),
        );
        (Some(warm), Some(cold), evictions)
    } else {
        println!("warm pool      skipped (RPULSAR_TRIGGERPLANE=sync)");
        (None, None, 0)
    };

    // Trajectory file for later PRs.
    let json = format!(
        "{{\n  \"figure\": \"fig17-scale\",\n  \"smoke\": {smoke},\n  \
         \"bindings\": {bindings},\n  \"cores\": {cores},\n  \"workers\": {workers},\n  \
         \"sequential_activations_per_sec\": {seq_rate:.1},\n  \
         \"sequential_cold_p99_us\": {},\n  \
         \"concurrent_activations_per_sec\": {},\n  \
         \"warm_p99_us\": {},\n  \"cold_p99_us\": {},\n  \"pool_evictions\": {evictions}\n}}\n",
        seq_cold.p99,
        conc_rate.map_or("null".to_string(), |r| format!("{r:.1}")),
        warm_snap.map_or("null".to_string(), |s| s.p99.to_string()),
        cold_snap.map_or("null".to_string(), |s| s.p99.to_string()),
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serverless.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("bench trajectory written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
