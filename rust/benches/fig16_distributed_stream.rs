//! Fig. 16 (beyond the paper): distributed stream topologies — the
//! Fig-13 analytics chain run on one simulated node vs *split across
//! the edge and the cloud* (paper's claim: pipelines run "across the
//! cloud and edge in a uniform manner").
//!
//! Three placements of `score*P@IMG->decide->stats@IMG` on a two-node
//! SimNetwork cluster (Raspberry Pi source + `cloud_small` core):
//!
//! - **single-node**: every stage on the Pi node — no cross-node hop,
//!   zero network bytes.
//! - **split-sync**: `score`/`decide` stay source-adjacent on the Pi,
//!   the `stats` aggregation runs on the cloud node, and the inter-node
//!   hop is pumped *synchronously* by the feeding thread (the PR-4
//!   net plane, kept as the ablation baseline).
//! - **split-async**: the same placement with the background shipper —
//!   hop encode/ship/deliver overlaps operator compute, and pooled
//!   `WireBatch` buffers make the codec encode each batch exactly once.
//!
//! Reported per placement: wall-clock throughput, network bytes /
//! messages, the device-accurate virtual network time the hops cost,
//! and the hop-path codec counters (`net.hop.{encodes,buffer_reuses,
//! bytes}`). All placements must reproduce the single-process
//! executor's output multiset exactly (the zero-loss cross-node drain
//! contract, property-tested in `rust/tests/netplane.rs`), and the
//! encode-once contract is asserted as `net.hop.encodes ==` shipped
//! batches in *both* pump modes.
//!
//! A second, saturated-link arm runs the chain at parallelism 16 with
//! 4 KiB wire payloads and near-zero operator work, so the cross-node
//! hop dominates: here the async shipper must beat the synchronous
//! pump by ≥1.5× (asserted in full mode; printed in smoke).
//!
//! The run also writes `BENCH_netplane.json` at the repo root so later
//! PRs can track the net-plane perf curve.
//!
//! `-- --test` runs a seconds-long smoke with tiny sizes (CI gate).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::stream::dist::netplane_async_default;
use rpulsar::pipeline::workflow::{
    analytics_spec, run_distributed_analytics_opts, run_stream_analytics, trace_tuples,
    DistStreamReport,
};
use std::time::Duration;

const PARALLELISM: usize = 4;
const SATURATED_PARALLELISM: usize = 16;

fn main() {
    header(
        "Fig. 16 — distributed stream topologies (single-node vs edge→cloud split placement)",
        "stream pipelines run across the cloud and the edge in a uniform manner",
    );
    let smoke = smoke_mode();
    let (images, work) = if smoke { (4, 2) } else { (48, 48) };
    let trace = LidarTrace::generate(23, images, 1.0);
    let tuples = trace_tuples(&trace, 512);
    println!(
        "{} tile tuples, score work={work}, parallelism={PARALLELISM}, smoke={smoke}",
        tuples.len()
    );

    // Ground truth: the plain single-process executor.
    let local = run_stream_analytics(&analytics_spec(PARALLELISM), tuples.clone(), work).unwrap();

    let spec = analytics_spec(PARALLELISM);
    let single =
        run_distributed_analytics_opts(&spec, tuples.clone(), work, false, false).unwrap();
    let split_sync =
        run_distributed_analytics_opts(&spec, tuples.clone(), work, true, true).unwrap();
    let split_async = run_distributed_analytics_opts(&spec, tuples, work, true, false).unwrap();

    println!(
        "\n{:<14} {:>10} {:>12} {:>10} {:>10} {:>9} {:>8} {:>12}  placement",
        "placement", "t/s", "net bytes", "net msgs", "net time", "encodes", "reuses", "outputs"
    );
    row("single-node", &single);
    row("split-sync", &split_sync);
    row("split-async", &split_async);

    // Output equivalence: every placement and pump mode vs the local
    // executor (zero-loss, order-per-key, decode≡encode).
    let want = canon_local(&local.outputs);
    assert_eq!(want, canon_local(&single.outputs), "single-node placement must match local");
    assert_eq!(want, canon_local(&split_sync.outputs), "split(sync pump) must match local");
    assert_eq!(want, canon_local(&split_async.outputs), "split(async shipper) must match local");

    // Placement shape and network accounting.
    for split in [&split_sync, &split_async] {
        assert!(
            split.placement.contains("cloud:[stats"),
            "the aggregation stage must land on the cloud node: {}",
            split.placement
        );
        assert!(split.net_bytes > 0, "split placement must ship its hop batches");
        assert!(split.net_messages > 0);
        assert!(split.net_virtual > Duration::ZERO, "hops must cost virtual network time");
        // Encode-once contract: the codec touches each shipped batch
        // exactly once, in both pump modes (no re-encode on
        // backpressure), and every encoded byte went over the wire.
        assert_eq!(
            split.hop_encodes, split.net_messages,
            "one encode per shipped batch (placement {})",
            split.placement
        );
        assert_eq!(split.hop_bytes, split.net_bytes, "encoded bytes must equal shipped bytes");
    }
    assert_eq!(single.net_bytes, 0, "single-node placement must ship nothing");
    assert_eq!(single.net_messages, 0);
    assert_eq!(single.hop_encodes, 0, "no boundary, no codec work");
    println!(
        "\nsplit ships {} bytes in {} batches costing {:.2?} of Pi-uplink time",
        split_async.net_bytes, split_async.net_messages, split_async.net_virtual
    );

    // Saturated-link arm: parallelism 16, 4 KiB payload slices, near-
    // zero operator work — the hop path dominates, so overlapping it
    // with the feed (async shipper) vs serializing it on the feeding
    // thread (sync pump) is the whole difference.
    let (sat_images, sat_work) = if smoke { (6, 1) } else { (96, 4) };
    let sat_trace = LidarTrace::generate(7, sat_images, 1.0);
    let sat_tuples = trace_tuples(&sat_trace, 4096);
    let sat_spec = analytics_spec(SATURATED_PARALLELISM);
    let reps = if smoke { 1 } else { 3 };
    println!(
        "\nsaturated arm: {} tuples of ≤4KiB, work={sat_work}, parallelism={SATURATED_PARALLELISM}",
        sat_tuples.len()
    );
    let sat_sync = best_of(reps, || {
        run_distributed_analytics_opts(&sat_spec, sat_tuples.clone(), sat_work, true, true).unwrap()
    });
    let sat_async = best_of(reps, || {
        run_distributed_analytics_opts(&sat_spec, sat_tuples.clone(), sat_work, true, false)
            .unwrap()
    });
    row("sat-sync", &sat_sync);
    row("sat-async", &sat_async);
    assert_eq!(
        canon_local(&sat_sync.outputs),
        canon_local(&sat_async.outputs),
        "saturated arm: async shipper must reproduce the sync pump's outputs"
    );
    assert_eq!(sat_sync.hop_encodes, sat_sync.net_messages);
    assert_eq!(sat_async.hop_encodes, sat_async.net_messages);
    let ratio = sat_async.tuples_per_sec() / sat_sync.tuples_per_sec().max(1e-9);
    println!("saturated async/sync throughput ratio: {ratio:.2}×");
    // The floor only means something when the "async" arm actually got
    // shippers — `RPULSAR_NETPLANE=sync` (the CI sync-mode smoke) turns
    // every arm into the legacy pump.
    if !smoke && netplane_async_default() {
        assert!(
            ratio >= 1.5,
            "async shipper must beat the synchronous pump ≥1.5× on a saturated link, got {ratio:.2}×"
        );
    }

    write_bench_json(
        smoke,
        &[
            ("single-node", &single),
            ("split-sync", &split_sync),
            ("split-async", &split_async),
            ("sat-sync", &sat_sync),
            ("sat-async", &sat_async),
        ],
        ratio,
    );
    println!("\nfig16 OK");
}

/// Keep the best-throughput run of `n` (wall-clock benches on shared
/// CI hosts are noisy; peak is the stable statistic).
fn best_of(n: usize, run: impl Fn() -> DistStreamReport) -> DistStreamReport {
    let mut best = run();
    for _ in 1..n {
        let r = run();
        if r.tuples_per_sec() > best.tuples_per_sec() {
            best = r;
        }
    }
    best
}

fn row(label: &str, r: &DistStreamReport) {
    println!(
        "{label:<14} {:>10.0} {:>12} {:>10} {:>9.2?} {:>9} {:>8} {:>12}  {}",
        r.tuples_per_sec(),
        r.net_bytes,
        r.net_messages,
        r.net_virtual,
        r.hop_encodes,
        r.hop_buffer_reuses,
        r.outputs.len(),
        r.placement
    );
}

fn canon_local(outs: &[rpulsar::stream::tuple::Tuple]) -> Vec<String> {
    let mut v: Vec<String> = outs.iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}

/// Bench-trajectory record for later PRs: one JSON object per arm plus
/// the saturated async/sync ratio, written at the repo root.
fn write_bench_json(smoke: bool, arms: &[(&str, &DistStreamReport)], ratio: f64) {
    let rows: Vec<String> = arms
        .iter()
        .map(|(name, r)| {
            format!(
                "    {{\"arm\": \"{name}\", \"tuples_per_sec\": {:.1}, \"net_bytes\": {}, \
                 \"net_messages\": {}, \"hop_encodes\": {}, \"hop_buffer_reuses\": {}, \
                 \"hop_bytes\": {}, \"outputs\": {}}}",
                r.tuples_per_sec(),
                r.net_bytes,
                r.net_messages,
                r.hop_encodes,
                r.hop_buffer_reuses,
                r.hop_bytes,
                r.outputs.len()
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"fig16_netplane\",\n  \"smoke\": {smoke},\n  \"arms\": [\n{}\n  ],\n  \
         \"saturated_async_over_sync\": {ratio:.3}\n}}\n",
        rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_netplane.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("bench trajectory written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
