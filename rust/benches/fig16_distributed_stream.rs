//! Fig. 16 (beyond the paper): distributed stream topologies — the
//! Fig-13 analytics chain run on one simulated node vs *split across
//! the edge and the cloud* (paper's claim: pipelines run "across the
//! cloud and edge in a uniform manner").
//!
//! Two placements of `score*P@IMG->decide->stats@IMG` on a two-node
//! SimNetwork cluster (Raspberry Pi source + `cloud_small` core):
//!
//! - **single-node**: every stage on the Pi node — no cross-node hop,
//!   zero network bytes.
//! - **split**: `score`/`decide` stay source-adjacent on the Pi, the
//!   `stats` aggregation runs on the cloud node; the inter-node hop
//!   ships `Vec<Tuple>` batches as `NetMessage::StreamBatch` frames,
//!   each charged to the SimNetwork at the Pi's uplink profile.
//!
//! Reported per placement: wall-clock throughput, network bytes /
//! messages, and the device-accurate virtual network time the hops
//! cost. Both placements must reproduce the single-process executor's
//! output multiset exactly (the zero-loss cross-node drain contract,
//! property-tested in `rust/tests/cluster.rs`).
//!
//! `-- --test` runs a seconds-long smoke with tiny sizes (CI gate).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::workflow::{
    analytics_spec, run_distributed_analytics, run_stream_analytics, trace_tuples,
    DistStreamReport,
};
use std::time::Duration;

const PARALLELISM: usize = 4;

fn main() {
    header(
        "Fig. 16 — distributed stream topologies (single-node vs edge→cloud split placement)",
        "stream pipelines run across the cloud and the edge in a uniform manner",
    );
    let smoke = smoke_mode();
    let (images, work) = if smoke { (4, 2) } else { (48, 48) };
    let trace = LidarTrace::generate(23, images, 1.0);
    let tuples = trace_tuples(&trace, 512);
    println!(
        "{} tile tuples, score work={work}, parallelism={PARALLELISM}, smoke={smoke}",
        tuples.len()
    );

    // Ground truth: the plain single-process executor.
    let local = run_stream_analytics(&analytics_spec(PARALLELISM), tuples.clone(), work).unwrap();

    let single =
        run_distributed_analytics(&analytics_spec(PARALLELISM), tuples.clone(), work, false)
            .unwrap();
    let split =
        run_distributed_analytics(&analytics_spec(PARALLELISM), tuples, work, true).unwrap();

    println!(
        "\n{:<14} {:>10} {:>12} {:>10} {:>10} {:>12}  placement",
        "placement", "t/s", "net bytes", "net msgs", "net time", "outputs"
    );
    row("single-node", &single);
    row("split", &split);

    // Output equivalence: both placements, and vs the local executor.
    let want = canon_local(&local.outputs);
    assert_eq!(want, canon_local(&single.outputs), "single-node placement must match local");
    assert_eq!(want, canon_local(&split.outputs), "split placement must match local");

    // Placement shape and network accounting.
    assert!(
        split.placement.contains("cloud:[stats"),
        "the aggregation stage must land on the cloud node: {}",
        split.placement
    );
    assert_eq!(single.net_bytes, 0, "single-node placement must ship nothing");
    assert_eq!(single.net_messages, 0);
    assert!(split.net_bytes > 0, "split placement must ship its hop batches");
    assert!(split.net_messages > 0);
    assert!(split.net_virtual > Duration::ZERO, "hops must cost virtual network time");
    println!(
        "\nsplit ships {} bytes in {} batches costing {:.2?} of Pi-uplink time",
        split.net_bytes, split.net_messages, split.net_virtual
    );
    println!("\nfig16 OK");
}

fn row(label: &str, r: &DistStreamReport) {
    println!(
        "{label:<14} {:>10.0} {:>12} {:>10} {:>9.2?} {:>12}  {}",
        r.tuples_per_sec(),
        r.net_bytes,
        r.net_messages,
        r.net_virtual,
        r.outputs.len(),
        r.placement
    );
}

fn canon_local(outs: &[rpulsar::stream::tuple::Tuple]) -> Vec<String> {
    let mut v: Vec<String> = outs.iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}
