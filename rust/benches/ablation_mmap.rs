//! Ablation: the memory-mapped queue vs a write()+fsync queue — the
//! design choice of paper §IV-C1 ("memory-mapped instead of heavily
//! relying on the filesystem"). Reports both the *device-model*
//! throughput (Pi) and the *real wall-clock* mmap append rate on this
//! host (the L3 hot-path number tracked in EXPERIMENTS.md §Perf).

#[path = "common/mod.rs"]
mod common;

use common::{fmt_size, header, mean_std, windowed_throughput};
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, Dir, Medium, Pattern, ThrottledDisk};
use rpulsar::mmq::queue::{MemoryMappedQueue, QueueOptions};
use rpulsar::util::timeutil::fmt_rate;

const MESSAGES: usize = 5_000;

fn main() {
    header(
        "Ablation — mmap queue vs write()+fsync queue (Pi model)",
        "motivates §IV-C1: sequential RAM beats per-message disk persistence",
    );
    println!("{:<10} {:>20} {:>20} {:>8}", "size", "mmap (msg/s)", "write+fsync (msg/s)", "ratio");
    for &size in &[64usize, 1024, 16 * 1024] {
        let disk = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual);
        let mmap_win = windowed_throughput(&disk, MESSAGES, 5, |_| {
            disk.charge(Medium::Ram, Pattern::Sequential, Dir::Write, size + 8);
        });
        let (mmap_tp, _) = mean_std(&mmap_win);

        let disk = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual);
        let fsync_win = windowed_throughput(&disk, MESSAGES.min(500), 5, |_| {
            disk.charge(Medium::Disk, Pattern::Sequential, Dir::Write, size + 8);
            disk.charge_fsync();
        });
        let (fsync_tp, _) = mean_std(&fsync_win);

        println!(
            "{:<10} {:>20.0} {:>20.0} {:>7.0}x",
            fmt_size(size),
            mmap_tp,
            fsync_tp,
            mmap_tp / fsync_tp
        );
        assert!(mmap_tp > 10.0 * fsync_tp);
    }

    // Real wall-clock: actual mmap queue on this host.
    println!("\nreal mmap queue on this host (wall clock):");
    for &size in &[64usize, 1024] {
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("ablation-mmap-{size}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut q = MemoryMappedQueue::open(QueueOptions {
            dir: dir.clone(),
            segment_bytes: 64 << 20,
            max_segments: 4,
            sync_every: 0,
        })
        .unwrap();
        let payload = vec![0xA5u8; size];
        let n = 200_000usize;
        let start = std::time::Instant::now();
        for _ in 0..n {
            q.append(&payload).unwrap();
        }
        let elapsed = start.elapsed().as_secs_f64();
        println!(
            "  {:<8} append: {} ({:.2}µs/msg)",
            fmt_size(size),
            fmt_rate(n as f64 / elapsed, "msg"),
            elapsed / n as f64 * 1e6
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
