//! Fig. 4: single-producer messaging throughput on the Raspberry Pi —
//! R-Pulsar (memory-mapped queue) vs Kafka-like vs Mosquitto-like, at
//! the paper's four message sizes, with throughput variability (σ).
//!
//! Paper result: R-Pulsar up to 3× Kafka and up to 7× Mosquitto, with
//! Kafka exhibiting high variance ("overwhelming the file system").

#[path = "common/mod.rs"]
mod common;

use common::{fmt_size, header, mean_std, messaging_run, RPulsarBroker};
use rpulsar::baselines::kafka_like::KafkaLikeBroker;
use rpulsar::baselines::mosquitto_like::MosquittoLikeBroker;
use rpulsar::baselines::MessageBroker;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::workload::message_sizes;

const MESSAGES: usize = 2_000;
const WINDOWS: usize = 10;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

fn main() {
    header(
        "Fig. 4 — single-producer throughput on Raspberry Pi",
        "R-Pulsar ≈3× Kafka, ≈7× Mosquitto; Kafka high variance",
    );
    println!(
        "{:<10} {:>22} {:>22} {:>22} {:>8} {:>8}",
        "size", "r-pulsar (msg/s)", "kafka-like (msg/s)", "mosquitto-like", "vs-kafka", "vs-mosq"
    );
    for size in message_sizes() {
        let disk = pi_disk();
        let mut rp = RPulsarBroker::new(&format!("fig4-{size}"), disk.clone());
        let rp_win = messaging_run(&mut rp, &disk, size, MESSAGES, WINDOWS);
        let (rp_mean, rp_std) = mean_std(&rp_win);

        let disk = pi_disk();
        let mut kafka = KafkaLikeBroker::with_defaults(disk.clone());
        let kafka_win = messaging_run(&mut kafka, &disk, size, MESSAGES, WINDOWS);
        let (k_mean, k_std) = mean_std(&kafka_win);

        let disk = pi_disk();
        let mut mosq = MosquittoLikeBroker::with_defaults(disk.clone());
        let mosq_win = messaging_run(&mut mosq, &disk, size, MESSAGES, WINDOWS);
        let (m_mean, m_std) = mean_std(&mosq_win);

        println!(
            "{:<10} {:>13.0} ±{:>6.0} {:>13.0} ±{:>6.0} {:>13.0} ±{:>6.0} {:>7.1}x {:>7.1}x",
            fmt_size(size),
            rp_mean,
            rp_std,
            k_mean,
            k_std,
            m_mean,
            m_std,
            rp_mean / k_mean,
            rp_mean / m_mean
        );
        // Sanity: the paper's ordering must hold (Kafka-vs-Mosquitto at
        // the IoT-typical small sizes the paper emphasises; at 64 KiB
        // both are disk-bound and converge).
        assert!(rp_mean > k_mean, "R-Pulsar must beat Kafka-like at {size}B");
        if size <= 1024 {
            assert!(k_mean > m_mean, "Kafka-like must beat Mosquitto-like at {size}B");
        }
        let _ = kafka.consume("bench", 1); // silence unused-path warnings
        let _ = mosq.consume("bench", 1);
        let _ = rp.name();
    }
}
