//! Fig. 4: single-producer messaging throughput on the Raspberry Pi —
//! R-Pulsar (memory-mapped queue) vs Kafka-like vs Mosquitto-like, at
//! the paper's four message sizes, with throughput variability (σ).
//!
//! Paper result: R-Pulsar up to 3× Kafka and up to 7× Mosquitto, with
//! Kafka exhibiting high variance ("overwhelming the file system").

#[path = "common/mod.rs"]
mod common;

use common::{fmt_size, header, mean_std, messaging_run, smoke_mode, RPulsarBroker};
use rpulsar::ar::matching;
use rpulsar::ar::profile::Profile;
use rpulsar::baselines::kafka_like::KafkaLikeBroker;
use rpulsar::baselines::mosquitto_like::MosquittoLikeBroker;
use rpulsar::baselines::MessageBroker;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::mmq::pubsub::Broker;
use rpulsar::mmq::queue::QueueOptions;
use rpulsar::workload::message_sizes;
use std::time::Instant;

const MESSAGES: usize = 2_000;
const WINDOWS: usize = 10;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Fig. 4 — single-producer throughput on Raspberry Pi",
        "R-Pulsar ≈3× Kafka, ≈7× Mosquitto; Kafka high variance",
    );
    println!(
        "{:<10} {:>22} {:>22} {:>22} {:>8} {:>8}",
        "size", "r-pulsar (msg/s)", "kafka-like (msg/s)", "mosquitto-like", "vs-kafka", "vs-mosq"
    );
    let messages = if smoke { 100 } else { MESSAGES };
    for size in message_sizes() {
        let disk = pi_disk();
        let mut rp = RPulsarBroker::new(&format!("fig4-{size}"), disk.clone());
        let rp_win = messaging_run(&mut rp, &disk, size, messages, WINDOWS);
        let (rp_mean, rp_std) = mean_std(&rp_win);

        let disk = pi_disk();
        let mut kafka = KafkaLikeBroker::with_defaults(disk.clone());
        let kafka_win = messaging_run(&mut kafka, &disk, size, messages, WINDOWS);
        let (k_mean, k_std) = mean_std(&kafka_win);

        let disk = pi_disk();
        let mut mosq = MosquittoLikeBroker::with_defaults(disk.clone());
        let mosq_win = messaging_run(&mut mosq, &disk, size, messages, WINDOWS);
        let (m_mean, m_std) = mean_std(&mosq_win);

        println!(
            "{:<10} {:>13.0} ±{:>6.0} {:>13.0} ±{:>6.0} {:>13.0} ±{:>6.0} {:>7.1}x {:>7.1}x",
            fmt_size(size),
            rp_mean,
            rp_std,
            k_mean,
            k_std,
            m_mean,
            m_std,
            rp_mean / k_mean,
            rp_mean / m_mean
        );
        // Sanity: the paper's ordering must hold (Kafka-vs-Mosquitto at
        // the IoT-typical small sizes the paper emphasises; at 64 KiB
        // both are disk-bound and converge).
        assert!(rp_mean > k_mean, "R-Pulsar must beat Kafka-like at {size}B");
        if size <= 1024 {
            assert!(k_mean > m_mean, "Kafka-like must beat Mosquitto-like at {size}B");
        }
        let _ = kafka.consume("bench", 1); // silence unused-path warnings
        let _ = mosq.consume("bench", 1);
        let _ = rp.name();
    }

    fetch_path_ablation(smoke);
}

/// Fetch-path ablation: with the subscription↔topic match cache, a
/// fetch must not re-run `matching::matches` against every topic — the
/// seed rematched all topics on every call. Proven with the matcher's
/// invocation counter (this bench binary is single-threaded).
fn fetch_path_ablation(smoke: bool) {
    header(
        "Fig. 4 ablation — fetch path: cached matching vs per-fetch rematch",
        "fetch/lag use the broker match cache; zero matcher calls per fetch",
    );
    let topics: usize = if smoke { 8 } else { 64 };
    let fetches: usize = if smoke { 200 } else { 5_000 };
    let dir = std::env::temp_dir()
        .join("rpulsar-bench")
        .join(format!("fig4-fetchpath-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut broker = Broker::new(QueueOptions {
        dir,
        segment_bytes: 1 << 20,
        max_segments: 4,
        sync_every: 0,
    });
    let topic_profiles: Vec<Profile> = (0..topics)
        .map(|t| Profile::parse(&format!("region{t:03},lidar")).unwrap())
        .collect();
    for p in &topic_profiles {
        broker.publish(p, b"seed-message").unwrap();
    }
    broker.subscribe("app", Profile::parse("region*,lidar").unwrap());

    let calls_before = matching::match_calls();
    let broker_calls_before = broker.match_calls();
    let t0 = Instant::now();
    let mut delivered = 0usize;
    for i in 0..fetches {
        // Keep a trickle of new data flowing so fetches do real work.
        let p = &topic_profiles[i % topic_profiles.len()];
        broker.publish(p, b"payload").unwrap();
        delivered += broker.fetch("app", 4).unwrap().len();
        broker.lag("app").unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let rematches = matching::match_calls() - calls_before;
    let broker_rematches = broker.match_calls() - broker_calls_before;

    println!(
        "{topics} topics, {fetches} fetches: {:.0} fetch/s, {delivered} delivered, \
         {rematches} matcher calls during fetch loop (scan arm would do {})",
        fetches as f64 / elapsed,
        topics * fetches,
    );
    assert_eq!(
        broker_rematches, 0,
        "broker fetch/lag path must not invoke the profile matcher"
    );
    assert_eq!(
        rematches, 0,
        "no code on the fetch path may rerun matching::matches"
    );
}
