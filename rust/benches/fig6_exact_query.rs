//! Fig. 6: exact-query performance — R-Pulsar vs SQLite-like vs
//! Nitrite-like as the stored workload grows.
//!
//! Paper result: the baselines are slightly faster for small workloads;
//! R-Pulsar shows better performance as the workload increases (its
//! recently-used data stays in RAM, the baselines' B-tree/page caches
//! stop fitting).
//!
//! Ablation arm (`indexed` vs `scan`): the associative matching plane
//! itself — index-backed profile queries (`ar::index`) against the
//! O(N) linear `matching::matches` scan they replaced, at growing
//! stored-profile counts. Run with `-- --test` for a CI smoke pass.
//!
//! Federated arm: the sharded matching plane under churn — profiles
//! rendezvous-hashed over shards, queries fanned out and verified
//! per-candidate only (the matcher-call counter proves zero full
//! scans on the fetch path), shard removal moving exactly the removed
//! shard's keys, and TTL-expired subscriptions provably swept. Writes
//! `BENCH_matching.json` at the repo root. Smoke scales the population
//! down; the full run uses 1M profiles / 100k queries.

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, smoke_mode, windowed_throughput};
use rpulsar::ar::index::{IndexedProfiles, ProfileIndex};
use rpulsar::ar::matching;
use rpulsar::ar::profile::Profile;
use rpulsar::ar::shard::{MatchingPlane, ShardMap, ShardedBroker};
use rpulsar::mmq::QueueOptions;
use rpulsar::baselines::nitrite_like::NitriteLikeStore;
use rpulsar::baselines::sqlite_like::SqliteLikeStore;
use rpulsar::baselines::RecordStore;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;
use rpulsar::workload::random_records;
use std::time::Instant;

const VALUE_BYTES: usize = 256;
const QUERIES: usize = 500;
const WINDOWS: usize = 5;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Fig. 6 — exact-query performance on Raspberry Pi",
        "baselines slightly faster when small; R-Pulsar wins as workload grows",
    );
    println!(
        "{:<8} {:>18} {:>18} {:>18}",
        "records", "r-pulsar (q/s)", "sqlite-like", "nitrite-like"
    );
    let sizes: &[usize] = if smoke { &[100] } else { &[100, 1_000, 5_000, 20_000] };
    for &n in sizes {
        let mut rng = Prng::seeded(6);
        let records = random_records(&mut rng, n, VALUE_BYTES);

        // R-Pulsar LSM.
        let disk = pi_disk();
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("fig6-{n}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // RocksDB-style: the write buffer is sized so recently-used data
        // stays in RAM (the paper's §IV-C3 design point; a Pi has 1 GB).
        let mut store = LsmStore::open(
            LsmOptions { dir, memtable_bytes: 32 << 20, bloom_bits_per_key: 10, max_tables: 8 },
            disk.clone(),
        )
        .unwrap();
        for (p, v) in &records {
            store.put(p.render().as_bytes(), v).unwrap();
        }
        let rp_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            store.get(p.render().as_bytes()).unwrap();
        });
        let (rp, _) = mean_std(&rp_win);

        // SQLite-like.
        let disk = pi_disk();
        let mut sq = SqliteLikeStore::with_defaults(disk.clone());
        for (p, v) in &records {
            sq.store(&p.render(), v).unwrap();
        }
        let sq_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            sq.query_exact(&p.render()).unwrap();
        });
        let (sq_mean, _) = mean_std(&sq_win);

        // Nitrite-like.
        let disk = pi_disk();
        let mut nit = NitriteLikeStore::with_defaults(disk.clone());
        for (p, v) in &records {
            nit.store(&p.render(), v).unwrap();
        }
        let nit_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            nit.query_exact(&p.render()).unwrap();
        });
        let (nit_mean, _) = mean_std(&nit_win);

        println!("{n:<8} {rp:>18.0} {sq_mean:>18.0} {nit_mean:>18.0}");
    }
    println!("(series shape: R-Pulsar flat/improving, baselines degrade past cache capacity)");

    matching_plane_ablation(smoke);
    federated_matching_arm(smoke);
}

/// Build the deterministic stored-profile population: simple 3-term
/// profiles (two keywords + one numeric pair), as the paper's resource
/// profiles are.
fn stored_profiles(n: usize) -> Vec<Profile> {
    (0..n)
        .map(|i| {
            Profile::parse(&format!("node{i:06},mod{},zone:{}", i % 8, i % 97)).unwrap()
        })
        .collect()
}

/// `indexed` vs `scan` ablation over the associative matching plane with
/// exact-tuple queries (the Fig. 6 query shape).
fn matching_plane_ablation(smoke: bool) {
    header(
        "Fig. 6 ablation — exact associative query: indexed vs scan",
        "inverted profile index replaces the O(N) matching scan",
    );
    println!(
        "{:<8} {:>16} {:>16} {:>9}",
        "profiles", "indexed (q/s)", "scan (q/s)", "speedup"
    );
    let sizes: &[usize] = if smoke { &[256] } else { &[1_000, 10_000, 40_000] };
    for &n in sizes {
        let stored = stored_profiles(n);
        let mut ix: IndexedProfiles<Profile> = IndexedProfiles::new();
        for p in &stored {
            ix.insert(p.clone());
        }
        let queries = (2_000_000 / n).clamp(100, 2_000);

        // Scan arm: the seed's linear pass over every stored profile.
        let t0 = Instant::now();
        let mut scan_hits = 0usize;
        for i in 0..queries {
            let q = &stored[(i * 37) % n];
            scan_hits += stored.iter().filter(|s| matching::matches(q, s)).count();
        }
        let scan_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        // Indexed arm: same queries through the inverted index.
        let t0 = Instant::now();
        let mut ix_hits = 0usize;
        for i in 0..queries {
            let q = &stored[(i * 37) % n];
            ix_hits += ix.query(q).len();
        }
        let ix_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        assert_eq!(ix_hits, scan_hits, "index and scan must agree on every query");
        let speedup = ix_qps / scan_qps;
        println!("{n:<8} {ix_qps:>16.0} {scan_qps:>16.0} {speedup:>8.1}x");
        if !smoke && n >= 10_000 {
            assert!(
                speedup >= 5.0,
                "indexed arm must be ≥5x the scan arm at n={n}, got {speedup:.1}x"
            );
        }
    }
}

/// One shard of the federated plane: the index plus its profile slab
/// (the index returns pids; the slab verifies and resolves them).
struct FedShard {
    index: ProfileIndex,
    slab: Vec<Profile>,
}

impl FedShard {
    fn new() -> Self {
        FedShard { index: ProfileIndex::new(), slab: Vec::new() }
    }

    fn insert(&mut self, p: Profile) {
        self.index.insert(self.slab.len() as u32, &p);
        self.slab.push(p);
    }
}

/// The Fig. 6 federated arm: rendezvous-sharded matching at scale with
/// churn, zero-scan counter proofs, and the TTL register/expire/sweep
/// lifecycle. Full scale is 1M profiles / 100k queries over 4 shards
/// (minutes on a laptop — run `cargo bench --bench fig6_exact_query`
/// without `-- --test`); smoke shrinks the population for CI.
fn federated_matching_arm(smoke: bool) {
    header(
        "Fig. 6 federated arm — sharded matching plane at 1M profiles",
        "HRW shards + candidate-only verify: no full scans, churn moves only owned keys",
    );
    let n: usize = if smoke { 20_000 } else { 1_000_000 };
    let q: usize = if smoke { 400 } else { 100_000 };
    let equiv_stride = if smoke { 1 } else { 500 };
    let shard_names = ["alpha", "beta", "gamma", "delta"];

    // Build: every profile lives on exactly its HRW owner shard.
    let mut map = ShardMap::new(shard_names);
    let mut shards: std::collections::BTreeMap<String, FedShard> =
        shard_names.iter().map(|s| (s.to_string(), FedShard::new())).collect();
    let stored = stored_profiles(n);
    let t0 = Instant::now();
    for p in &stored {
        let owner = map.owner(&p.render()).unwrap().to_string();
        shards.get_mut(&owner).unwrap().insert(p.clone());
    }
    let build_s = t0.elapsed().as_secs_f64();
    let populations: Vec<usize> = shards.values().map(|s| s.slab.len()).collect();
    println!(
        "built {n} profiles over {} shards in {build_s:.2}s (populations {populations:?})"
    );

    // Query mix: exact tuples, partial keywords, numeric ranges — the
    // three Fig. 6/7 shapes — fanned out to every shard. The matcher
    // counter proves every `matches` call was a per-candidate verify.
    let query_at = |i: usize| -> Profile {
        match i % 3 {
            0 => stored[(i * 37) % n].clone(),
            1 => Profile::parse(&format!("node{:05}*", (i * 131) % (n / 10).max(1))).unwrap(),
            _ => {
                let lo = (i * 29) % 90;
                Profile::parse(&format!("zone:{lo}..{}", lo + 7)).unwrap()
            }
        }
    };
    let mc0 = matching::match_calls();
    let mut candidates = 0u64;
    let mut fed_hits = 0usize;
    let t0 = Instant::now();
    for i in 0..q {
        let query = query_at(i);
        for shard in shards.values() {
            let cands = shard.index.forward_candidates(&query);
            candidates += cands.len() as u64;
            fed_hits += cands
                .iter()
                .filter(|&&pid| matching::matches(&query, &shard.slab[pid as usize]))
                .count();
        }
    }
    let fed_qps = q as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let verify_calls = matching::match_calls() - mc0;
    assert_eq!(
        verify_calls, candidates,
        "every matcher call on the fetch path must be a per-candidate verify — zero full scans"
    );

    // Positional routing takes the same indexed path (satellite of the
    // same scan surface): counter-asserted like the associative form.
    let pm0 = matching::positional_match_calls();
    let mut pos_candidates = 0u64;
    for i in 0..q.min(if smoke { 200 } else { 10_000 }) {
        let query = stored[(i * 53) % n].clone();
        for shard in shards.values() {
            let cands = shard.index.forward_candidates_positional(&query);
            pos_candidates += cands.len() as u64;
            for pid in cands {
                matching::matches_positional(&query, &shard.slab[pid as usize]);
            }
        }
    }
    let pos_calls = matching::positional_match_calls() - pm0;
    assert_eq!(pos_calls, pos_candidates, "positional fetch path must not full-scan either");

    // Set-equivalence against the shard-local linear scan baseline, on
    // a stride of the query stream (every query in smoke mode).
    let t0 = Instant::now();
    let mut scan_hits = 0usize;
    let mut scanned_queries = 0usize;
    for i in (0..q).step_by(equiv_stride) {
        let query = query_at(i);
        scanned_queries += 1;
        let mut fed: Vec<String> = Vec::new();
        let mut scan: Vec<String> = Vec::new();
        for shard in shards.values() {
            fed.extend(
                shard
                    .index
                    .forward_candidates(&query)
                    .into_iter()
                    .filter(|&pid| matching::matches(&query, &shard.slab[pid as usize]))
                    .map(|pid| shard.slab[pid as usize].render()),
            );
            scan.extend(
                shard
                    .slab
                    .iter()
                    .filter(|s| matching::matches(&query, s))
                    .map(|s| s.render()),
            );
        }
        fed.sort();
        scan.sort();
        assert_eq!(fed, scan, "federated result must be set-equivalent to the scan");
        scan_hits += scan.len();
    }
    let scan_qps = scanned_queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let _ = scan_hits;

    // Churn: drop one shard; exactly its keys re-home (HRW property),
    // and the re-homed plane answers the same queries.
    let victim = "delta";
    let moved = shards.remove(victim).unwrap();
    map.remove(victim);
    for p in &moved.slab {
        debug_assert_ne!(map.owner(&p.render()).unwrap(), victim);
        let owner = map.owner(&p.render()).unwrap().to_string();
        shards.get_mut(&owner).unwrap().insert(p.clone());
    }
    let moved_keys = moved.slab.len();
    for p in stored.iter().step_by((n / 1000).max(1)) {
        // Sampled stability check: survivors kept their owner unless
        // they were the victim's.
        let owner = map.owner(&p.render()).unwrap();
        assert!(shard_names.contains(&owner) && owner != victim);
    }
    for i in (0..q).step_by(equiv_stride.max(10)) {
        let query = query_at(i);
        let mut fed = 0usize;
        let mut scan = 0usize;
        for shard in shards.values() {
            fed += shard
                .index
                .forward_candidates(&query)
                .into_iter()
                .filter(|&pid| matching::matches(&query, &shard.slab[pid as usize]))
                .count();
            scan += shard.slab.iter().filter(|s| matching::matches(&query, s)).count();
        }
        assert_eq!(fed, scan, "post-churn federated result must stay scan-equivalent");
    }
    println!(
        "churn: removed `{victim}`, re-homed {moved_keys} keys (only its own); \
         results unchanged"
    );

    // TTL lifecycle on the broker-backed plane: a zero-TTL registration
    // is provably swept from every shard, and a re-register replays.
    let dir = std::env::temp_dir()
        .join("rpulsar-bench")
        .join(format!("fig6-fed-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts =
        QueueOptions { dir: dir.clone(), segment_bytes: 1 << 18, max_segments: 4, sync_every: 0 };
    let mut plane = ShardedBroker::new(opts, shard_names);
    plane.subscribe_with_ttl(
        "ephemeral",
        Profile::parse("node*,*,zone:*").unwrap(),
        Some(std::time::Duration::ZERO),
    );
    for p in stored.iter().take(16) {
        plane.publish(p, b"tuple").unwrap();
    }
    let swept = plane.sweep_expired();
    assert_eq!(swept, ["ephemeral"], "zero-TTL registration must be swept");
    assert!(plane.fetch("ephemeral", 16).is_err(), "swept consumer no longer fetches");
    plane.subscribe_with_ttl("ephemeral", Profile::parse("node*,*,zone:*").unwrap(), None);
    assert_eq!(
        plane.fetch("ephemeral", 64).unwrap().len(),
        16,
        "post-expiry re-register replays the retained backlog"
    );
    let _ = std::fs::remove_dir_all(&dir);
    println!("ttl: swept {} expired registration(s); re-register replayed 16", swept.len());

    let speedup = fed_qps / scan_qps;
    println!(
        "federated {fed_qps:>10.0} q/s   shard-local scan {scan_qps:>8.0} q/s   \
         ({speedup:.1}x, {fed_hits} hits, {verify_calls} candidate verifies)"
    );
    write_matching_json(
        smoke, n, q, fed_qps, scan_qps, verify_calls, candidates, moved_keys, swept.len(),
    );
}

/// Bench-trajectory record for later PRs, written at the repo root.
#[allow(clippy::too_many_arguments)]
fn write_matching_json(
    smoke: bool,
    profiles: usize,
    queries: usize,
    fed_qps: f64,
    scan_qps: f64,
    verify_calls: u64,
    candidates: u64,
    moved_keys: usize,
    ttl_swept: usize,
) {
    let json = format!(
        "{{\n  \"bench\": \"fig6_federated_matching\",\n  \"smoke\": {smoke},\n  \
         \"profiles\": {profiles},\n  \"queries\": {queries},\n  \
         \"federated_qps\": {fed_qps:.1},\n  \"shard_scan_qps\": {scan_qps:.1},\n  \
         \"matcher_calls\": {verify_calls},\n  \"candidates\": {candidates},\n  \
         \"full_scans_on_fetch_path\": 0,\n  \"moved_keys_on_churn\": {moved_keys},\n  \
         \"ttl_swept\": {ttl_swept}\n}}\n"
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_matching.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("bench trajectory written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
