//! Fig. 6: exact-query performance — R-Pulsar vs SQLite-like vs
//! Nitrite-like as the stored workload grows.
//!
//! Paper result: the baselines are slightly faster for small workloads;
//! R-Pulsar shows better performance as the workload increases (its
//! recently-used data stays in RAM, the baselines' B-tree/page caches
//! stop fitting).

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, windowed_throughput};
use rpulsar::baselines::nitrite_like::NitriteLikeStore;
use rpulsar::baselines::sqlite_like::SqliteLikeStore;
use rpulsar::baselines::RecordStore;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;
use rpulsar::workload::random_records;

const VALUE_BYTES: usize = 256;
const QUERIES: usize = 500;
const WINDOWS: usize = 5;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

fn main() {
    header(
        "Fig. 6 — exact-query performance on Raspberry Pi",
        "baselines slightly faster when small; R-Pulsar wins as workload grows",
    );
    println!(
        "{:<8} {:>18} {:>18} {:>18}",
        "records", "r-pulsar (q/s)", "sqlite-like", "nitrite-like"
    );
    for &n in &[100usize, 1_000, 5_000, 20_000] {
        let mut rng = Prng::seeded(6);
        let records = random_records(&mut rng, n, VALUE_BYTES);

        // R-Pulsar LSM.
        let disk = pi_disk();
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("fig6-{n}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // RocksDB-style: the write buffer is sized so recently-used data
        // stays in RAM (the paper's §IV-C3 design point; a Pi has 1 GB).
        let mut store = LsmStore::open(
            LsmOptions { dir, memtable_bytes: 32 << 20, bloom_bits_per_key: 10, max_tables: 8 },
            disk.clone(),
        )
        .unwrap();
        for (p, v) in &records {
            store.put(p.render().as_bytes(), v).unwrap();
        }
        let rp_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            store.get(p.render().as_bytes()).unwrap();
        });
        let (rp, _) = mean_std(&rp_win);

        // SQLite-like.
        let disk = pi_disk();
        let mut sq = SqliteLikeStore::with_defaults(disk.clone());
        for (p, v) in &records {
            sq.store(&p.render(), v).unwrap();
        }
        let sq_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            sq.query_exact(&p.render()).unwrap();
        });
        let (sq_mean, _) = mean_std(&sq_win);

        // Nitrite-like.
        let disk = pi_disk();
        let mut nit = NitriteLikeStore::with_defaults(disk.clone());
        for (p, v) in &records {
            nit.store(&p.render(), v).unwrap();
        }
        let nit_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            nit.query_exact(&p.render()).unwrap();
        });
        let (nit_mean, _) = mean_std(&nit_win);

        println!("{n:<8} {rp:>18.0} {sq_mean:>18.0} {nit_mean:>18.0}");
    }
    println!("(series shape: R-Pulsar flat/improving, baselines degrade past cache capacity)");
}
