//! Fig. 6: exact-query performance — R-Pulsar vs SQLite-like vs
//! Nitrite-like as the stored workload grows.
//!
//! Paper result: the baselines are slightly faster for small workloads;
//! R-Pulsar shows better performance as the workload increases (its
//! recently-used data stays in RAM, the baselines' B-tree/page caches
//! stop fitting).
//!
//! Ablation arm (`indexed` vs `scan`): the associative matching plane
//! itself — index-backed profile queries (`ar::index`) against the
//! O(N) linear `matching::matches` scan they replaced, at growing
//! stored-profile counts. Run with `-- --test` for a CI smoke pass.

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, smoke_mode, windowed_throughput};
use rpulsar::ar::index::IndexedProfiles;
use rpulsar::ar::matching;
use rpulsar::ar::profile::Profile;
use rpulsar::baselines::nitrite_like::NitriteLikeStore;
use rpulsar::baselines::sqlite_like::SqliteLikeStore;
use rpulsar::baselines::RecordStore;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;
use rpulsar::workload::random_records;
use std::time::Instant;

const VALUE_BYTES: usize = 256;
const QUERIES: usize = 500;
const WINDOWS: usize = 5;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Fig. 6 — exact-query performance on Raspberry Pi",
        "baselines slightly faster when small; R-Pulsar wins as workload grows",
    );
    println!(
        "{:<8} {:>18} {:>18} {:>18}",
        "records", "r-pulsar (q/s)", "sqlite-like", "nitrite-like"
    );
    let sizes: &[usize] = if smoke { &[100] } else { &[100, 1_000, 5_000, 20_000] };
    for &n in sizes {
        let mut rng = Prng::seeded(6);
        let records = random_records(&mut rng, n, VALUE_BYTES);

        // R-Pulsar LSM.
        let disk = pi_disk();
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("fig6-{n}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // RocksDB-style: the write buffer is sized so recently-used data
        // stays in RAM (the paper's §IV-C3 design point; a Pi has 1 GB).
        let mut store = LsmStore::open(
            LsmOptions { dir, memtable_bytes: 32 << 20, bloom_bits_per_key: 10, max_tables: 8 },
            disk.clone(),
        )
        .unwrap();
        for (p, v) in &records {
            store.put(p.render().as_bytes(), v).unwrap();
        }
        let rp_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            store.get(p.render().as_bytes()).unwrap();
        });
        let (rp, _) = mean_std(&rp_win);

        // SQLite-like.
        let disk = pi_disk();
        let mut sq = SqliteLikeStore::with_defaults(disk.clone());
        for (p, v) in &records {
            sq.store(&p.render(), v).unwrap();
        }
        let sq_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            sq.query_exact(&p.render()).unwrap();
        });
        let (sq_mean, _) = mean_std(&sq_win);

        // Nitrite-like.
        let disk = pi_disk();
        let mut nit = NitriteLikeStore::with_defaults(disk.clone());
        for (p, v) in &records {
            nit.store(&p.render(), v).unwrap();
        }
        let nit_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let (p, _) = &records[(i * 37) % n];
            nit.query_exact(&p.render()).unwrap();
        });
        let (nit_mean, _) = mean_std(&nit_win);

        println!("{n:<8} {rp:>18.0} {sq_mean:>18.0} {nit_mean:>18.0}");
    }
    println!("(series shape: R-Pulsar flat/improving, baselines degrade past cache capacity)");

    matching_plane_ablation(smoke);
}

/// Build the deterministic stored-profile population: simple 3-term
/// profiles (two keywords + one numeric pair), as the paper's resource
/// profiles are.
fn stored_profiles(n: usize) -> Vec<Profile> {
    (0..n)
        .map(|i| {
            Profile::parse(&format!("node{i:06},mod{},zone:{}", i % 8, i % 97)).unwrap()
        })
        .collect()
}

/// `indexed` vs `scan` ablation over the associative matching plane with
/// exact-tuple queries (the Fig. 6 query shape).
fn matching_plane_ablation(smoke: bool) {
    header(
        "Fig. 6 ablation — exact associative query: indexed vs scan",
        "inverted profile index replaces the O(N) matching scan",
    );
    println!(
        "{:<8} {:>16} {:>16} {:>9}",
        "profiles", "indexed (q/s)", "scan (q/s)", "speedup"
    );
    let sizes: &[usize] = if smoke { &[256] } else { &[1_000, 10_000, 40_000] };
    for &n in sizes {
        let stored = stored_profiles(n);
        let mut ix: IndexedProfiles<Profile> = IndexedProfiles::new();
        for p in &stored {
            ix.insert(p.clone());
        }
        let queries = (2_000_000 / n).clamp(100, 2_000);

        // Scan arm: the seed's linear pass over every stored profile.
        let t0 = Instant::now();
        let mut scan_hits = 0usize;
        for i in 0..queries {
            let q = &stored[(i * 37) % n];
            scan_hits += stored.iter().filter(|s| matching::matches(q, s)).count();
        }
        let scan_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        // Indexed arm: same queries through the inverted index.
        let t0 = Instant::now();
        let mut ix_hits = 0usize;
        for i in 0..queries {
            let q = &stored[(i * 37) % n];
            ix_hits += ix.query(q).len();
        }
        let ix_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        assert_eq!(ix_hits, scan_hits, "index and scan must agree on every query");
        let speedup = ix_qps / scan_qps;
        println!("{n:<8} {ix_qps:>16.0} {scan_qps:>16.0} {speedup:>8.1}x");
        if !smoke && n >= 10_000 {
            assert!(
                speedup >= 5.0,
                "indexed arm must be ≥5x the scan arm at n={n}, got {speedup:.1}x"
            );
        }
    }
}
