//! Fig. 18 (beyond the paper): cluster-wide elasticity — a keyed
//! analytics chain rides a diurnal load curve while the cluster
//! changes under it.
//!
//! The scenario, on a SimNetwork cluster of two Raspberry-Pi-class
//! nodes (`ingest` on the edge, `featurize` on a spare Pi, the
//! CPU-heavy keyed window back on the edge):
//!
//! - **pre-join**: the diurnal feed runs on the two Pis; the policy
//!   plane ticks along the way and finds no migration worth taking
//!   (uniform hosts — every alternative costs the same).
//! - **join**: a `cloud_small` node joins. The join alone is inert; the
//!   next [`ClusterPolicy`] tick live-migrates the heavy window
//!   fragment onto the joiner — open keyed windows ship as
//!   `MigrateState` frames, zero loss, measured pause — and the next
//!   tick confirms the placement converged.
//! - **leave**: mid-run the cloud node is *decommissioned*: its
//!   fragment (open state again) drains back to the best surviving Pi,
//!   then the node leaves membership and reachability. The feed never
//!   stops.
//!
//! Reported per phase: wall-clock feed throughput and the policy
//! actions taken; per migration: moved keys, wire bytes and the
//! measured pause. The final output multiset must equal the
//! single-process ground truth — the zero-loss contract the elasticity
//! suite (`rust/tests/elasticity.rs`) property-tests — and the
//! `net.migration.*` counters must agree exactly with the reports.
//!
//! Writes `BENCH_elasticity.json` at the repo root so later PRs can
//! track the elasticity curve. `-- --test` runs a seconds-long smoke
//! (CI gate).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::device::profile::DeviceProfile;
use rpulsar::overlay::node_id::NodeId;
use rpulsar::stream::deploy::TopologyManager;
use rpulsar::stream::dist::{
    ClusterPolicy, DistributedTopologyManager, Fragment, MigrationReport, PlacementPlan,
    PolicyAction,
};
use rpulsar::stream::engine::StreamEngine;
use rpulsar::stream::operator::OperatorKind;
use rpulsar::stream::topology::Topology;
use rpulsar::stream::tuple::Tuple;
use std::hint::black_box;
use std::time::{Duration, Instant};

const KEYS: u64 = 16;
const SPEC: &str = "ingest->featurize*2@K->kwin@K";
/// Chunk sizes cycled through each phase: the diurnal peak→trough→peak.
const DIURNAL: &[usize] = &[256, 192, 128, 64, 32, 64, 128, 192];

fn make_stage(name: &str, window: usize) -> OperatorKind {
    match name {
        "ingest" => OperatorKind::map("ingest", |mut t| {
            let v = t.get("V").unwrap_or(0.0);
            t.set("V", v + 1.0);
            t
        }),
        "featurize" => OperatorKind::map("featurize", |mut t| {
            let v = t.get("V").unwrap_or(0.0);
            // Fixed CPU work, value-neutral: the stage the cost model
            // weighs as heavy actually burns cycles.
            let mut acc = 0.0f64;
            for i in 0..40 {
                acc += (v + i as f64).sqrt();
            }
            black_box(acc);
            t.set("V", v * 2.0);
            t
        }),
        "kwin" => OperatorKind::window_by("kwin", "V", window, "K"),
        other => unreachable!("unknown stage {other}"),
    }
}

fn tuples(total: usize) -> Vec<Tuple> {
    (0..total)
        .map(|i| {
            Tuple::new(i as u64, vec![])
                .with("K", (i as u64 % KEYS) as f64)
                .with("V", (i % 97) as f64 * 0.5)
        })
        .collect()
}

fn canon(out: Vec<Tuple>) -> Vec<String> {
    let mut v: Vec<String> = out.into_iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}

/// Feed one phase of the diurnal curve, ticking the policy plane every
/// few chunks. Returns (tuples/sec wall-clock, policy actions taken).
fn feed_phase(
    dist: &mut DistributedTopologyManager,
    input: &[Tuple],
    policy: &ClusterPolicy,
) -> (f64, Vec<PolicyAction>) {
    let mut actions = Vec::new();
    let clock = Instant::now();
    let (mut i, mut c) = (0usize, 0usize);
    while i < input.len() {
        let n = DIURNAL[c % DIURNAL.len()].min(input.len() - i);
        dist.send_batch("job", input[i..i + n].to_vec()).unwrap();
        i += n;
        c += 1;
        if c % 4 == 0 {
            actions.extend(dist.policy_tick(policy).unwrap());
        }
    }
    let secs = clock.elapsed().as_secs_f64().max(1e-9);
    (input.len() as f64 / secs, actions)
}

fn hosts(dist: &DistributedTopologyManager) -> Vec<NodeId> {
    dist.route("job").unwrap().hops().iter().map(|h| h.node).collect()
}

/// Let the background shipper deliver what is in flight, so migrations
/// at phase boundaries find the keyed state in the window fragment
/// rather than in staged batches (bounded wait — this is cosmetic for
/// the report, not a correctness requirement).
fn settle(dist: &DistributedTopologyManager) {
    let clock = Instant::now();
    while dist.route("job").unwrap().staged_tuples() > 0 && clock.elapsed() < Duration::from_secs(2)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn main() {
    header(
        "Fig. 18 — cluster elasticity (live migration, join/leave, policy plane)",
        "pipelines scale across the cloud and the edge as resources come and go",
    );
    let smoke = smoke_mode();
    // Window sizes chosen so open keyed state exists at both migration
    // points (the per-key arrival counts are not window multiples).
    let (total, window) = if smoke { (600usize, 4usize) } else { (24_000, 7) };
    let input = tuples(total);
    println!("{total} tuples over {KEYS} keys, window={window}, spec={SPEC}, smoke={smoke}");

    // Ground truth: the same spec on one single-process manager.
    let mut local = TopologyManager::new(StreamEngine::new());
    for name in ["ingest", "featurize", "kwin"] {
        local.register_stage(name, move || Box::new(make_stage(name, window)));
    }
    local.start("job", SPEC).unwrap();
    for chunk in input.chunks(512) {
        local.send_batch("job", chunk.to_vec()).unwrap();
    }
    let expected = canon(local.stop("job").unwrap());

    // The elastic cluster: two Pis now, a cloud node later.
    let mut dist = DistributedTopologyManager::new();
    let edge = NodeId::from_name("pi-edge");
    let spare = NodeId::from_name("pi-spare");
    let cloud = NodeId::from_name("cloud-join");
    dist.add_node(edge, DeviceProfile::raspberry_pi());
    dist.add_node(spare, DeviceProfile::raspberry_pi());
    for name in ["ingest", "featurize", "kwin"] {
        dist.register_stage(name, move || Box::new(make_stage(name, window)));
    }
    let topo = Topology::parse("job", SPEC).unwrap();
    let plan = PlacementPlan {
        fragments: vec![
            Fragment { node: edge, stages: topo.stages[0..1].to_vec() },
            Fragment { node: spare, stages: topo.stages[1..2].to_vec() },
            Fragment { node: edge, stages: topo.stages[2..3].to_vec() },
        ],
    };
    dist.start("job", SPEC, &plan).unwrap();
    let policy = ClusterPolicy {
        sustain: 2,
        migrate_min_gain: 0.05,
        cpu_heavy: vec!["kwin".to_string()],
        ..ClusterPolicy::default()
    };

    let phase = total / 3;

    // -- Phase 1: the two-Pi cluster rides the curve.
    let (tps_pre, acts_pre) = feed_phase(&mut dist, &input[0..phase], &policy);
    assert!(
        !acts_pre.iter().any(|a| matches!(a, PolicyAction::Migrate { .. })),
        "uniform hosts: no migration is worth taking before the join"
    );

    // -- Join: inert until the policy plane pulls the heavy fragment.
    settle(&dist);
    let before = hosts(&dist);
    dist.add_node(cloud, DeviceProfile::cloud_small());
    assert_eq!(before, hosts(&dist), "a join alone must move nothing");
    let clock = Instant::now();
    let join_actions = dist.policy_tick(&policy).unwrap();
    let join_tick = clock.elapsed();
    let pulls = join_actions
        .iter()
        .filter(|a| matches!(a, PolicyAction::Migrate { to, .. } if *to == cloud))
        .count();
    assert_eq!(pulls, 1, "the tick must pull exactly the heavy window fragment: {join_actions:?}");
    assert!(hosts(&dist).contains(&cloud), "the joiner hosts the pulled fragment");
    assert!(
        !dist
            .policy_tick(&policy)
            .unwrap()
            .iter()
            .any(|a| matches!(a, PolicyAction::Migrate { .. })),
        "placement converges after one pull"
    );
    let pull_report = dist.route("job").unwrap().migrations().last().unwrap().clone();
    assert!(
        pull_report.moved_keys <= KEYS as usize,
        "at most one state snapshot per key: {pull_report:?}"
    );

    // -- Phase 2: edge + cloud split.
    let (tps_mid, acts_mid) = feed_phase(&mut dist, &input[phase..2 * phase], &policy);

    // -- Leave: clean decommission of the cloud node, mid-run.
    settle(&dist);
    let hosted = hosts(&dist).iter().filter(|n| **n == cloud).count();
    let drain_reports = dist.decommission_node(cloud, &policy).unwrap();
    assert_eq!(drain_reports.len(), hosted, "every hosted fragment drains off the leaver");
    assert!(drain_reports[0].moved_keys <= KEYS as usize);
    assert!(!dist.nodes().contains(&cloud), "the leaver is out of membership");
    assert!(!dist.network().is_reachable(&cloud), "the leaver is out of reachability");
    assert!(!hosts(&dist).contains(&cloud));

    // -- Phase 3: back on the surviving Pis.
    let (tps_post, acts_post) = feed_phase(&mut dist, &input[2 * phase..], &policy);

    // Migration accounting: the route log, the reports and the
    // `net.migration.*` counters agree exactly.
    let migrations: Vec<MigrationReport> = dist.route("job").unwrap().migrations().to_vec();
    assert_eq!(migrations.len(), 2, "one pull at join, one drain at leave");
    let m = dist.metrics();
    assert_eq!(m.counter("net.migration.started").get(), 2);
    assert_eq!(m.counter("net.migration.completed").get(), 2);
    assert_eq!(
        m.counter("net.migration.bytes").get(),
        migrations.iter().map(|r| r.state_bytes as u64).sum::<u64>()
    );
    assert_eq!(
        m.counter("net.migration.pause_ms").get(),
        migrations.iter().map(|r| r.pause.as_millis() as u64).sum::<u64>()
    );
    for r in &migrations {
        assert!(r.pause < Duration::from_secs(60), "pause must be measured and sane: {r:?}");
    }

    // Zero loss across the whole ride.
    let out = dist.stop("job").unwrap();
    assert_eq!(
        canon(out),
        expected,
        "join, pull, and decommission must not change the output multiset"
    );

    println!("\n{:<12} {:>12} {:>9}  policy actions", "phase", "t/s (wall)", "rescales");
    for (name, tps, acts) in
        [("pre-join", tps_pre, &acts_pre), ("split", tps_mid, &acts_mid), ("drained", tps_post, &acts_post)]
    {
        let rescales =
            acts.iter().filter(|a| matches!(a, PolicyAction::Rescale { .. })).count();
        println!("{name:<12} {tps:>12.0} {rescales:>9}  {acts:?}");
    }
    println!("\njoin tick (incl. live pull): {join_tick:.2?}");
    for r in &migrations {
        println!(
            "migration f{} {} → {}: {} keys, {} B state, pause {:.2?}",
            r.fragment, r.from, r.to, r.moved_keys, r.state_bytes, r.pause
        );
    }

    write_bench_json(
        smoke,
        &[("pre-join", tps_pre), ("split", tps_mid), ("drained", tps_post)],
        &migrations,
    );
    println!("\nfig18 OK");
}

/// Bench-trajectory record for later PRs, written at the repo root.
fn write_bench_json(smoke: bool, phases: &[(&str, f64)], migrations: &[MigrationReport]) {
    let phase_rows: Vec<String> = phases
        .iter()
        .map(|(name, tps)| format!("    {{\"phase\": \"{name}\", \"tuples_per_sec\": {tps:.1}}}"))
        .collect();
    let mig_rows: Vec<String> = migrations
        .iter()
        .map(|r| {
            format!(
                "    {{\"fragment\": {}, \"from\": \"{}\", \"to\": \"{}\", \"moved_keys\": {}, \
                 \"state_bytes\": {}, \"pause_ms\": {}}}",
                r.fragment,
                r.from,
                r.to,
                r.moved_keys,
                r.state_bytes,
                r.pause.as_millis()
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"fig18_elasticity\",\n  \"smoke\": {smoke},\n  \"phases\": [\n{}\n  ],\n  \
         \"migrations\": [\n{}\n  ]\n}}\n",
        phase_rows.join(",\n"),
        mig_rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_elasticity.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("bench trajectory written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
