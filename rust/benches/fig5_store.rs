//! Fig. 5: store throughput — R-Pulsar's DHT/LSM vs SQLite-like vs
//! Nitrite-like on the Raspberry Pi model, across workload sizes.
//!
//! Paper result: R-Pulsar outperforms the best baseline (SQLite) by a
//! factor of ~32 when storing elements; Nitrite is slowest.

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, windowed_throughput};
use rpulsar::baselines::nitrite_like::NitriteLikeStore;
use rpulsar::baselines::sqlite_like::SqliteLikeStore;
use rpulsar::baselines::RecordStore;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;
use rpulsar::workload::random_records;

const VALUE_BYTES: usize = 512;
const WINDOWS: usize = 5;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

fn rpulsar_store(tag: &str, disk: ThrottledDisk) -> LsmStore {
    let dir = std::env::temp_dir()
        .join("rpulsar-bench")
        .join(format!("fig5-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    LsmStore::open(
        LsmOptions { dir, memtable_bytes: 16 << 20, bloom_bits_per_key: 10, max_tables: 6 },
        disk,
    )
    .unwrap()
}

fn main() {
    header(
        "Fig. 5 — store throughput on Raspberry Pi",
        "R-Pulsar ≈32× SQLite; Nitrite slowest",
    );
    println!(
        "{:<8} {:>18} {:>18} {:>18} {:>10} {:>10}",
        "records", "r-pulsar (op/s)", "sqlite-like", "nitrite-like", "vs-sqlite", "vs-nitrite"
    );
    for &n in &[100usize, 500, 1_000, 2_000] {
        let mut rng = Prng::seeded(5);
        let records = random_records(&mut rng, n, VALUE_BYTES);

        let disk = pi_disk();
        let mut store = rpulsar_store(&format!("{n}"), disk.clone());
        let rp_win = windowed_throughput(&disk, n, WINDOWS, |i| {
            let (p, v) = &records[i];
            store.put(p.render().as_bytes(), v).unwrap();
        });
        let (rp, _) = mean_std(&rp_win);

        let disk = pi_disk();
        let mut sq = SqliteLikeStore::with_defaults(disk.clone());
        let sq_win = windowed_throughput(&disk, n, WINDOWS, |i| {
            let (p, v) = &records[i];
            sq.store(&p.render(), v).unwrap();
        });
        let (sq_mean, _) = mean_std(&sq_win);

        let disk = pi_disk();
        let mut nit = NitriteLikeStore::with_defaults(disk.clone());
        let nit_win = windowed_throughput(&disk, n, WINDOWS, |i| {
            let (p, v) = &records[i];
            nit.store(&p.render(), v).unwrap();
        });
        let (nit_mean, _) = mean_std(&nit_win);

        println!(
            "{n:<8} {rp:>18.0} {sq_mean:>18.0} {nit_mean:>18.0} {:>9.1}x {:>9.1}x",
            rp / sq_mean,
            rp / nit_mean
        );
        assert!(rp > sq_mean && sq_mean >= nit_mean, "paper ordering must hold at n={n}");
    }
}
