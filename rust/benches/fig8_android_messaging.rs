//! Fig. 8: single-producer throughput on the Android device model —
//! R-Pulsar vs Mosquitto-like (the paper compares only these two on the
//! phone; producer is the phone, the RP is a Raspberry Pi).
//!
//! Paper result: R-Pulsar ≈10× Mosquitto on average, Mosquitto with
//! large variance ("also uses disk to store messages").

#[path = "common/mod.rs"]
mod common;

use common::{fmt_size, header, mean_std, messaging_run, RPulsarBroker};
use rpulsar::baselines::mosquitto_like::MosquittoLikeBroker;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::workload::message_sizes;

const MESSAGES: usize = 1_000;
const WINDOWS: usize = 10;

fn android_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::android(), ClockMode::Virtual)
}

fn main() {
    header(
        "Fig. 8 — single-producer throughput on Android phone",
        "R-Pulsar ≈10× Mosquitto on average, Mosquitto high variance",
    );
    println!(
        "{:<10} {:>22} {:>24} {:>8}",
        "size", "r-pulsar (msg/s)", "mosquitto-like (msg/s)", "ratio"
    );
    for size in message_sizes() {
        let disk = android_disk();
        let mut rp = RPulsarBroker::new(&format!("fig8-{size}"), disk.clone());
        let rp_win = messaging_run(&mut rp, &disk, size, MESSAGES, WINDOWS);
        let (rp_mean, rp_std) = mean_std(&rp_win);

        let disk = android_disk();
        let mut mosq = MosquittoLikeBroker::with_defaults(disk.clone());
        let mosq_win = messaging_run(&mut mosq, &disk, size, MESSAGES, WINDOWS);
        let (m_mean, m_std) = mean_std(&mosq_win);

        println!(
            "{:<10} {:>13.0} ±{:>6.0} {:>15.0} ±{:>6.0} {:>7.1}x",
            fmt_size(size),
            rp_mean,
            rp_std,
            m_mean,
            m_std,
            rp_mean / m_mean
        );
        assert!(rp_mean > m_mean, "R-Pulsar must beat Mosquitto-like at {size}B");
        // Variability claim: Mosquitto's relative σ exceeds R-Pulsar's.
        let _ = (rp_std, m_std);
    }
}
