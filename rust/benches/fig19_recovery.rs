//! Fig. 19 (beyond the paper): checkpoint/recovery — a keyed analytics
//! chain survives a whole-node kill mid-stream, at several checkpoint
//! intervals.
//!
//! The scenario, on an in-process `Cluster` of four nodes (ingest,
//! featurize and the keyed window on three of them, one spare
//! survivor):
//!
//! - **baseline**: checkpoints never enabled, no kill — the raw feed
//!   throughput every other arm's overhead is measured against.
//! - **ckpt-N** (one arm per interval): durable checkpoints every `N`
//!   input tuples; halfway through the feed the window fragment's host
//!   is killed outright. The coordinator detects the dead member,
//!   restarts the fragment on the best survivor seeded from the latest
//!   committed epoch, and replays the journaled backlog — the final
//!   output multiset must equal the uncrashed single-process ground
//!   truth (the exactly-once contract `rust/tests/recovery.rs`
//!   property-tests).
//!
//! Reported per arm: wall-clock feed throughput, committed epochs and
//! journal bytes (checkpoint overhead), recovery pause, replayed
//! tuples and fragment restarts — the interval trades steady-state
//! overhead against replay work, which is the curve this figure draws.
//!
//! Writes `BENCH_recovery.json` at the repo root so later PRs can
//! track the recovery curve. `-- --test` runs a seconds-long smoke
//! (CI gate). With `RPULSAR_CHECKPOINT=off` only the baseline arm
//! runs (a kill without checkpoints is data loss by design).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::config::DeviceKind;
use rpulsar::coordinator::Cluster;
use rpulsar::stream::checkpoint::checkpointing_enabled;
use rpulsar::stream::deploy::TopologyManager;
use rpulsar::stream::dist::{Fragment, PlacementPlan};
use rpulsar::stream::engine::StreamEngine;
use rpulsar::stream::operator::OperatorKind;
use rpulsar::stream::topology::Topology;
use rpulsar::stream::tuple::Tuple;
use std::hint::black_box;
use std::time::Instant;

const KEYS: u64 = 16;
const SPEC: &str = "ingest->featurize@K->kwin@K";
const STAGES: [&str; 3] = ["ingest", "featurize", "kwin"];

fn make_stage(name: &str, window: usize) -> OperatorKind {
    match name {
        "ingest" => OperatorKind::map("ingest", |mut t| {
            let v = t.get("V").unwrap_or(0.0);
            t.set("V", v + 1.0);
            t
        }),
        "featurize" => OperatorKind::map("featurize", |mut t| {
            let v = t.get("V").unwrap_or(0.0);
            // Fixed CPU work, value-neutral: throughput numbers mean
            // something beyond channel overhead.
            let mut acc = 0.0f64;
            for i in 0..40 {
                acc += (v + i as f64).sqrt();
            }
            black_box(acc);
            t.set("V", v * 2.0);
            t
        }),
        "kwin" => OperatorKind::window_by("kwin", "V", window, "K"),
        other => unreachable!("unknown stage {other}"),
    }
}

fn tuples(total: usize) -> Vec<Tuple> {
    (0..total)
        .map(|i| {
            Tuple::new(i as u64, vec![])
                .with("K", (i as u64 % KEYS) as f64)
                .with("V", (i % 97) as f64 * 0.5)
        })
        .collect()
}

fn canon(out: Vec<Tuple>) -> Vec<String> {
    let mut v: Vec<String> = out.into_iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}

/// One measured arm: throughput plus the checkpoint/recovery counters.
struct Arm {
    label: String,
    /// Checkpoint interval in input tuples; `0` = checkpoints off.
    interval: u64,
    tps: f64,
    epochs: u64,
    ckpt_bytes: u64,
    ckpt_us: u64,
    pause_ms: u64,
    replayed: u64,
    restarts: u64,
}

/// Run the chain over `input` on a fresh four-node cluster. With
/// `interval` set, durable checkpoints are enabled; with `kill`, the
/// window fragment's host dies at the halfway chunk and the run must
/// still match `expected` exactly-once.
fn run_arm(
    label: &str,
    interval: Option<u64>,
    kill: bool,
    input: &[Tuple],
    window: usize,
    batch: usize,
    expected: &[String],
) -> Arm {
    let mut c = Cluster::new(&format!("fig19-{label}"), 4, DeviceKind::Native).unwrap();
    for id in c.ids() {
        let topologies = c.node_mut(&id).unwrap().topologies_mut();
        for name in STAGES {
            topologies.register_stage(name, move || Box::new(make_stage(name, window)));
        }
    }
    let ids = c.ids();
    let topo = Topology::parse("job", SPEC).unwrap();
    let plan = PlacementPlan {
        fragments: vec![
            Fragment { node: ids[0], stages: topo.stages[0..1].to_vec() },
            Fragment { node: ids[1], stages: topo.stages[1..2].to_vec() },
            Fragment { node: ids[2], stages: topo.stages[2..3].to_vec() },
        ],
    };
    c.deploy_stream("job", SPEC, &plan).unwrap();
    if let Some(iv) = interval {
        assert!(c.enable_checkpoints("job", iv).unwrap(), "plane is on: enable must take");
    }

    let chunks: Vec<&[Tuple]> = input.chunks(batch).collect();
    let kill_at = chunks.len() / 2;
    let clock = Instant::now();
    let mut out = Vec::new();
    for (i, chunk) in chunks.iter().enumerate() {
        if kill && i == kill_at {
            let victim = c.stream_route("job").unwrap().hops()[2].node;
            c.kill_node(&victim).unwrap();
        }
        c.stream_send_batch("job", chunk.to_vec()).unwrap();
        out.extend(c.stream_pump("job").unwrap());
    }
    out.extend(c.stream_stop("job").unwrap());
    let secs = clock.elapsed().as_secs_f64().max(1e-9);

    let m = c.stream_metrics();
    let arm = Arm {
        label: label.to_string(),
        interval: interval.unwrap_or(0),
        tps: input.len() as f64 / secs,
        epochs: m.counter("ckpt.epochs").get(),
        ckpt_bytes: m.counter("ckpt.bytes").get(),
        ckpt_us: m.counter("ckpt.duration_us").get(),
        pause_ms: m.counter("recovery.pause_ms").get(),
        replayed: m.counter("recovery.replayed_tuples").get(),
        restarts: m.counter("recovery.restarts").get(),
    };
    if kill {
        assert!(arm.restarts >= 1, "{label}: the kill must trigger a failover");
        assert!(arm.epochs >= 1, "{label}: at least one epoch must have committed");
    }
    assert_eq!(canon(out), expected.to_vec(), "{label}: recovery must be exactly-once");
    c.shutdown().unwrap();
    arm
}

fn main() {
    header(
        "Fig. 19 — checkpoint/recovery (node kill, durable epochs, exactly-once replay)",
        "edge pipelines keep their data-driven contract through resource loss",
    );
    let smoke = smoke_mode();
    // Window sizes chosen so open keyed state exists at the kill point
    // (per-key arrival counts are not window multiples) — recovery has
    // to restore mid-window operator state, not just cursors.
    let (total, window, batch) = if smoke { (600usize, 4usize, 48usize) } else { (24_000, 7, 256) };
    let intervals: &[u64] = if smoke { &[8, 32] } else { &[64, 256, 1024] };
    let input = tuples(total);
    println!("{total} tuples over {KEYS} keys, window={window}, spec={SPEC}, smoke={smoke}");

    // Ground truth: the same spec on one single-process manager.
    let mut local = TopologyManager::new(StreamEngine::new());
    for name in STAGES {
        local.register_stage(name, move || Box::new(make_stage(name, window)));
    }
    local.start("job", SPEC).unwrap();
    for chunk in input.chunks(512) {
        local.send_batch("job", chunk.to_vec()).unwrap();
    }
    let expected = canon(local.stop("job").unwrap());

    let mut arms = vec![run_arm("baseline", None, false, &input, window, batch, &expected)];
    if checkpointing_enabled() {
        for &iv in intervals {
            let label = format!("ckpt-{iv}");
            arms.push(run_arm(&label, Some(iv), true, &input, window, batch, &expected));
        }
    } else {
        println!("RPULSAR_CHECKPOINT=off: kill arms skipped (baseline only)");
    }

    let base_tps = arms[0].tps;
    println!(
        "\n{:<12} {:>12} {:>9} {:>7} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "arm", "t/s (wall)", "overhead", "epochs", "ckpt B", "ckpt ms", "pause ms", "replayed", "restarts"
    );
    for a in &arms {
        let overhead = if a.interval == 0 {
            "-".to_string()
        } else {
            format!("{:+.1}%", (base_tps / a.tps.max(1e-9) - 1.0) * 100.0)
        };
        println!(
            "{:<12} {:>12.0} {:>9} {:>7} {:>10} {:>9} {:>9} {:>9} {:>9}",
            a.label,
            a.tps,
            overhead,
            a.epochs,
            a.ckpt_bytes,
            a.ckpt_us / 1000,
            a.pause_ms,
            a.replayed,
            a.restarts
        );
    }

    write_bench_json(smoke, &arms);
    println!("\nfig19 OK");
}

/// Bench-trajectory record for later PRs, written at the repo root.
fn write_bench_json(smoke: bool, arms: &[Arm]) {
    let rows: Vec<String> = arms
        .iter()
        .map(|a| {
            format!(
                "    {{\"arm\": \"{}\", \"interval\": {}, \"tuples_per_sec\": {:.1}, \
                 \"epochs\": {}, \"ckpt_bytes\": {}, \"ckpt_us\": {}, \
                 \"recovery_pause_ms\": {}, \"replayed_tuples\": {}, \"restarts\": {}}}",
                a.label,
                a.interval,
                a.tps,
                a.epochs,
                a.ckpt_bytes,
                a.ckpt_us,
                a.pause_ms,
                a.replayed,
                a.restarts
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"fig19_recovery\",\n  \"smoke\": {smoke},\n  \"arms\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_recovery.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("bench trajectory written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
