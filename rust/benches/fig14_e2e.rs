//! Fig. 14: end-to-end disaster-recovery pipeline on the Raspberry Pi —
//! R-Pulsar vs Kafka+Edgent+SQLite vs Kafka+Edgent+NitriteDB, over a
//! Hurricane-Sandy-shaped synthetic LiDAR trace, with the PJRT-compiled
//! Pallas pre-processing kernel on the request path.
//!
//! Paper result: "a gain in response time up to 36% compared to
//! traditional stream processing pipelines."
//!
//! Requires artifacts: run `make artifacts` first.

#[path = "common/mod.rs"]
mod common;

use common::header;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::workflow::{
    analytics_spec, run_stream_analytics, trace_tuples, BaselineKind, DisasterRecoveryPipeline,
};
use std::path::PathBuf;

const IMAGES: usize = 200;

fn main() {
    header(
        "Fig. 14 — end-to-end disaster-recovery pipeline (Raspberry Pi)",
        "R-Pulsar up to 36% faster than Kafka+Edgent+{SQLite,Nitrite}",
    );
    let artifacts = PathBuf::from(
        std::env::var("RPULSAR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let pipeline = match DisasterRecoveryPipeline::new(&artifacts, DeviceProfile::raspberry_pi())
    {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping fig14 bench: {e}");
            return;
        }
    };
    let trace = LidarTrace::generate(42, IMAGES, 16.0);
    println!(
        "trace: {} images, {:.1} MB nominal (paper: 741 images, 3.7 GB)",
        trace.len(),
        trace.total_bytes() as f64 / 1e6
    );

    let rp = pipeline.run_rpulsar(&trace).unwrap();
    let sq = pipeline.run_baseline(&trace, BaselineKind::KafkaEdgentSqlite).unwrap();
    let nit = pipeline.run_baseline(&trace, BaselineKind::KafkaEdgentNitrite).unwrap();

    println!("{:<24} {:>14} {:>14} {:>8} {:>8} {:>8}", "system", "total", "per-image", "edge", "core", "drop");
    for r in [&rp, &sq, &nit] {
        println!(
            "{:<24} {:>11.2?} {:>11.2?} {:>8} {:>8} {:>8}",
            r.system,
            r.total(),
            r.per_image(),
            r.stored_at_edge,
            r.forwarded_to_core,
            r.dropped
        );
    }
    let gain_sq = 100.0 * (1.0 - rp.total().as_secs_f64() / sq.total().as_secs_f64());
    let gain_nit = 100.0 * (1.0 - rp.total().as_secs_f64() / nit.total().as_secs_f64());
    println!("\nresponse-time gain: {gain_sq:.1}% vs SQLite stack, {gain_nit:.1}% vs Nitrite stack");
    println!("paper claims up to 36% — shape holds when the gain is ≥ 30%");
    assert!(gain_sq > 0.0 && gain_nit > 0.0, "R-Pulsar must win end-to-end");

    // Beyond the paper: the same trace's tiles through the parallel
    // keyed stream executor (Fig. 13 analytics as a topology; the
    // serial-vs-parallel ablation lives in fig15_parallel_stream).
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let parallelism = cores.clamp(1, 4);
    let tuples = trace_tuples(&trace, 512);
    let streamed =
        run_stream_analytics(&analytics_spec(parallelism), tuples, 16).unwrap();
    println!(
        "\nstream plane: {} tile tuples through `{}` at {:.0} tuples/s → {} windowed aggregates",
        streamed.tuples,
        streamed.spec,
        streamed.tuples_per_sec(),
        streamed.outputs.len()
    );
    assert!(!streamed.outputs.is_empty(), "stream analytics must emit aggregates");
}
