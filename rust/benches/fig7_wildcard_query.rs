//! Fig. 7: wildcard-query performance — R-Pulsar vs SQLite-like vs
//! Nitrite-like. Wildcards may return multiple results; the baselines
//! full-scan (LIKE without index / collection filter), R-Pulsar
//! prefix-scans its sorted store.
//!
//! Paper result: same shape as Fig. 6 — baselines fine when small,
//! R-Pulsar better as the workload increases.

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, windowed_throughput};
use rpulsar::baselines::nitrite_like::NitriteLikeStore;
use rpulsar::baselines::sqlite_like::SqliteLikeStore;
use rpulsar::baselines::RecordStore;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;

const QUERIES: usize = 100;
const WINDOWS: usize = 5;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

/// Records with a controlled set of prefixes so wildcard selectivity is
/// stable across workload sizes.
fn prefixed_records(rng: &mut Prng, n: usize) -> Vec<(String, Vec<u8>)> {
    let prefixes = ["sensa", "sensb", "sensc", "sensd"];
    (0..n)
        .map(|i| {
            let p = prefixes[i % prefixes.len()];
            let key = format!("{p}{:05},lidar", i);
            let mut v = vec![0u8; 256];
            rng.fill_bytes(&mut v);
            (key, v)
        })
        .collect()
}

fn main() {
    header(
        "Fig. 7 — wildcard-query performance on Raspberry Pi",
        "same crossover as Fig. 6; wildcard returns multiple results",
    );
    println!(
        "{:<8} {:>18} {:>18} {:>18}",
        "records", "r-pulsar (q/s)", "sqlite-like", "nitrite-like"
    );
    for &n in &[100usize, 1_000, 4_000] {
        let mut rng = Prng::seeded(7);
        let records = prefixed_records(&mut rng, n);

        // R-Pulsar: sorted prefix scan.
        let disk = pi_disk();
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("fig7-{n}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = LsmStore::open(
            LsmOptions { dir, memtable_bytes: 8 << 20, bloom_bits_per_key: 10, max_tables: 8 },
            disk.clone(),
        )
        .unwrap();
        for (k, v) in &records {
            store.put(k.as_bytes(), v).unwrap();
        }
        let rp_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let prefix = ["sensa", "sensb", "sensc", "sensd"][i % 4];
            let hits = store.scan_prefix(prefix.as_bytes()).unwrap();
            assert!(!hits.is_empty());
        });
        let (rp, _) = mean_std(&rp_win);

        // SQLite-like: LIKE 'prefix%' full scan.
        let disk = pi_disk();
        let mut sq = SqliteLikeStore::with_defaults(disk.clone());
        for (k, v) in &records {
            sq.store(k, v).unwrap();
        }
        let sq_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let prefix = ["sensa", "sensb", "sensc", "sensd"][i % 4];
            let hits = sq.query_wildcard(&format!("{prefix}*")).unwrap();
            assert!(!hits.is_empty());
        });
        let (sq_mean, _) = mean_std(&sq_win);

        // Nitrite-like: filter scan with deserialization.
        let disk = pi_disk();
        let mut nit = NitriteLikeStore::with_defaults(disk.clone());
        for (k, v) in &records {
            nit.store(k, v).unwrap();
        }
        let nit_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let prefix = ["sensa", "sensb", "sensc", "sensd"][i % 4];
            let hits = nit.query_wildcard(&format!("{prefix}*")).unwrap();
            assert!(!hits.is_empty());
        });
        let (nit_mean, _) = mean_std(&nit_win);

        println!("{n:<8} {rp:>18.1} {sq_mean:>18.1} {nit_mean:>18.1}");
        assert!(
            rp > sq_mean && rp > nit_mean,
            "R-Pulsar must win wildcard queries at n={n}"
        );
    }
}
