//! Fig. 7: wildcard-query performance — R-Pulsar vs SQLite-like vs
//! Nitrite-like. Wildcards may return multiple results; the baselines
//! full-scan (LIKE without index / collection filter), R-Pulsar
//! prefix-scans its sorted store.
//!
//! Paper result: same shape as Fig. 6 — baselines fine when small,
//! R-Pulsar better as the workload increases.
//!
//! Second ablation arm: interval tree vs linear interval list for
//! range-heavy populations — stabbing and overlap queries against
//! stored `lo..hi` profiles, where the old interval *list* degraded to
//! O(ranges) per lookup.

#[path = "common/mod.rs"]
mod common;

use common::{header, mean_std, smoke_mode, windowed_throughput};
use rpulsar::ar::index::IndexedProfiles;
use rpulsar::ar::matching;
use rpulsar::ar::profile::Profile;
use rpulsar::baselines::nitrite_like::NitriteLikeStore;
use rpulsar::baselines::sqlite_like::SqliteLikeStore;
use rpulsar::baselines::RecordStore;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, ThrottledDisk};
use rpulsar::storage::lsm::{LsmOptions, LsmStore};
use rpulsar::util::prng::Prng;
use std::time::Instant;

const QUERIES: usize = 100;
const WINDOWS: usize = 5;

fn pi_disk() -> ThrottledDisk {
    ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
}

/// Records with a controlled set of prefixes so wildcard selectivity is
/// stable across workload sizes.
fn prefixed_records(rng: &mut Prng, n: usize) -> Vec<(String, Vec<u8>)> {
    let prefixes = ["sensa", "sensb", "sensc", "sensd"];
    (0..n)
        .map(|i| {
            let p = prefixes[i % prefixes.len()];
            let key = format!("{p}{:05},lidar", i);
            let mut v = vec![0u8; 256];
            rng.fill_bytes(&mut v);
            (key, v)
        })
        .collect()
}

fn main() {
    let smoke = smoke_mode();
    header(
        "Fig. 7 — wildcard-query performance on Raspberry Pi",
        "same crossover as Fig. 6; wildcard returns multiple results",
    );
    println!(
        "{:<8} {:>18} {:>18} {:>18}",
        "records", "r-pulsar (q/s)", "sqlite-like", "nitrite-like"
    );
    let sizes: &[usize] = if smoke { &[100] } else { &[100, 1_000, 4_000] };
    for &n in sizes {
        let mut rng = Prng::seeded(7);
        let records = prefixed_records(&mut rng, n);

        // R-Pulsar: sorted prefix scan.
        let disk = pi_disk();
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("fig7-{n}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = LsmStore::open(
            LsmOptions { dir, memtable_bytes: 8 << 20, bloom_bits_per_key: 10, max_tables: 8 },
            disk.clone(),
        )
        .unwrap();
        for (k, v) in &records {
            store.put(k.as_bytes(), v).unwrap();
        }
        let rp_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let prefix = ["sensa", "sensb", "sensc", "sensd"][i % 4];
            let hits = store.scan_prefix(prefix.as_bytes()).unwrap();
            assert!(!hits.is_empty());
        });
        let (rp, _) = mean_std(&rp_win);

        // SQLite-like: LIKE 'prefix%' full scan.
        let disk = pi_disk();
        let mut sq = SqliteLikeStore::with_defaults(disk.clone());
        for (k, v) in &records {
            sq.store(k, v).unwrap();
        }
        let sq_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let prefix = ["sensa", "sensb", "sensc", "sensd"][i % 4];
            let hits = sq.query_wildcard(&format!("{prefix}*")).unwrap();
            assert!(!hits.is_empty());
        });
        let (sq_mean, _) = mean_std(&sq_win);

        // Nitrite-like: filter scan with deserialization.
        let disk = pi_disk();
        let mut nit = NitriteLikeStore::with_defaults(disk.clone());
        for (k, v) in &records {
            nit.store(k, v).unwrap();
        }
        let nit_win = windowed_throughput(&disk, QUERIES, WINDOWS, |i| {
            let prefix = ["sensa", "sensb", "sensc", "sensd"][i % 4];
            let hits = nit.query_wildcard(&format!("{prefix}*")).unwrap();
            assert!(!hits.is_empty());
        });
        let (nit_mean, _) = mean_std(&nit_win);

        println!("{n:<8} {rp:>18.1} {sq_mean:>18.1} {nit_mean:>18.1}");
        assert!(
            rp > sq_mean && rp > nit_mean,
            "R-Pulsar must win wildcard queries at n={n}"
        );
    }

    matching_plane_ablation(smoke);
    interval_tree_ablation(smoke);
}

/// Interval-tree ablation: stored profiles are numeric ranges
/// (`zone:lo..hi`), the Fig. 7 query stream stabs and overlaps them.
/// The baseline is the linear interval list the tree replaced: every
/// stored range tested per query via the matching scan. Hit counts
/// must agree exactly; at scale the tree must win clearly.
fn interval_tree_ablation(smoke: bool) {
    header(
        "Fig. 7 ablation — range matching: interval tree vs linear list",
        "sorted-lo prefix + subtree-max-hi pruning replaces the O(ranges) sweep",
    );
    println!(
        "{:<8} {:>16} {:>16} {:>9}",
        "ranges", "tree (q/s)", "list (q/s)", "speedup"
    );
    let sizes: &[usize] = if smoke { &[256] } else { &[1_000, 10_000, 40_000] };
    for &n in sizes {
        // Mostly-short ranges over a wide domain, plus a few giants so
        // subtree-max pruning actually earns its keep.
        let stored: Vec<Profile> = (0..n)
            .map(|i| {
                let lo = (i * 37) % (n * 4);
                let span = if i % 97 == 0 { n } else { 3 + i % 13 };
                Profile::parse(&format!("zone:{lo}..{}", lo + span)).unwrap()
            })
            .collect();
        let mut ix: IndexedProfiles<Profile> = IndexedProfiles::new();
        for p in &stored {
            ix.insert(p.clone());
        }
        let queries = (1_000_000 / n).clamp(100, 1_000);
        // Alternate stabbing (`zone:x`) and overlap (`zone:a..b`).
        let query_at = |i: usize| {
            let x = (i * 131) % (n * 4);
            if i % 2 == 0 {
                Profile::parse(&format!("zone:{x}")).unwrap()
            } else {
                Profile::parse(&format!("zone:{x}..{}", x + 9)).unwrap()
            }
        };

        let t0 = Instant::now();
        let mut list_hits = 0usize;
        for i in 0..queries {
            let q = query_at(i);
            list_hits += stored.iter().filter(|s| matching::matches(&q, s)).count();
        }
        let list_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        let t0 = Instant::now();
        let mut tree_hits = 0usize;
        for i in 0..queries {
            let q = query_at(i);
            tree_hits += ix.query(&q).len();
        }
        let tree_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        assert_eq!(tree_hits, list_hits, "tree and list must agree on every range query");
        let speedup = tree_qps / list_qps;
        println!("{n:<8} {tree_qps:>16.0} {list_qps:>16.0} {speedup:>8.1}x");
        if !smoke && n >= 10_000 {
            assert!(
                speedup >= 3.0,
                "interval tree must be ≥3x the linear list at n={n}, got {speedup:.1}x"
            );
        }
    }
}

/// `indexed` vs `scan` ablation for the partial-keyword (prefix) query
/// shape: stored profiles carry controlled prefixes; queries are
/// selective `sens<c><ddd>*` patterns resolved by the index's prefix
/// buckets versus the seed's linear matching scan.
fn matching_plane_ablation(smoke: bool) {
    header(
        "Fig. 7 ablation — wildcard associative query: indexed vs scan",
        "prefix buckets replace the O(N) pattern-matching scan",
    );
    println!(
        "{:<8} {:>16} {:>16} {:>9}",
        "profiles", "indexed (q/s)", "scan (q/s)", "speedup"
    );
    let sizes: &[usize] = if smoke { &[256] } else { &[1_000, 10_000, 40_000] };
    let prefixes = ["sensa", "sensb", "sensc", "sensd"];
    for &n in sizes {
        let stored: Vec<Profile> = (0..n)
            .map(|i| {
                Profile::parse(&format!("{}{:05},lidar", prefixes[i % 4], i)).unwrap()
            })
            .collect();
        let mut ix: IndexedProfiles<Profile> = IndexedProfiles::new();
        for p in &stored {
            ix.insert(p.clone());
        }
        let queries = (1_000_000 / n).clamp(100, 1_000);
        // Selective partial keywords: "sensa012*" matches the ≤10 stored
        // profiles whose counter falls in one decade of one prefix class.
        let query_at = |i: usize| {
            let decade = (i * 131) % (n / 10).max(1);
            Profile::parse(&format!("{}{:04}*", prefixes[i % 4], decade)).unwrap()
        };

        let t0 = Instant::now();
        let mut scan_hits = 0usize;
        for i in 0..queries {
            let q = query_at(i);
            scan_hits += stored.iter().filter(|s| matching::matches(&q, s)).count();
        }
        let scan_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        let t0 = Instant::now();
        let mut ix_hits = 0usize;
        for i in 0..queries {
            let q = query_at(i);
            ix_hits += ix.query(&q).len();
        }
        let ix_qps = queries as f64 / t0.elapsed().as_secs_f64().max(1e-9);

        assert_eq!(ix_hits, scan_hits, "index and scan must agree on every query");
        let speedup = ix_qps / scan_qps;
        println!("{n:<8} {ix_qps:>16.0} {scan_qps:>16.0} {speedup:>8.1}x");
        if !smoke && n >= 10_000 {
            assert!(
                speedup >= 5.0,
                "indexed arm must be ≥5x the scan arm at n={n}, got {speedup:.1}x"
            );
        }
    }
}
