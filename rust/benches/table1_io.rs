//! Table I: disk vs RAM bandwidth on the Raspberry Pi device model.
//!
//! Regenerates the paper's four-row table (sequential/random ×
//! read/write) by driving the throttled-device substrate with the same
//! access patterns the paper's `dd`/micro-bench measurements used:
//! 64 MiB sequential streams and 4 KiB random blocks.

#[path = "common/mod.rs"]
mod common;

use rpulsar::device::profile::DeviceProfile;
use rpulsar::device::throttle::{ClockMode, Dir, Medium, Pattern, ThrottledDisk};

fn measure(disk: &ThrottledDisk, medium: Medium, pattern: Pattern, dir: Dir) -> f64 {
    disk.reset();
    let total_bytes: usize = 64 << 20;
    match pattern {
        Pattern::Sequential => {
            // One 64 MiB stream in 1 MiB chunks.
            for _ in 0..64 {
                disk.charge(medium, pattern, dir, 1 << 20);
            }
        }
        Pattern::Random => {
            // 4 KiB random blocks.
            for _ in 0..(total_bytes / 4096) {
                disk.charge(medium, pattern, dir, 4096);
            }
        }
    }
    total_bytes as f64 / 1e6 / disk.virtual_elapsed().as_secs_f64()
}

fn main() {
    common::header(
        "Table I — Disk I/O vs RAM on Raspberry Pi",
        "seq read 18.89 vs 631.34 MB/s; seq write 7.12 vs 573.65; \
         rand read 0.78 vs 65.96; rand write 0.15 vs 65.88",
    );
    let disk = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual);
    println!("{:<18} {:>12} {:>12}", "Operation", "Disk", "RAM");
    let rows = [
        ("Sequential read", Pattern::Sequential, Dir::Read),
        ("Sequential write", Pattern::Sequential, Dir::Write),
        ("Random read", Pattern::Random, Dir::Read),
        ("Random write", Pattern::Random, Dir::Write),
    ];
    for (label, pattern, dir) in rows {
        let d = measure(&disk, Medium::Disk, pattern, dir);
        let r = measure(&disk, Medium::Ram, pattern, dir);
        println!("{label:<18} {d:>9.2} MB/s {r:>8.2} MB/s");
    }
}
