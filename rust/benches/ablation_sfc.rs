//! Ablation: Hilbert SFC routing vs naive hash placement — the design
//! choice of paper §IV-B. The SFC maps *similar* keywords (shared
//! prefixes, adjacent ranges) to nearby curve positions, so a range or
//! prefix query touches few RPs; hashing scatters them across the whole
//! ring.

#[path = "common/mod.rs"]
mod common;

use common::header;
use rpulsar::ar::profile::Profile;
use rpulsar::overlay::node_id::NodeId;
use rpulsar::overlay::ring::build_converged_tables;
use rpulsar::routing::router::ContentRouter;
use std::collections::BTreeSet;

const NODES: usize = 64;

fn main() {
    header(
        "Ablation — Hilbert SFC routing vs hash placement",
        "motivates §IV-B: prefix queries touch O(clusters) RPs, not O(ring)",
    );
    let ids: Vec<NodeId> = (0..NODES).map(|i| NodeId::from_name(&format!("a-{i}"))).collect();
    let tables = build_converged_tables(&ids, 8);
    let router = ContentRouter::new();

    // 40 sensors sharing the "sens" prefix, stored under both schemes.
    let keywords: Vec<String> = (0..40).map(|i| format!("sens{i:02}")).collect();

    // SFC placement: owner of each simple profile.
    let mut sfc_owners = BTreeSet::new();
    for kw in &keywords {
        let p = Profile::parse(&format!("{kw},lidar")).unwrap();
        let owner = router.owner_for_simple(&p, &tables, ids[0]).unwrap();
        sfc_owners.insert(owner);
    }

    // Hash placement: sha1(profile) → closest node.
    let mut hash_owners = BTreeSet::new();
    for kw in &keywords {
        let key = NodeId::from_name(&format!("{kw},lidar"));
        let owner = ids.iter().min_by_key(|i| i.distance(&key)).copied().unwrap();
        hash_owners.insert(owner);
    }

    println!("40 prefix-similar records over {NODES} nodes:");
    println!("  SFC placement : {} distinct owner RPs", sfc_owners.len());
    println!("  hash placement: {} distinct owner RPs", hash_owners.len());

    // A prefix query `sens*,lidar` must contact every owner.
    let query = Profile::parse("sens*,lidar").unwrap();
    let outcome = router.route(&query, &tables, ids[0]).unwrap();
    println!(
        "\nprefix query `sens*,lidar`: SFC resolves {} cluster(s) → {} RP(s) contacted",
        outcome.clusters.len(),
        outcome.targets.len()
    );
    println!("hash placement would require contacting all {} owner RPs (no cluster structure)", hash_owners.len());

    assert!(
        sfc_owners.len() <= hash_owners.len(),
        "SFC must co-locate similar keywords at least as well as hashing"
    );
    assert!(
        outcome.targets.len() <= hash_owners.len().max(1),
        "SFC query fan-out must not exceed hash fan-out"
    );

    // And the SFC query must actually find every record's owner.
    for kw in &keywords {
        let p = Profile::parse(&format!("{kw},lidar")).unwrap();
        let owner = router.owner_for_simple(&p, &tables, ids[0]).unwrap();
        assert!(
            outcome.targets.contains(&owner),
            "query targets must cover owner of {kw}"
        );
    }
    println!("\ncoverage check: every record owner is inside the query's target set ✓");
}
