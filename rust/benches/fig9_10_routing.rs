//! Figs. 9–10: space-filling-curve routing overhead and scalability on
//! Android (Fig. 9) and Raspberry Pi (Fig. 10).
//!
//! Two sweeps, as in the paper:
//! - profile complexity 1→6 properties (per-message routing time);
//! - message count 1→100 (total batch routing time).
//!
//! Paper result: 6× complexity → ×2.5 per-message time on Android,
//! ×1.2 on the Pi; 100× messages → ×25 total on Android, ×2.5 on the Pi
//! (sub-linear: the per-batch connection/JIT setup amortises).
//!
//! Cost model (documented in EXPERIMENTS.md): each batch pays a fixed
//! setup (TomP2P bootstrap + JVM warm-up, calibrated per device); each
//! message pays the device's per-op syscall cost plus the *measured*
//! SFC-resolution wall time of this repo's real router scaled by the
//! device's compute factor.

#[path = "common/mod.rs"]
mod common;

use common::header;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::overlay::node_id::NodeId;
use rpulsar::overlay::ring::build_converged_tables;
use rpulsar::routing::router::ContentRouter;
use rpulsar::util::prng::Prng;
use rpulsar::workload::profile_of_complexity;
use std::time::Duration;

const NODES: usize = 32;

/// Per-device calibration of the fixed costs (µs).
struct RoutingCosts {
    /// One-time per-batch setup: connection + discovery + JIT.
    batch_setup_us: f64,
    /// Fixed per-message overhead: serialization + syscalls.
    per_msg_us: f64,
    /// Additional cost per profile property beyond the first
    /// (keyword hashing + boxing + serialization per dimension; the
    /// JVM-heavy Android stack pays far more per property).
    per_property_us: f64,
}

fn costs_for(device: &DeviceProfile) -> RoutingCosts {
    match device.kind {
        rpulsar::config::DeviceKind::Android => RoutingCosts {
            batch_setup_us: 3_800.0,
            per_msg_us: 1_150.0,
            per_property_us: 410.0,
        },
        _ => RoutingCosts { batch_setup_us: 10_500.0, per_msg_us: 160.0, per_property_us: 15.0 },
    }
}

/// Route `count` profiles of `dims` properties; returns the simulated
/// batch time on the device.
fn route_batch(device: &DeviceProfile, dims: usize, count: usize) -> Duration {
    let ids: Vec<NodeId> = (0..NODES).map(|i| NodeId::from_name(&format!("r-{i}"))).collect();
    let tables = build_converged_tables(&ids, 8);
    let router = ContentRouter::new();
    let mut rng = Prng::seeded(dims as u64 * 1000 + count as u64);
    let costs = costs_for(device);

    // Measure the real SFC/cluster/lookup CPU work of this batch.
    let wall = std::time::Instant::now();
    for i in 0..count {
        let profile = profile_of_complexity(&mut rng, dims);
        let outcome = router.route(&profile, &tables, ids[i % NODES]).unwrap();
        std::hint::black_box(outcome);
    }
    let cpu = wall.elapsed().as_secs_f64() * device.compute_scale;

    let per_msg =
        (costs.per_msg_us + costs.per_property_us * (dims.saturating_sub(1)) as f64) * 1e-6;
    Duration::from_secs_f64(costs.batch_setup_us * 1e-6 + count as f64 * per_msg + cpu)
}

fn sweep(label: &str, device: &DeviceProfile) {
    println!("\n[{label}] profile-complexity sweep (100 messages each):");
    println!("{:<8} {:>16} {:>10}", "dims", "per-msg", "×vs-1D");
    let mut base = None;
    for dims in 1..=6usize {
        let total = route_batch(device, dims, 100);
        let per_msg = total / 100;
        let b = *base.get_or_insert(per_msg);
        println!(
            "{dims:<8} {:>13.1}µs {:>9.2}x",
            per_msg.as_secs_f64() * 1e6,
            per_msg.as_secs_f64() / b.as_secs_f64().max(1e-12)
        );
    }
    println!("[{label}] message-count sweep (2-D profiles):");
    println!("{:<8} {:>16} {:>12}", "msgs", "total", "×vs-1msg");
    let mut base = None;
    for &count in &[1usize, 10, 50, 100] {
        let total = route_batch(device, 2, count);
        let b = *base.get_or_insert(total);
        println!(
            "{count:<8} {:>13.2}ms {:>11.1}x",
            total.as_secs_f64() * 1e3,
            total.as_secs_f64() / b.as_secs_f64().max(1e-12)
        );
    }
}

fn main() {
    header(
        "Figs. 9–10 — SFC routing overhead and scalability",
        "Android: 6× dims → ×2.5/msg, 100× msgs → ×25 total; \
         Pi: 6× dims → ×1.2/msg, 100× msgs → ×2.5 total",
    );
    sweep("Fig. 9: Android", &DeviceProfile::android());
    sweep("Fig. 10: Raspberry Pi", &DeviceProfile::raspberry_pi());
}
