//! Figs. 11–12: store and exact-query scalability on the cloud cluster
//! (the paper's Chameleon deployment → our in-process cluster over the
//! simulated network), workloads W1–W4, cluster sizes 4→64.
//!
//! Paper result: 16× more nodes (4→64) costs only ~4× store runtime
//! (Fig. 11) and ~2.8× query runtime (Fig. 12) for W1 — sub-linear
//! growth from multi-hop overlay routing.

#[path = "common/mod.rs"]
mod common;

use common::header;
use rpulsar::ar::message::{Action, ArMessage};
use rpulsar::ar::profile::Profile;
use rpulsar::config::DeviceKind;
use rpulsar::coordinator::Cluster;
use rpulsar::util::prng::Prng;
use rpulsar::workload::StoreWorkload;
use std::time::Duration;

const SIZES: [usize; 5] = [4, 8, 16, 32, 64];
const OPS: usize = 20;

fn store_msg(profile: &Profile, value: &[u8]) -> ArMessage {
    ArMessage::builder()
        .set_header(profile.clone())
        .set_sender("bench")
        .set_action(Action::Store)
        .set_data(value.to_vec())
        .build()
        .unwrap()
}

fn run(nodes: usize, workload: StoreWorkload) -> (Duration, Duration) {
    let mut cluster = Cluster::new(&format!("scal-{nodes}-{}", workload.name()), nodes, DeviceKind::CloudSmall).unwrap();
    let origin = cluster.ids()[0];
    let mut rng = Prng::seeded(nodes as u64);
    let elements = workload.elements();

    // Generate profiles first so store/query use identical keys.
    let profiles: Vec<Vec<Profile>> = (0..OPS)
        .map(|_| {
            (0..elements)
                .map(|_| {
                    Profile::builder()
                        .add_single(&rng.ascii_lower(8))
                        .add_single(&rng.ascii_lower(6))
                        .build()
                })
                .collect()
        })
        .collect();

    // Store phase.
    cluster.network().reset();
    for batch in &profiles {
        for p in batch {
            cluster.store_replicated(origin, &store_msg(p, &[0u8; 128]), 2).unwrap();
        }
    }
    let store_time = cluster.network().virtual_elapsed() / OPS as u32;

    // Query phase.
    cluster.network().reset();
    for batch in &profiles {
        for p in batch {
            let got = cluster.query_exact(origin, p).unwrap();
            assert!(got.is_some(), "stored key must be found");
        }
    }
    let query_time = cluster.network().virtual_elapsed() / OPS as u32;
    cluster.shutdown().unwrap();
    (store_time, query_time)
}

fn main() {
    header(
        "Figs. 11–12 — store/query scalability (cluster 4→64 nodes)",
        "16× nodes → ~4× store runtime (W1), ~2.8× query runtime (W1)",
    );
    for workload in StoreWorkload::all() {
        println!(
            "\n{} ({} element(s) per operation):",
            workload.name(),
            workload.elements()
        );
        println!("{:<8} {:>16} {:>10} {:>16} {:>10}", "nodes", "store/op", "×", "query/op", "×");
        let mut store_base = None;
        let mut query_base = None;
        for &n in &SIZES {
            let (s, q) = run(n, workload);
            let sb = *store_base.get_or_insert(s);
            let qb = *query_base.get_or_insert(q);
            println!(
                "{n:<8} {:>13.2}ms {:>9.1}x {:>13.2}ms {:>9.1}x",
                s.as_secs_f64() * 1e3,
                s.as_secs_f64() / sb.as_secs_f64().max(1e-12),
                q.as_secs_f64() * 1e3,
                q.as_secs_f64() / qb.as_secs_f64().max(1e-12)
            );
        }
    }
    println!("\n(shape: runtime grows sub-linearly in cluster size, as in the paper)");
}
