//! Fig. 15 (beyond the paper): parallel keyed stream executor ablation —
//! the same topology run serial vs parallel, on two workload shapes:
//!
//! - **CPU-bound arm**: the Fig. 13 analytics chain
//!   (`score*P@IMG->decide->stats@IMG`) where `score` burns cycles on
//!   every tile. Speedup is bounded by physical cores: with ≥4 cores,
//!   parallelism 4 must deliver ≥2× the serial throughput; on 2–3 core
//!   hosts a scaled floor is asserted instead (and noted).
//! - **Latency-bound arm**: a stage that waits on each tuple (an
//!   accelerator/IO round-trip model). Replica parallelism overlaps the
//!   waits, so ≥2× at parallelism 4 is asserted on any host.
//! - **Rescale arm**: the same latency-bound stage scaled 1→4 *live*,
//!   mid-stream — throughput before/during/after the scale-up (the
//!   ≥2× after/before floor is core-count independent), zero tuple
//!   loss asserted, plus full-pipeline output equivalence of the
//!   Fig-13 analytics across a mid-stream 1→4 scale-up — with the
//!   keyed `stats` stage verified (linked-stages introspection) to
//!   stay on the router-free direct-exchange fast path.
//!
//! All arms assert output equivalence — the ablation cannot drift from
//! the property-tested semantics (`rust/tests/stream_parallel.rs`).
//!
//! `-- --test` runs a seconds-long smoke with tiny sizes (CI keeps the
//! arms compiling and running; throughput floors are full-mode only).

#[path = "common/mod.rs"]
mod common;

use common::{header, smoke_mode};
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::workflow::{
    analytics_spec, elastic_analytics_spec, run_rescaling_analytics, run_stream_analytics,
    trace_tuples, StreamReport,
};
use rpulsar::stream::engine::{StageRuntime, StreamEngine};
use rpulsar::stream::operator::{Operator, OperatorKind};
use rpulsar::stream::topology::StageSpec;
use rpulsar::stream::tuple::Tuple;
use std::sync::Arc;
use std::time::{Duration, Instant};

const PARALLELISM: usize = 4;

fn main() {
    header(
        "Fig. 15 — parallel keyed stream executor (serial vs parallel ablation + live rescale)",
        "stage-level parallelism is the throughput lever on constrained edge devices",
    );
    let smoke = smoke_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host cores: {cores}, parallelism: {PARALLELISM}, smoke: {smoke}");

    cpu_bound_arm(smoke, cores);
    latency_bound_arm(smoke);
    rescale_arm(smoke);
    println!("\nfig15 OK");
}

/// CPU-bound arm: Fig. 13 analytics, serial vs `score*4@IMG`.
fn cpu_bound_arm(smoke: bool, cores: usize) {
    let (images, work) = if smoke { (4, 2) } else { (96, 128) };
    let trace = LidarTrace::generate(15, images, 1.0);
    let tuples = trace_tuples(&trace, 512);
    println!("\n[cpu-bound] {} tile tuples, score work={work}", tuples.len());

    let serial = best_of(2, || {
        run_stream_analytics(&analytics_spec(1), tuples.clone(), work).unwrap()
    });
    let parallel = best_of(2, || {
        run_stream_analytics(&analytics_spec(PARALLELISM), tuples.clone(), work).unwrap()
    });
    let speedup = parallel.tuples_per_sec() / serial.tuples_per_sec().max(1e-9);
    row("serial", &serial);
    row(&format!("parallel×{PARALLELISM}"), &parallel);
    println!("cpu-bound speedup: {speedup:.2}×");

    assert_eq!(
        canon(&serial),
        canon(&parallel),
        "parallel analytics must produce the serial outputs"
    );
    if !smoke {
        if cores >= PARALLELISM {
            assert!(
                speedup >= 2.0,
                "parallelism {PARALLELISM} on {cores} cores must be ≥2× serial, got {speedup:.2}×"
            );
        } else {
            // A P-replica stage cannot beat the core count; assert a
            // scaled floor and say so.
            let floor = 0.6 * cores.min(PARALLELISM) as f64;
            println!(
                "note: only {cores} cores — the ≥2× bound needs ≥{PARALLELISM}; asserting ≥{floor:.1}×"
            );
            assert!(
                speedup >= floor,
                "parallelism {PARALLELISM} on {cores} cores must be ≥{floor:.1}× serial, got {speedup:.2}×"
            );
        }
    }
}

/// Latency-bound arm: per-tuple wait stage, serial vs 4 replicas.
/// Replicas overlap waits, so the speedup is core-count independent.
fn latency_bound_arm(smoke: bool) {
    let (count, wait) = if smoke {
        (64usize, Duration::from_micros(300))
    } else {
        (1024usize, Duration::from_micros(500))
    };
    println!("\n[latency-bound] {count} tuples, {wait:?} wait per tuple");
    let serial = best_of_f(2, || run_wait_arm(1, count, wait));
    let parallel = best_of_f(2, || run_wait_arm(PARALLELISM, count, wait));
    let speedup = parallel / serial.max(1e-9);
    println!("serial: {serial:.0} t/s   parallel×{PARALLELISM}: {parallel:.0} t/s   speedup: {speedup:.2}×");
    if !smoke {
        assert!(
            speedup >= 2.0,
            "latency-bound parallelism {PARALLELISM} must be ≥2× serial, got {speedup:.2}×"
        );
    }
}

/// Rescale arm: one elastic latency-bound stage scaled 1→4 live. Three
/// phases of `count` tuples each — before (×1), during (the rescale
/// fires a quarter into the phase), after (×4) — with per-phase
/// throughput, the handoff pause, a ≥2× after/before floor
/// (core-count independent: replicas overlap waits), and a zero-loss
/// check over every sequence number. Then the Fig-13 analytics chain
/// is scaled 1→4 mid-stream and must reproduce the static run's
/// outputs exactly.
fn rescale_arm(smoke: bool) {
    let (count, wait) = if smoke {
        (48usize, Duration::from_micros(300))
    } else {
        (768usize, Duration::from_micros(500))
    };
    println!("\n[rescale] {count} tuples per phase, {wait:?} wait per tuple, live 1→{PARALLELISM}");
    let engine = StreamEngine::new();
    let stage = StageRuntime::elastic(
        StageSpec { name: "wait".into(), parallelism: 1, key: None },
        Arc::new(move || {
            Box::new(OperatorKind::map("wait", move |t| {
                std::thread::sleep(wait);
                t
            })) as Box<dyn Operator>
        }),
    )
    .unwrap();
    let h = engine.launch_stages("fig15rescale", vec![stage]).unwrap();
    let sender = h.sender().unwrap();
    let mut seen: Vec<u64> = Vec::with_capacity(3 * count);

    let mut run_phase = |label: &str, base: usize, rescale_at: Option<usize>| -> f64 {
        let started = Instant::now();
        let tx = sender.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..count {
                tx.send(Tuple::new((base + i) as u64, vec![])).unwrap();
            }
        });
        let mut got = 0usize;
        let mut pause = None;
        while got < count {
            if rescale_at == Some(got) {
                let t0 = Instant::now();
                let report = h.rescale("wait", PARALLELISM).unwrap();
                pause = Some((t0.elapsed(), report.moved_keys));
                assert_eq!(report.to, PARALLELISM);
            }
            seen.push(h.recv().expect("rescale arm ended early").seq);
            got += 1;
        }
        producer.join().unwrap();
        let tps = count as f64 / started.elapsed().as_secs_f64().max(1e-9);
        match pause {
            Some((d, moved)) => println!(
                "  {label:<12} {tps:>10.0} t/s   (handoff pause {d:.2?}, {moved} key snapshot(s) moved)"
            ),
            None => println!("  {label:<12} {tps:>10.0} t/s"),
        }
        tps
    };
    let before = run_phase("before ×1", 0, None);
    let during = run_phase("during", count, Some(count / 4));
    let after = run_phase(&format!("after ×{PARALLELISM}"), 2 * count, None);
    drop(sender); // last live sender — lets finish() drain to completion
    assert!(h.finish().unwrap().is_empty());
    let speedup = after / before.max(1e-9);
    println!("  during/before: {:.2}×   after/before: {speedup:.2}×", during / before.max(1e-9));
    // Zero loss, zero duplication across the live handoff.
    seen.sort_unstable();
    assert_eq!(seen, (0..3 * count as u64).collect::<Vec<_>>(), "rescale arm lost or duplicated tuples");
    if !smoke {
        assert!(
            speedup >= 2.0,
            "live scale-up to {PARALLELISM} must be ≥2× the pre-rescale throughput, got {speedup:.2}×"
        );
    }

    // Output equivalence through the analytics pipeline.
    let (images, work) = if smoke { (4, 2) } else { (24, 16) };
    let trace = LidarTrace::generate(31, images, 1.0);
    let tuples = trace_tuples(&trace, 512);
    let cut = tuples.len() / 2;
    let serial = run_stream_analytics(&analytics_spec(1), tuples.clone(), work).unwrap();
    let (rescaled, report) =
        run_rescaling_analytics(&elastic_analytics_spec(1), tuples, work, "score", PARALLELISM, cut)
            .unwrap();
    assert_eq!((report.from, report.to), (1, PARALLELISM));
    assert_eq!(
        canon(&serial),
        canon(&rescaled),
        "a mid-stream 1→{PARALLELISM} scale-up must not change the analytics outputs"
    );
    // Router-free fast path: the keyed `stats` stage is fed by direct
    // replica→replica exchange, and because elastic exchanges re-wire
    // in place, the link (and the equivalence above) holds across the
    // live rescale.
    assert!(
        rescaled.linked.contains(&"stats".to_string()),
        "stats must stay on the direct-exchange fast path, got {:?}",
        rescaled.linked
    );
    println!(
        "  analytics equivalence across mid-stream 1→{PARALLELISM} scale-up OK ({} outputs, direct-exchange stages {:?})",
        rescaled.outputs.len(),
        rescaled.linked
    );
}

/// Run `count` tuples through a single wait stage with `degree`
/// replicas; returns tuples/sec (outputs drained concurrently).
fn run_wait_arm(degree: usize, count: usize, wait: Duration) -> f64 {
    let engine = StreamEngine::new();
    let make = move || {
        Box::new(OperatorKind::map("wait", move |t| {
            std::thread::sleep(wait);
            t
        })) as Box<dyn Operator>
    };
    let stage = StageRuntime::new(
        StageSpec { name: "wait".into(), parallelism: degree, key: None },
        (0..degree).map(|_| make()).collect(),
    )
    .unwrap();
    let h = engine.launch_stages("fig15wait", vec![stage]).unwrap();
    let sender = h.sender().unwrap();
    let started = Instant::now();
    let producer = std::thread::spawn(move || {
        for i in 0..count {
            sender.send(Tuple::new(i as u64, vec![])).unwrap();
        }
    });
    let mut got = 0usize;
    while got < count {
        h.recv().expect("wait arm ended early");
        got += 1;
    }
    let secs = started.elapsed().as_secs_f64().max(1e-9);
    producer.join().unwrap();
    assert!(h.finish().unwrap().is_empty());
    count as f64 / secs
}

/// Best throughput report over `n` runs (thermal/scheduler noise guard).
fn best_of(n: usize, run: impl Fn() -> StreamReport) -> StreamReport {
    (0..n).map(|_| run()).max_by(|a, b| a.tuples_per_sec().total_cmp(&b.tuples_per_sec())).unwrap()
}

fn best_of_f(n: usize, run: impl Fn() -> f64) -> f64 {
    (0..n).map(|_| run()).fold(f64::MIN, f64::max)
}

fn canon(report: &StreamReport) -> Vec<String> {
    let mut v: Vec<String> =
        report.outputs.iter().map(|t| format!("{:?}", t.fields)).collect();
    v.sort();
    v
}

fn row(label: &str, r: &StreamReport) {
    println!(
        "{label:<12} {:>8} tuples  {:>10.2?}  {:>10.0} t/s  {:>5} outputs",
        r.tuples,
        r.elapsed,
        r.tuples_per_sec(),
        r.outputs.len()
    );
}
