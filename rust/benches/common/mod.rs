//! Shared bench harness (no criterion offline): paper-style tables with
//! mean/σ over repeated windows, plus the R-Pulsar broker adapter used
//! by the messaging figures.
//!
//! Included per-bench via `#[path]`, so each binary only uses a subset.
#![allow(dead_code)]

use rpulsar::ar::profile::Profile;
use rpulsar::baselines::MessageBroker;
use rpulsar::device::throttle::{Dir, Medium, Pattern, ThrottledDisk};
use rpulsar::error::Result;
use rpulsar::mmq::pubsub::Broker;
use rpulsar::mmq::queue::QueueOptions;
use std::time::Duration;

/// Print a figure/table header.
pub fn header(title: &str, paper_claim: &str) {
    println!("\n=== {title} ===");
    println!("paper: {paper_claim}");
}

/// Smoke-run mode (`cargo bench --bench fig… -- --test`): tiny sizes so
/// CI can keep the bench binaries and their ablation arms compiling and
/// running without paying full measurement time.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--test")
}

/// Format bytes compactly.
pub fn fmt_size(bytes: usize) -> String {
    if bytes >= 1 << 20 {
        format!("{}MiB", bytes >> 20)
    } else if bytes >= 1 << 10 {
        format!("{}KiB", bytes >> 10)
    } else {
        format!("{bytes}B")
    }
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0).max(1.0);
    (mean, var.sqrt())
}

/// Simulated-throughput measurement: run `op` `n` times against a
/// virtual-clocked device, in `windows` windows; returns per-window
/// throughputs (ops/simulated-second).
pub fn windowed_throughput(
    disk: &ThrottledDisk,
    n: usize,
    windows: usize,
    mut op: impl FnMut(usize),
) -> Vec<f64> {
    let per_window = (n / windows.max(1)).max(1);
    let mut out = Vec::with_capacity(windows);
    let mut done = 0usize;
    for _ in 0..windows {
        disk.reset();
        for _ in 0..per_window {
            op(done);
            done += 1;
        }
        let secs = disk.virtual_elapsed().as_secs_f64().max(1e-12);
        out.push(per_window as f64 / secs);
    }
    out
}

/// R-Pulsar's broker modelled on a device: real mmap publishes plus
/// device-accurate accounting (RAM append; the producer→RP network hop
/// is charged uniformly by the bench driver for every system).
pub struct RPulsarBroker {
    broker: Broker,
    disk: ThrottledDisk,
    profile: Profile,
}

impl RPulsarBroker {
    pub fn new(name: &str, disk: ThrottledDisk) -> Self {
        let dir = std::env::temp_dir()
            .join("rpulsar-bench")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let broker = Broker::new(QueueOptions {
            dir,
            segment_bytes: 8 << 20,
            max_segments: 4,
            sync_every: 0,
        });
        RPulsarBroker { broker, disk, profile: Profile::parse("bench,topic").unwrap() }
    }

    pub fn disk(&self) -> &ThrottledDisk {
        &self.disk
    }
}

impl MessageBroker for RPulsarBroker {
    fn publish(&mut self, _topic: &str, payload: &[u8]) -> Result<()> {
        // Real mmap append...
        self.broker.publish(&self.profile, payload)?;
        // ...charged at the device's RAM sequential-write bandwidth
        // (the memory-mapped design point, paper Table I).
        self.disk.charge(Medium::Ram, Pattern::Sequential, Dir::Write, payload.len() + 8);
        Ok(())
    }

    fn consume(&mut self, _topic: &str, max: usize) -> Result<Vec<Vec<u8>>> {
        self.broker.subscribe("bench-consumer", self.profile.clone());
        let msgs = self.broker.fetch("bench-consumer", max)?;
        for (_, m) in &msgs {
            self.disk.charge(Medium::Ram, Pattern::Sequential, Dir::Read, m.len());
        }
        Ok(msgs.into_iter().map(|(_, m)| m.to_vec()).collect())
    }

    fn name(&self) -> &'static str {
        "r-pulsar"
    }
}

/// Run a single-producer messaging experiment: `count` messages of
/// `size` bytes through `broker`, charging the producer→RP network hop
/// uniformly. Returns windowed throughputs (msg/s, simulated).
pub fn messaging_run(
    broker: &mut dyn MessageBroker,
    disk: &ThrottledDisk,
    size: usize,
    count: usize,
    windows: usize,
) -> Vec<f64> {
    let payload = vec![0xA5u8; size];
    windowed_throughput(disk, count, windows, |_| {
        disk.charge_network(size + 32);
        broker.publish("bench", &payload).unwrap();
    })
}

/// Pretty-print a series row.
pub fn row(label: &str, cells: &[String]) {
    println!("{label:<22} {}", cells.join("  "));
}

/// Convenience: `Duration` from simulated seconds.
pub fn dur(secs: f64) -> Duration {
    Duration::from_secs_f64(secs.max(0.0))
}
