//! The end-to-end disaster-recovery pipeline (paper §V-B, Figs. 13–14).
//!
//! R-Pulsar path per image: drone → mmap broker (collection) → PJRT
//! pre-processing (the AOT'd Pallas kernel) → IF-THEN rule decision →
//! store-at-edge (LSM) or forward-to-core (network charge at the Pi's
//! uplink). Baseline paths swap the collection layer for the Kafka-like
//! broker, the processing layer for the Edgent-like per-event chain
//! (compute still PJRT — same math for a fair comparison, as in the
//! paper where Edgent ran the same user code), and the storage layer
//! for SQLite-like or Nitrite-like stores.

use super::lidar::LidarTrace;
use crate::overlay::node_id::NodeId;
use crate::stream::deploy::TopologyManager;
use crate::stream::dist::{
    plan_placement, DistributedTopologyManager, MigrationReport, PlacementPlan,
};
use crate::stream::engine::{RescaleReport, StageFactory, StreamEngine};
use crate::stream::operator::{Operator, OperatorKind};
use crate::stream::topology::Topology;
use crate::stream::tuple::Tuple;
use std::sync::Arc;
use crate::baselines::edgent_like::EdgentLikePipeline;
use crate::baselines::kafka_like::KafkaLikeBroker;
use crate::baselines::nitrite_like::NitriteLikeStore;
use crate::baselines::sqlite_like::SqliteLikeStore;
use crate::baselines::{MessageBroker, RecordStore};
use crate::device::profile::DeviceProfile;
use crate::device::throttle::{ClockMode, Dir, Medium, Pattern, ThrottledDisk};
use crate::error::Result;
use crate::mmq::pubsub::Broker;
use crate::mmq::queue::QueueOptions;
use crate::rules::ast::EvalContext;
use crate::rules::engine::{Consequence, Rule, RuleEngine, RuleOutcome};
use crate::runtime::preprocess::PreprocessRuntime;
use crate::storage::lsm::{LsmOptions, LsmStore};
use std::path::Path;
use std::time::Duration;

/// Which baseline stack to run (Fig. 14's comparison pipelines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// Apache Kafka + Apache Edgent + SQLite.
    KafkaEdgentSqlite,
    /// Apache Kafka + Apache Edgent + NitriteDB.
    KafkaEdgentNitrite,
}

/// Result of one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub system: String,
    pub images: usize,
    /// Simulated (device-accurate) end-to-end time.
    pub simulated: Duration,
    /// Wall-clock compute time actually spent (PJRT etc.).
    pub wall_compute: Duration,
    pub stored_at_edge: usize,
    pub forwarded_to_core: usize,
    pub dropped: usize,
}

impl PipelineReport {
    /// Device-accurate response time per image.
    pub fn per_image(&self) -> Duration {
        if self.images == 0 {
            return Duration::ZERO;
        }
        self.total() / self.images as u32
    }

    /// Total response time (the Fig. 14 metric). Compute is already
    /// charged into the simulated clock at the device's `compute_scale`;
    /// on the Native profile (scale 0) fall back to host wall time.
    pub fn total(&self) -> Duration {
        if self.simulated.is_zero() {
            self.wall_compute
        } else {
            self.simulated
        }
    }
}

/// The paper's Listing-4 rule set: forward heavily-damaged images to the
/// core for post-processing, store the rest at the edge, drop unusable
/// tiles.
pub fn paper_rules() -> RuleEngine {
    let mut engine = RuleEngine::new();
    engine.add(
        Rule::builder()
            .with_name("post-process-on-core")
            .with_condition("IF(RESULT >= 10)")
            .unwrap()
            .with_consequence(Consequence::ForwardToCore)
            .with_priority(0)
            .build()
            .unwrap(),
    );
    engine.add(
        Rule::builder()
            .with_name("unusable")
            .with_condition("IF(QUALITY < 0.01)")
            .unwrap()
            .with_consequence(Consequence::Drop)
            .with_priority(1)
            .build()
            .unwrap(),
    );
    engine.add(
        Rule::builder()
            .with_name("store-at-edge")
            .with_condition("IF(RESULT >= 0)")
            .unwrap()
            .with_consequence(Consequence::StoreAtEdge)
            .with_priority(2)
            .build()
            .unwrap(),
    );
    engine
}

/// The end-to-end pipeline harness.
pub struct DisasterRecoveryPipeline {
    runtime: PreprocessRuntime,
    device: DeviceProfile,
    scratch: std::path::PathBuf,
}

impl DisasterRecoveryPipeline {
    /// Load PJRT artifacts and fix the emulated device.
    pub fn new(artifacts_dir: &Path, device: DeviceProfile) -> Result<Self> {
        let scratch = std::env::temp_dir()
            .join("rpulsar-pipeline")
            .join(format!("{}", std::process::id()));
        Ok(DisasterRecoveryPipeline {
            runtime: PreprocessRuntime::load(artifacts_dir)?,
            device,
            scratch,
        })
    }

    /// Run the R-Pulsar stack over a trace.
    pub fn run_rpulsar(&self, trace: &LidarTrace) -> Result<PipelineReport> {
        let disk = ThrottledDisk::new(self.device, ClockMode::Virtual);
        let dir = self.scratch.join("rpulsar");
        let _ = std::fs::remove_dir_all(&dir);
        let mut broker = Broker::new(QueueOptions {
            dir: dir.join("queue"),
            segment_bytes: 8 << 20,
            max_segments: 8,
            sync_every: 0,
        });
        let mut store = LsmStore::open(
            LsmOptions {
                dir: dir.join("store"),
                memtable_bytes: 4 << 20,
                bloom_bits_per_key: 10,
                max_tables: 6,
            },
            disk.clone(),
        )?;
        let rules = paper_rules();
        let profile = crate::ar::profile::Profile::parse("drone,lidar").unwrap();
        broker.subscribe("pipeline", profile.clone());

        let wall = std::time::Instant::now();
        let mut report = base_report("r-pulsar", trace.images.len());
        for img in &trace.images {
            // Collection: drone → broker. The mmap append is RAM-speed;
            // charge the (scaled) network transfer of the whole image
            // and the RAM append of all of its bytes.
            disk.charge_network(img.nominal_bytes);
            let tile_bytes = bytes_of(&img.tile);
            broker.publish(&profile, &tile_bytes)?;
            disk.charge(
                Medium::Ram,
                Pattern::Sequential,
                Dir::Write,
                img.nominal_bytes.max(tile_bytes.len()),
            );
            // Processing: fetch + PJRT preprocess. Host compute time is
            // scaled to the emulated device and multiplied by the
            // image's tile count (identical in every stack).
            let fetched = broker.fetch("pipeline", 1)?;
            let tile = f32s_of(&fetched[0].1);
            let compute_wall = std::time::Instant::now();
            let out = self.runtime.preprocess(&tile)?;
            disk.charge_compute(compute_wall.elapsed() * tiles_of(img.nominal_bytes));
            decide(&rules, out.result, out.quality, img, &disk, &mut store, &mut report)?;
        }
        report.simulated = disk.virtual_elapsed();
        report.wall_compute = wall.elapsed();
        let _ = std::fs::remove_dir_all(&dir);
        Ok(report)
    }

    /// Run a baseline stack (Fig. 14's comparisons) over the same trace.
    pub fn run_baseline(&self, trace: &LidarTrace, kind: BaselineKind) -> Result<PipelineReport> {
        let disk = ThrottledDisk::new(self.device, ClockMode::Virtual);
        let mut kafka = KafkaLikeBroker::with_defaults(disk.clone());
        let mut edgent = EdgentLikePipeline::new(disk.clone())
            .op(|t| Some(t.to_vec())) // parse stage
            .op(|t| Some(t.to_vec())) // feature stage wrapper
            .op(|t| Some(t.to_vec())); // decision stage wrapper
        let mut sqlite;
        let mut nitrite;
        let store: &mut dyn RecordStore = match kind {
            BaselineKind::KafkaEdgentSqlite => {
                sqlite = SqliteLikeStore::with_defaults(disk.clone());
                &mut sqlite
            }
            BaselineKind::KafkaEdgentNitrite => {
                nitrite = NitriteLikeStore::with_defaults(disk.clone());
                &mut nitrite
            }
        };
        let rules = paper_rules();
        let name = match kind {
            BaselineKind::KafkaEdgentSqlite => "kafka+edgent+sqlite",
            BaselineKind::KafkaEdgentNitrite => "kafka+edgent+nitrite",
        };

        let wall = std::time::Instant::now();
        let mut report = base_report(name, trace.images.len());
        for img in &trace.images {
            disk.charge_network(img.nominal_bytes);
            let tile_bytes = bytes_of(&img.tile);
            kafka.publish("drone.lidar", &tile_bytes)?;
            // Kafka persists the *whole* image to its log (the paper's
            // broker receives every byte); charge the remainder beyond
            // the tile actually carried in-process.
            if img.nominal_bytes > tile_bytes.len() {
                disk.charge(
                    Medium::Disk,
                    Pattern::Sequential,
                    Dir::Write,
                    img.nominal_bytes - tile_bytes.len(),
                );
            }
            let fetched = kafka.consume("drone.lidar", 1)?;
            if img.nominal_bytes > tile_bytes.len() {
                disk.charge(
                    Medium::Disk,
                    Pattern::Sequential,
                    Dir::Read,
                    img.nominal_bytes - tile_bytes.len(),
                );
            }
            // Edgent chain invocation overhead per event.
            edgent.process(&fetched[0][..64.min(fetched[0].len())])?;
            let tile = f32s_of(&fetched[0]);
            let compute_wall = std::time::Instant::now();
            let out = self.runtime.preprocess(&tile)?;
            disk.charge_compute(compute_wall.elapsed() * tiles_of(img.nominal_bytes));
            // Decision + storage through the baseline store.
            let ctx = EvalContext::new()
                .with("RESULT", out.result as f64)
                .with("QUALITY", out.quality as f64);
            match rules.evaluate(&ctx) {
                RuleOutcome::Fired { consequence: Consequence::ForwardToCore, .. } => {
                    disk.charge_network(img.nominal_bytes);
                    report.forwarded_to_core += 1;
                }
                RuleOutcome::Fired { consequence: Consequence::Drop, .. } => {
                    report.dropped += 1;
                }
                _ => {
                    store.store(&format!("drone,lidar,{}", img.id), &bytes_of(&out.stats))?;
                    report.stored_at_edge += 1;
                }
            }
        }
        report.simulated = disk.virtual_elapsed();
        report.wall_compute = wall.elapsed();
        Ok(report)
    }
}

// ---- Stream-plane analytics (Fig. 13 as a parallel keyed topology) ----

/// The Fig. 13 analytics chain in the annotated topology spec:
/// CPU-bound tile scoring fanned across `parallelism` replicas (keyed
/// by image so per-image tile order survives the shuffle), a serial
/// rule-decision stage, and a per-image keyed window of tile scores.
pub fn analytics_spec(parallelism: usize) -> String {
    if parallelism <= 1 {
        "score->decide->stats@IMG".to_string()
    } else {
        format!("score*{parallelism}@IMG->decide->stats@IMG")
    }
}

/// The analytics chain with the CPU stage keyed *even at parallelism 1*
/// — the spec to deploy when the topology may be re-scaled live. The
/// `@IMG` annotation is inert while serial, but it tells a later
/// `rescale` how to partition: without it a scale-up degrades to
/// round-robin and per-image tile order (which the stats windows
/// depend on) is lost.
pub fn elastic_analytics_spec(parallelism: usize) -> String {
    if parallelism <= 1 {
        "score@IMG->decide->stats@IMG".to_string()
    } else {
        analytics_spec(parallelism)
    }
}

/// The analytics stage factories, shared between local and distributed
/// registration. `work` scales the per-tile scoring cost (1 ≈ one pass
/// over the payload).
fn analytics_stage_factories(work: u32) -> Vec<(&'static str, StageFactory)> {
    vec![
        (
            "score",
            Arc::new(move || {
                Box::new(OperatorKind::map("score", move |mut t| {
                    let (result, quality) = edge_score(&t.payload, work);
                    t.set("RESULT", result);
                    t.set("QUALITY", quality);
                    t
                })) as Box<dyn Operator>
            }) as StageFactory,
        ),
        (
            "decide",
            Arc::new(|| Box::new(OperatorKind::rules("decide", paper_rules())) as Box<dyn Operator>)
                as StageFactory,
        ),
        (
            "stats",
            Arc::new(|| {
                Box::new(OperatorKind::window_by("stats", "RESULT", 8, "IMG")) as Box<dyn Operator>
            }) as StageFactory,
        ),
    ]
}

/// Register the analytics stages on a [`TopologyManager`]. `work`
/// scales the per-tile scoring cost (1 ≈ one pass over the payload).
pub fn register_analytics_stages(manager: &mut TopologyManager, work: u32) {
    for (name, factory) in analytics_stage_factories(work) {
        manager.register_stage_factory(name, factory);
    }
}

/// Register the analytics stages on every node of a
/// [`DistributedTopologyManager`].
pub fn register_analytics_stages_dist(dist: &mut DistributedTopologyManager, work: u32) {
    for (name, factory) in analytics_stage_factories(work) {
        dist.register_stage_factory(name, factory);
    }
}

/// Deterministic CPU-bound edge-density proxy over a tile payload:
/// `work` FNV+gradient passes. Pure function of `(payload, work)`, so
/// serial and parallel topologies score identically — the equivalence
/// hook for the fig15 ablation.
pub fn edge_score(payload: &[u8], work: u32) -> (f64, f64) {
    let mut acc: u64 = 0xcbf2_9ce4_8422_2325;
    let mut grad: u64 = 0;
    for _ in 0..work.max(1) {
        let mut prev = 0u8;
        for &b in payload {
            acc = (acc ^ b as u64).wrapping_mul(0x100_0000_01b3);
            grad = grad.wrapping_add(b.abs_diff(prev) as u64);
            prev = b;
        }
        acc = acc.rotate_left(7);
    }
    let result = (acc % 41) as f64; // paper rules: ≥10 forwards to core
    let quality = (grad % 101) as f64 / 100.0; // <0.01 drops the tile
    (result, quality)
}

/// Tile tuples for a LiDAR trace: one tuple per synthetic tile slice,
/// keyed by image id (`IMG`).
pub fn trace_tuples(trace: &LidarTrace, tile_slice_bytes: usize) -> Vec<Tuple> {
    let slice = tile_slice_bytes.max(16);
    let mut tuples = Vec::new();
    let mut seq = 0u64;
    for img in &trace.images {
        let bytes = bytes_of(&img.tile);
        for chunk in bytes.chunks(slice).take(tiles_of(img.nominal_bytes) as usize) {
            tuples.push(Tuple::new(seq, chunk.to_vec()).with("IMG", img.id as f64));
            seq += 1;
        }
    }
    tuples
}

/// Report of one stream-plane analytics run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub spec: String,
    pub tuples: usize,
    pub outputs: Vec<Tuple>,
    pub elapsed: Duration,
    /// Stages wired replica→replica (direct exchange, router-free) —
    /// the executor's own introspection, captured at deploy time.
    pub linked: Vec<String>,
}

impl StreamReport {
    /// Input tuples per wall-clock second.
    pub fn tuples_per_sec(&self) -> f64 {
        self.tuples as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

/// Drive `tuples` through the analytics topology `spec`: a producer
/// thread feeds batches while `stop` drains concurrently on this
/// thread (`finish` keeps consuming outputs until the producer's
/// sender clone drops — the backpressure contract — so no polling
/// thread competes with the replicas for cores).
pub fn run_stream_analytics(spec: &str, tuples: Vec<Tuple>, work: u32) -> Result<StreamReport> {
    let mut manager = TopologyManager::new(StreamEngine::new());
    register_analytics_stages(&mut manager, work);
    manager.start("analytics", spec)?;
    let linked = manager.linked_stages("analytics")?;
    let count = tuples.len();
    let sender = manager.sender("analytics")?;
    let started = std::time::Instant::now();
    let producer = std::thread::spawn(move || -> Result<()> {
        let mut it = tuples.into_iter();
        loop {
            let batch: Vec<Tuple> = it.by_ref().take(64).collect();
            if batch.is_empty() {
                return Ok(());
            }
            sender.send_batch(batch)?;
        }
    });
    let stopped = manager.stop("analytics");
    let produced = producer.join().expect("producer thread panicked");
    let outputs = stopped?;
    produced?;
    Ok(StreamReport {
        spec: spec.to_string(),
        tuples: count,
        outputs,
        elapsed: started.elapsed(),
        linked,
    })
}

/// Drive `tuples` through the analytics topology like
/// [`run_stream_analytics`], but live-rescale `stage` to `to` replicas
/// mid-stream, once `rescale_after` tuples have been fed (paper §IV-C2
/// "scaling up or down" — without stopping the pipeline). The producer
/// thread issues the rescale itself so feeding and scaling interleave
/// exactly as they would on an edge node reacting to load. Returns the
/// run report plus the rescale report; the output multiset must equal a
/// static run's — asserted by the fig15 rescale arm and the tests
/// below.
pub fn run_rescaling_analytics(
    spec: &str,
    tuples: Vec<Tuple>,
    work: u32,
    stage: &str,
    to: usize,
    rescale_after: usize,
) -> Result<(StreamReport, RescaleReport)> {
    let mut manager = TopologyManager::new(StreamEngine::new());
    register_analytics_stages(&mut manager, work);
    manager.start("analytics", spec)?;
    let linked = manager.linked_stages("analytics")?;
    let count = tuples.len();
    let sender = manager.sender("analytics")?;
    let rescaler = manager.rescaler("analytics")?;
    let stage = stage.to_string();
    let started = std::time::Instant::now();
    let producer = std::thread::spawn(move || -> Result<RescaleReport> {
        let mut it = tuples.into_iter();
        let mut fed = 0usize;
        let mut report = None;
        loop {
            if report.is_none() && fed >= rescale_after {
                report = Some(rescaler.rescale(&stage, to)?);
            }
            let batch: Vec<Tuple> = it.by_ref().take(64).collect();
            if batch.is_empty() {
                break;
            }
            fed += batch.len();
            sender.send_batch(batch)?;
        }
        match report {
            Some(r) => Ok(r),
            // Stream shorter than the cut point: rescale at the end.
            None => rescaler.rescale(&stage, to),
        }
    });
    let stopped = manager.stop("analytics");
    let produced = producer.join().expect("producer thread panicked");
    let outputs = stopped?;
    let report = produced?;
    Ok((
        StreamReport {
            spec: spec.to_string(),
            tuples: count,
            outputs,
            elapsed: started.elapsed(),
            linked,
        },
        report,
    ))
}

// ---- Distributed stream analytics (Fig-13 split edge → cloud) ----

/// Report of one distributed analytics run: the stream metrics plus
/// what the cross-node hops cost on the simulated network.
#[derive(Debug, Clone)]
pub struct DistStreamReport {
    pub spec: String,
    /// Human-readable fragment placement (`pi:[score->decide] → cloud:[stats@IMG]`).
    pub placement: String,
    pub tuples: usize,
    pub outputs: Vec<Tuple>,
    pub elapsed: Duration,
    /// Bytes shipped between nodes (`StreamBatch` frames, wire-sized).
    pub net_bytes: u64,
    /// Inter-node messages (one per shipped batch).
    pub net_messages: u64,
    /// Device-accurate virtual network time those hops cost.
    pub net_virtual: Duration,
    /// Codec encodes on the hop path (`net.hop.encodes`): the
    /// encode-once contract means this equals `net_messages`.
    pub hop_encodes: u64,
    /// Wire buffers served from the pool instead of allocated
    /// (`net.hop.buffer_reuses`).
    pub hop_buffer_reuses: u64,
    /// Bytes encoded onto the hop path (`net.hop.bytes`).
    pub hop_bytes: u64,
    /// Live fragment migrations the route underwent during the run
    /// (empty unless an elasticity scenario moved fragments mid-run).
    pub migrations: Vec<MigrationReport>,
}

impl DistStreamReport {
    /// Input tuples per wall-clock second.
    pub fn tuples_per_sec(&self) -> f64 {
        self.tuples as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

/// Drive `tuples` through the Fig-13 analytics topology placed across
/// a two-node SimNetwork cluster — a Raspberry Pi source node and a
/// `cloud_small` core node. With `split`, the placement planner puts
/// the source-adjacent stages (`score`, `decide`) on the Pi and the
/// `stats` aggregation on the cloud node, shipping tuple batches as
/// `NetMessage::StreamBatch` over the simulated network; without it
/// the whole chain runs on the Pi node (no hops, zero network bytes).
/// Output equivalence between the two placements — and with the plain
/// single-process `run_stream_analytics` — is asserted by
/// `benches/fig16_distributed_stream.rs` and `rust/tests/cluster.rs`.
pub fn run_distributed_analytics(
    spec: &str,
    tuples: Vec<Tuple>,
    work: u32,
    split: bool,
) -> Result<DistStreamReport> {
    run_distributed_analytics_opts(spec, tuples, work, split, false)
}

/// [`run_distributed_analytics`] with the net-plane mode explicit:
/// `sync_pump` forces the legacy synchronous pump (hops moved inline on
/// the producer thread) — the fig16 ablation axis. `false` keeps the
/// process default: background shippers, unless `RPULSAR_NETPLANE=sync`
/// turned them off globally.
pub fn run_distributed_analytics_opts(
    spec: &str,
    tuples: Vec<Tuple>,
    work: u32,
    split: bool,
    sync_pump: bool,
) -> Result<DistStreamReport> {
    let mut dist = DistributedTopologyManager::new();
    if sync_pump {
        dist.set_async_shippers(false);
    }
    let pi = NodeId::from_name("edge-pi");
    let cloud = NodeId::from_name("cloud-core");
    dist.add_node(pi, DeviceProfile::raspberry_pi());
    dist.add_node(cloud, DeviceProfile::cloud_small());
    register_analytics_stages_dist(&mut dist, work);
    let topo = Topology::parse("analytics", spec)?;
    let plan = if split {
        plan_placement(&topo, pi, &dist.profiles(), &["stats"])?
    } else {
        PlacementPlan::single(pi, &topo)
    };
    let placement = plan
        .fragments
        .iter()
        .map(|f| format!("{}:[{}]", if f.node == pi { "pi" } else { "cloud" }, f.spec()))
        .collect::<Vec<_>>()
        .join(" → ");
    dist.start("analytics", spec, &plan)?;
    let count = tuples.len();
    let started = std::time::Instant::now();
    let mut iter = tuples.into_iter();
    loop {
        let batch: Vec<Tuple> = iter.by_ref().take(64).collect();
        if batch.is_empty() {
            break;
        }
        dist.send_batch("analytics", batch)?;
    }
    let migrations =
        dist.route("analytics").map(|r| r.migrations().to_vec()).unwrap_or_default();
    let outputs = dist.stop("analytics")?;
    Ok(DistStreamReport {
        spec: spec.to_string(),
        placement,
        tuples: count,
        outputs,
        elapsed: started.elapsed(),
        net_bytes: dist.network().bytes(),
        net_messages: dist.network().messages(),
        net_virtual: dist.network().virtual_elapsed(),
        hop_encodes: dist.metrics().counter("net.hop.encodes").get(),
        hop_buffer_reuses: dist.metrics().counter("net.hop.buffer_reuses").get(),
        hop_bytes: dist.metrics().counter("net.hop.bytes").get(),
        migrations,
    })
}

/// How many 256×256 tiles an image of `nominal` bytes decomposes into
/// (the pipeline processes every tile; compute scales with image size,
/// as in the paper's 1.8 KB – 33.8 MB dataset).
fn tiles_of(nominal: usize) -> u32 {
    ((nominal + TILE_BYTES - 1) / TILE_BYTES).clamp(1, 64) as u32
}

/// Bytes of one 256×256 f32 tile.
const TILE_BYTES: usize = 256 * 256 * 4;

fn base_report(system: &str, images: usize) -> PipelineReport {
    PipelineReport {
        system: system.to_string(),
        images,
        simulated: Duration::ZERO,
        wall_compute: Duration::ZERO,
        stored_at_edge: 0,
        forwarded_to_core: 0,
        dropped: 0,
    }
}

fn decide(
    rules: &RuleEngine,
    result: f32,
    quality: f32,
    img: &super::lidar::LidarImage,
    disk: &ThrottledDisk,
    store: &mut LsmStore,
    report: &mut PipelineReport,
) -> Result<()> {
    let ctx = EvalContext::new()
        .with("RESULT", result as f64)
        .with("QUALITY", quality as f64);
    match rules.evaluate(&ctx) {
        RuleOutcome::Fired { consequence: Consequence::ForwardToCore, .. } => {
            // Send the image to the cloud for post-processing.
            disk.charge_network(img.nominal_bytes);
            report.forwarded_to_core += 1;
        }
        RuleOutcome::Fired { consequence: Consequence::Drop, .. } => {
            report.dropped += 1;
        }
        _ => {
            store.put(format!("drone,lidar,{}", img.id).as_bytes(), &[0u8; 64])?;
            report.stored_at_edge += 1;
        }
    }
    Ok(())
}

fn bytes_of(f: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(f.len() * 4);
    for v in f {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn f32s_of(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

// End-to-end tests (needing artifacts) live in rust/tests/integration.rs;
// here only the pure helpers are unit-tested.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_conversions_round_trip() {
        let f = vec![1.5f32, -2.25, 0.0, 1e9];
        assert_eq!(f32s_of(&bytes_of(&f)), f);
    }

    #[test]
    fn paper_rules_decide_as_listing4() {
        let rules = paper_rules();
        // High edge density → forward to core.
        let hot = EvalContext::new().with("RESULT", 35.0).with("QUALITY", 1.0);
        assert!(matches!(
            rules.evaluate(&hot),
            RuleOutcome::Fired { consequence: Consequence::ForwardToCore, .. }
        ));
        // Flat, low-quality tile → dropped.
        let junk = EvalContext::new().with("RESULT", 0.5).with("QUALITY", 0.001);
        assert!(matches!(
            rules.evaluate(&junk),
            RuleOutcome::Fired { consequence: Consequence::Drop, .. }
        ));
        // Normal tile → stored at the edge.
        let calm = EvalContext::new().with("RESULT", 3.0).with("QUALITY", 0.8);
        assert!(matches!(
            rules.evaluate(&calm),
            RuleOutcome::Fired { consequence: Consequence::StoreAtEdge, .. }
        ));
    }

    #[test]
    fn report_per_image_math() {
        let mut r = base_report("x", 10);
        r.simulated = Duration::from_millis(900);
        r.wall_compute = Duration::from_millis(100); // bookkeeping only
        assert_eq!(r.per_image(), Duration::from_millis(90));
        assert_eq!(r.total(), Duration::from_millis(900));
        // Native profile: nothing lands on the virtual clock → wall time.
        let mut native = base_report("n", 10);
        native.wall_compute = Duration::from_millis(50);
        assert_eq!(native.total(), Duration::from_millis(50));
        let empty = base_report("y", 0);
        assert_eq!(empty.per_image(), Duration::ZERO);
    }

    #[test]
    fn edge_score_is_deterministic_and_scales_with_work() {
        let payload = vec![7u8, 200, 3, 99, 250, 1];
        assert_eq!(edge_score(&payload, 3), edge_score(&payload, 3));
        let (r, q) = edge_score(&payload, 2);
        assert!((0.0..41.0).contains(&r));
        assert!((0.0..=1.0).contains(&q));
        // Different payloads should (virtually always) score apart.
        assert_ne!(edge_score(&payload, 2), edge_score(&[1, 2, 3], 2));
    }

    #[test]
    fn analytics_spec_shapes() {
        assert_eq!(analytics_spec(1), "score->decide->stats@IMG");
        assert_eq!(analytics_spec(4), "score*4@IMG->decide->stats@IMG");
        assert_eq!(elastic_analytics_spec(1), "score@IMG->decide->stats@IMG");
        assert_eq!(elastic_analytics_spec(4), analytics_spec(4));
        // All forms parse as valid topologies.
        for p in [1, 2, 4] {
            rpulsar_parse(&analytics_spec(p));
            rpulsar_parse(&elastic_analytics_spec(p));
        }
    }

    fn rpulsar_parse(spec: &str) {
        crate::stream::topology::Topology::parse("t", spec).unwrap();
    }

    #[test]
    fn stream_analytics_serial_parallel_equivalent() {
        let trace = LidarTrace::generate(7, 6, 0.2);
        let tuples = trace_tuples(&trace, 512);
        assert!(!tuples.is_empty());
        let serial = run_stream_analytics(&analytics_spec(1), tuples.clone(), 1).unwrap();
        let parallel = run_stream_analytics(&analytics_spec(3), tuples, 1).unwrap();
        assert_eq!(serial.tuples, parallel.tuples);
        let canon = |r: &StreamReport| {
            let mut v: Vec<String> = r.outputs.iter().map(|t| format!("{:?}", t.fields)).collect();
            v.sort();
            v
        };
        assert_eq!(canon(&serial), canon(&parallel), "spec: {}", parallel.spec);
        assert!(!serial.outputs.is_empty(), "keyed stats windows must emit aggregates");
        assert!(serial.tuples_per_sec() > 0.0);
    }

    #[test]
    fn rescaled_analytics_equals_static_run() {
        // A mid-stream 1→3 scale-up of the CPU stage must reproduce the
        // static pipeline's outputs exactly: the keyed shuffle plus the
        // state handoff keep the per-image stats windows intact.
        let trace = LidarTrace::generate(9, 6, 0.2);
        let tuples = trace_tuples(&trace, 512);
        let cut = tuples.len() / 2;
        let serial = run_stream_analytics(&analytics_spec(1), tuples.clone(), 1).unwrap();
        let (rescaled, report) =
            run_rescaling_analytics(&elastic_analytics_spec(1), tuples, 1, "score", 3, cut)
                .unwrap();
        assert_eq!((report.from, report.to), (1, 3));
        assert_eq!(serial.tuples, rescaled.tuples);
        let canon = |r: &StreamReport| {
            let mut v: Vec<String> = r.outputs.iter().map(|t| format!("{:?}", t.fields)).collect();
            v.sort();
            v
        };
        assert_eq!(canon(&serial), canon(&rescaled), "spec: {}", rescaled.spec);
        assert!(!rescaled.outputs.is_empty());
    }

    #[test]
    fn distributed_split_analytics_equals_local_run() {
        // The flagship scenario: Fig-13 analytics split Pi → cloud must
        // reproduce the single-process run's output multiset exactly,
        // and the split placement must actually use the network.
        let trace = LidarTrace::generate(11, 5, 0.2);
        let tuples = trace_tuples(&trace, 512);
        let local = run_stream_analytics(&analytics_spec(1), tuples.clone(), 1).unwrap();
        let split = run_distributed_analytics(&analytics_spec(1), tuples.clone(), 1, true).unwrap();
        let single = run_distributed_analytics(&analytics_spec(1), tuples, 1, false).unwrap();
        let canon_t = |outs: &[Tuple]| {
            let mut v: Vec<String> = outs.iter().map(|t| format!("{:?}", t.fields)).collect();
            v.sort();
            v
        };
        assert_eq!(canon_t(&local.outputs), canon_t(&split.outputs), "{}", split.placement);
        assert_eq!(canon_t(&local.outputs), canon_t(&single.outputs));
        assert!(split.placement.contains("pi:[") && split.placement.contains("cloud:[stats"),
            "source stages on the Pi, aggregation on the cloud: {}", split.placement);
        assert!(split.net_bytes > 0 && split.net_messages > 0, "split must ship batches");
        assert!(split.net_virtual > Duration::ZERO, "hops must cost virtual network time");
        assert_eq!(single.net_bytes, 0, "single-node placement must not touch the net");
    }

    #[test]
    fn tiles_of_scales_with_image_size() {
        assert_eq!(tiles_of(1_000), 1);
        assert_eq!(tiles_of(TILE_BYTES), 1);
        assert_eq!(tiles_of(TILE_BYTES + 1), 2);
        assert_eq!(tiles_of(10 * TILE_BYTES), 10);
        assert_eq!(tiles_of(usize::MAX / 2), 64); // clamped
    }
}
