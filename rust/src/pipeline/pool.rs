//! Warm pipeline pools: cold-start engineering for the trigger plane.
//!
//! A cold start pays the full [`Deployer::deploy`] path — validation,
//! factory resolution, operator construction, channel wiring, replica
//! threads. The serverless-edge literature treats that latency as the
//! decisive metric, so the trigger plane keeps a bounded pool of
//! *warm* pipelines: deployed-but-idle instances a re-activation can
//! take over in O(map lookup) instead of a full deploy.
//!
//! **Mechanism → policy split** (the [`RetirePolicy`] idiom): the pool
//! is pure mechanism; [`WarmPolicy`] decides capacity, whether
//! stateful pipelines get a pre-built standby, and when a parked entry
//! has sat too long. The default policy has `capacity: 0` — warm
//! pooling is strictly opt-in and every pre-existing trigger lifecycle
//! (deploy on data, stop on idle) is unchanged without it.
//!
//! **Statefulness rule.** A *stateless* pipeline is parked live: its
//! replicas keep running, in-flight outputs are surfaced on the next
//! activation, and taking it back is a pure re-attach. A *stateful*
//! pipeline can NOT be parked live — open windows would carry state
//! across what the contract says is a scale-to-zero boundary, and the
//! warm path would diverge from the cold path (whose
//! [`Deployer::stop`] flushes partial windows through
//! `Operator::finish`). So a stateful park performs the flushing stop
//! (the tail goes to the binding's outputs, exactly as a cold
//! decommission would), and — when `prebuild` is on — deploys a
//! *fresh standby* off the activation path, so the next activation
//! still skips the deploy. With a [`SnapshotSource`] attached the
//! standby is additionally seeded from the binding's latest checkpoint
//! snapshot via [`Deployer::seed_state`] — warm *resume* for
//! checkpointed jobs. Warm ≡ cold output equivalence is
//! property-tested in `rust/tests/trigger_scale.rs` and pre-validated
//! by `python/sims/trigger_scale_sim.py`.
//!
//! **Eviction.** Capacity overflow, idle expiry ([`WarmPool::sweep`])
//! and memory-pressure reclaim ([`WarmPool::reclaim`]) all evict
//! coldest-first (oldest `parked_at`). An evicted entry is stopped
//! through the deployer and its drain tail is routed back to the
//! owning binding — eviction never loses tuples. Counted in
//! `trigger.pool_evictions`.
//!
//! [`RetirePolicy`]: crate::mmq::pubsub::RetirePolicy

use crate::error::Result;
use crate::metrics::Registry;
use crate::stream::checkpoint::StageStates;
use crate::stream::pipeline::{Deployer, Pipeline, PipelineHandle};
use crate::stream::tuple::Tuple;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Provider of the latest checkpointed per-stage state for a binding —
/// typically a closure over `CheckpointJournal::latest`. Returning
/// `None` means "no snapshot for this binding": the standby deploys
/// empty, exactly as without a source.
pub type SnapshotSource = Arc<dyn Fn(&str) -> Option<StageStates> + Send + Sync>;

/// Policy half of the warm pool: how many decommissioned pipelines to
/// retain, whether stateful pipelines get a pre-built standby, and how
/// long a parked entry may sit before the sweep evicts it.
#[derive(Debug, Clone)]
pub struct WarmPolicy {
    /// Max parked pipelines. `0` disables warm pooling entirely
    /// (every decommission is a plain stop — the pre-PR-9 lifecycle).
    pub capacity: usize,
    /// Deploy a fresh standby when a *stateful* pipeline is parked
    /// (its live instance must flush, see the module docs). Off, a
    /// stateful decommission is a plain stop and the next activation
    /// is cold.
    pub prebuild: bool,
    /// Parked entries older than this are evicted by
    /// [`WarmPool::sweep`] — warmth has a shelf life; an edge node
    /// should not hold replicas for a tenant that went quiet an hour
    /// ago.
    pub max_idle: Duration,
}

impl Default for WarmPolicy {
    fn default() -> Self {
        WarmPolicy::disabled()
    }
}

impl WarmPolicy {
    /// No warm pooling (the default): decommission means stop.
    pub fn disabled() -> Self {
        WarmPolicy { capacity: 0, prebuild: true, max_idle: Duration::from_secs(300) }
    }

    /// Retain up to `capacity` warm pipelines with the default
    /// prebuild/expiry knobs.
    pub fn retain(capacity: usize) -> Self {
        WarmPolicy { capacity, ..WarmPolicy::disabled() }
    }

    /// Whether a pool currently holding `resident` entries may accept
    /// one more without evicting.
    pub fn admits(&self, resident: usize) -> bool {
        resident < self.capacity
    }

    /// Whether an entry parked `parked` ago has expired.
    pub fn expired(&self, parked: Duration) -> bool {
        parked >= self.max_idle
    }
}

struct WarmEntry {
    handle: PipelineHandle,
    parked_at: Instant,
}

/// What a park produced: the flushed tail of the parked pipeline (to
/// the owner's outputs) plus the drain tails of anything evicted to
/// make room (routed to *their* owners by the caller).
pub struct ParkOutcome {
    /// Flush tail of the pipeline being parked (empty for a stateless
    /// live-park).
    pub tail: Vec<Tuple>,
    /// `(binding, drain tail)` for each entry evicted by capacity.
    pub evicted: Vec<(String, Vec<Tuple>)>,
}

/// Mechanism half: the bounded map of parked pipelines, keyed by
/// binding name. Owned by a `BindingRunner`; all mutations that touch
/// live topologies take the runner's deployer.
pub struct WarmPool {
    policy: WarmPolicy,
    entries: BTreeMap<String, WarmEntry>,
    metrics: Registry,
    snapshots: Option<SnapshotSource>,
}

impl WarmPool {
    pub fn new(policy: WarmPolicy, metrics: Registry) -> Self {
        WarmPool { policy, entries: BTreeMap::new(), metrics, snapshots: None }
    }

    /// Opt into checkpoint-seeded standbys: a stateful prebuild asks
    /// `source` for the binding's latest snapshot and seeds it into the
    /// fresh standby through [`Deployer::seed_state`] — the standby
    /// resumes where the checkpointed instance left off instead of
    /// starting empty. Without a source (the default), prebuilds stay
    /// empty and the warm ≡ cold equivalence contract is untouched.
    pub fn set_snapshot_source(&mut self, source: SnapshotSource) {
        self.snapshots = Some(source);
    }

    /// Swap the policy (capacity shrink applies lazily: the next
    /// park/sweep/reclaim enforces it).
    pub fn set_policy(&mut self, policy: WarmPolicy) {
        self.policy = policy;
    }

    pub fn policy(&self) -> &WarmPolicy {
        &self.policy
    }

    /// Parked entries right now.
    pub fn resident(&self) -> usize {
        self.entries.len()
    }

    /// Take `name`'s warm pipeline for re-activation, if parked. The
    /// caller verifies the handle is still deployed (and counts
    /// `trigger.warm_hits`) — the pool only owns residency.
    pub fn take(&mut self, name: &str) -> Option<PipelineHandle> {
        self.entries.remove(name).map(|e| e.handle)
    }

    /// Park a decommissioning activation. With `capacity: 0` this is a
    /// plain stop. Stateless pipelines park live; stateful ones flush
    /// (stop) and, under `prebuild`, a fresh standby is deployed and
    /// parked in their place. Over-capacity evicts coldest-first.
    pub fn park(
        &mut self,
        deployer: &mut dyn Deployer,
        name: &str,
        handle: PipelineHandle,
        stateful: bool,
        pipeline: &Pipeline,
    ) -> Result<ParkOutcome> {
        if self.policy.capacity == 0 {
            return Ok(ParkOutcome { tail: deployer.stop(&handle)?, evicted: Vec::new() });
        }
        let (tail, parked) = if stateful {
            let tail = deployer.stop(&handle)?;
            if !self.policy.prebuild {
                return Ok(ParkOutcome { tail, evicted: Vec::new() });
            }
            let standby = deployer.deploy(pipeline)?;
            if let Some(states) = self.snapshots.as_ref().and_then(|s| s(name)) {
                for (stage, state) in states {
                    if state.is_empty() {
                        continue;
                    }
                    deployer.seed_state(&standby, &stage, state)?;
                }
                self.metrics.counter("trigger.pool_seeded").inc();
            }
            (tail, standby)
        } else {
            (Vec::new(), handle)
        };
        self.entries
            .insert(name.to_string(), WarmEntry { handle: parked, parked_at: Instant::now() });
        let mut evicted = Vec::new();
        while self.entries.len() > self.policy.capacity {
            if let Some((owner, tail)) = self.evict_coldest(deployer)? {
                evicted.push((owner, tail));
            }
        }
        Ok(ParkOutcome { tail, evicted })
    }

    /// Evict entries whose warmth has expired ([`WarmPolicy::max_idle`]).
    pub fn sweep(&mut self, deployer: &mut dyn Deployer) -> Result<Vec<(String, Vec<Tuple>)>> {
        let mut evicted = Vec::new();
        loop {
            let Some(name) = self
                .coldest()
                .filter(|n| self.policy.expired(self.entries[n].parked_at.elapsed()))
            else {
                break;
            };
            let entry = self.entries.remove(&name).expect("coldest exists");
            self.metrics.counter("trigger.pool_evictions").inc();
            evicted.push((name, deployer.stop(&entry.handle)?));
        }
        Ok(evicted)
    }

    /// Memory-pressure reclaim: evict coldest-first down to `keep`
    /// resident entries. Returns how many were evicted plus their
    /// drain tails.
    pub fn reclaim(
        &mut self,
        deployer: &mut dyn Deployer,
        keep: usize,
    ) -> Result<(usize, Vec<(String, Vec<Tuple>)>)> {
        let mut evicted = Vec::new();
        while self.entries.len() > keep {
            if let Some((owner, tail)) = self.evict_coldest(deployer)? {
                evicted.push((owner, tail));
            }
        }
        Ok((evicted.len(), evicted))
    }

    /// Stop every parked pipeline (shutdown / decommission-all). Not
    /// counted as evictions — this is teardown, not pressure.
    pub fn drain_all(&mut self, deployer: &mut dyn Deployer) -> Result<Vec<(String, Vec<Tuple>)>> {
        let mut out = Vec::new();
        let entries = std::mem::take(&mut self.entries);
        for (name, entry) in entries {
            out.push((name, deployer.stop(&entry.handle)?));
        }
        Ok(out)
    }

    /// Drop `name`'s parked entry (unbind): stop it, return its tail.
    pub fn remove(
        &mut self,
        deployer: &mut dyn Deployer,
        name: &str,
    ) -> Result<Option<Vec<Tuple>>> {
        match self.entries.remove(name) {
            Some(entry) => Ok(Some(deployer.stop(&entry.handle)?)),
            None => Ok(None),
        }
    }

    fn coldest(&self) -> Option<String> {
        self.entries
            .iter()
            .min_by_key(|(_, e)| e.parked_at)
            .map(|(n, _)| n.clone())
    }

    fn evict_coldest(
        &mut self,
        deployer: &mut dyn Deployer,
    ) -> Result<Option<(String, Vec<Tuple>)>> {
        let Some(name) = self.coldest() else { return Ok(None) };
        let entry = self.entries.remove(&name).expect("coldest exists");
        self.metrics.counter("trigger.pool_evictions").inc();
        let tail = deployer.stop(&entry.handle)?;
        Ok(Some((name, tail)))
    }
}

impl std::fmt::Debug for WarmPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WarmPool(resident={}, capacity={})", self.entries.len(), self.policy.capacity)
    }
}
