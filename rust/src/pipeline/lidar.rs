//! Synthetic LiDAR trace generator.
//!
//! The paper's dataset: post-Hurricane-Sandy LiDAR of NY/Long Island,
//! "741 images and 3.7 GB in size, with the biggest image size of
//! 33.8 MB, and the smallest of 1.8 KB". We reproduce the *count* and
//! the *log-normal size spread* (scaled by a configurable factor so CI
//! runs in seconds), and generate image content with damage-like
//! structure: a smooth terrain field plus sharp-edged "debris" patches
//! whose density drives the pre-processing RESULT score — so the rule
//! engine's routing decisions exercise both branches, like the real
//! workflow.

use crate::overlay::geo::GeoPoint;
use crate::util::prng::Prng;

/// Paper dataset constants.
pub const PAPER_IMAGE_COUNT: usize = 741;
pub const PAPER_MIN_BYTES: usize = 1_800;
pub const PAPER_MAX_BYTES: usize = 33_800_000;
pub const PAPER_TOTAL_BYTES: u64 = 3_700_000_000;

/// One synthetic LiDAR capture.
#[derive(Debug, Clone)]
pub struct LidarImage {
    pub id: u32,
    /// Capture location (within the NY/Long-Island box).
    pub location: GeoPoint,
    /// Raw size this image represents in the paper's dataset (bytes).
    pub nominal_bytes: usize,
    /// One 256×256 f32 tile of the image (the unit the pipeline
    /// processes; larger images are represented by their nominal size
    /// for transfer-cost purposes and by one tile for compute).
    pub tile: Vec<f32>,
    /// Ground-truth damage density in [0,1] (test oracle only).
    pub damage: f64,
}

/// The whole trace.
#[derive(Debug, Clone)]
pub struct LidarTrace {
    pub images: Vec<LidarImage>,
}

/// Tile side length (matches the AOT artifact geometry).
pub const TILE_DIM: usize = 256;

impl LidarTrace {
    /// Generate `count` images; `size_scale` divides the nominal sizes
    /// (1.0 = paper-scale 3.7 GB; 64.0 ≈ 58 MB total).
    pub fn generate(seed: u64, count: usize, size_scale: f64) -> Self {
        let mut rng = Prng::seeded(seed);
        // Log-normal calibrated to the paper's spread: median ≈ 1 MB,
        // clamped to [1.8 KB, 33.8 MB].
        let mu = (1.0e6f64).ln();
        let sigma = 1.6;
        let images = (0..count)
            .map(|i| {
                let raw = rng.gen_lognormal(mu, sigma);
                let nominal = (raw.clamp(PAPER_MIN_BYTES as f64, PAPER_MAX_BYTES as f64)
                    / size_scale.max(1.0)) as usize;
                // Hurricane-Sandy area: NY / Long Island.
                let location = GeoPoint::new(
                    40.55 + rng.gen_f64() * 0.45,
                    -74.2 + rng.gen_f64() * 1.6,
                );
                let damage = rng.gen_f64().powi(2); // most areas lightly damaged
                let tile = generate_tile(&mut rng, damage);
                LidarImage {
                    id: i as u32,
                    location,
                    nominal_bytes: nominal.max(PAPER_MIN_BYTES / size_scale.max(1.0) as usize),
                    tile,
                    damage,
                }
            })
            .collect();
        LidarTrace { images }
    }

    /// Paper-shaped trace at a CI-friendly scale.
    pub fn paper_shaped(seed: u64) -> Self {
        Self::generate(seed, PAPER_IMAGE_COUNT, 256.0)
    }

    /// Total nominal bytes.
    pub fn total_bytes(&self) -> u64 {
        self.images.iter().map(|i| i.nominal_bytes as u64).sum()
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// Generate one 256×256 tile: smooth terrain + `damage`-scaled debris.
fn generate_tile(rng: &mut Prng, damage: f64) -> Vec<f32> {
    let n = TILE_DIM;
    let mut tile = vec![0f32; n * n];
    // Smooth terrain: sum of a few low-frequency sinusoids.
    let fx = 1.0 + rng.gen_f64() * 3.0;
    let fy = 1.0 + rng.gen_f64() * 3.0;
    let phase = rng.gen_f64() * std::f64::consts::TAU;
    for y in 0..n {
        for x in 0..n {
            let u = x as f64 / n as f64;
            let v = y as f64 / n as f64;
            let h = (fx * u * std::f64::consts::TAU + phase).sin()
                + (fy * v * std::f64::consts::TAU).cos();
            tile[y * n + x] = (h * 0.5) as f32;
        }
    }
    // Debris: sharp-edged rectangles with random heights; count scales
    // with damage density.
    let patches = (damage * 40.0) as usize;
    for _ in 0..patches {
        let px = rng.gen_range(0, n - 8);
        let py = rng.gen_range(0, n - 8);
        let w = rng.gen_range(2, 9);
        let h = rng.gen_range(2, 9);
        let height = 2.0 + rng.gen_f32() * 6.0;
        for y in py..(py + h).min(n) {
            for x in px..(px + w).min(n) {
                tile[y * n + x] += height;
            }
        }
    }
    tile
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_count_and_bounds() {
        let t = LidarTrace::paper_shaped(42);
        assert_eq!(t.len(), PAPER_IMAGE_COUNT);
        for img in &t.images {
            assert!(img.nominal_bytes <= PAPER_MAX_BYTES);
            assert!(img.location.is_valid());
            assert_eq!(img.tile.len(), TILE_DIM * TILE_DIM);
        }
    }

    #[test]
    fn size_distribution_is_spread() {
        let t = LidarTrace::generate(7, 741, 1.0);
        let sizes: Vec<usize> = t.images.iter().map(|i| i.nominal_bytes).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        // Log-normal with σ=1.6 over 741 draws: orders of magnitude apart.
        assert!(max as f64 / min as f64 > 100.0, "min={min} max={max}");
        // Total in the paper's ballpark (3.7 GB ± 3×).
        let total = t.total_bytes() as f64;
        assert!(total > 0.8e9 && total < 12.0e9, "total={total}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = LidarTrace::generate(1, 10, 64.0);
        let b = LidarTrace::generate(1, 10, 64.0);
        assert_eq!(a.images[3].nominal_bytes, b.images[3].nominal_bytes);
        assert_eq!(a.images[3].tile, b.images[3].tile);
        let c = LidarTrace::generate(2, 10, 64.0);
        assert_ne!(a.images[3].tile, c.images[3].tile);
    }

    #[test]
    fn damage_increases_edge_content() {
        // The generator's contract with the pipeline: damaged tiles have
        // more gradient energy (drives RESULT).
        let mut rng = Prng::seeded(3);
        let calm = generate_tile(&mut rng, 0.0);
        let mut rng = Prng::seeded(3);
        let wrecked = generate_tile(&mut rng, 1.0);
        let energy = |t: &[f32]| -> f64 {
            let n = TILE_DIM;
            let mut e = 0.0f64;
            for y in 0..n {
                for x in 1..n {
                    e += (t[y * n + x] - t[y * n + x - 1]).abs() as f64;
                }
            }
            e
        };
        assert!(energy(&wrecked) > 2.0 * energy(&calm));
    }
}
