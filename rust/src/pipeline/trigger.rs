//! Data-driven pipeline activation (paper §IV-D2 + the serverless-edge
//! gap named by the related work): a [`Pipeline`] bound to an AR
//! [`Profile`] is *not* deployed until matching data actually arrives
//! at the broker — then it cold-starts on demand, is fed from its
//! topic cursor, and is decommissioned back to **zero** running
//! replicas once an idle watermark passes. This is what makes the
//! platform serverless rather than just streaming: compute exists only
//! while data flows.
//!
//! **Cursor contract.** A binding subscribes its own broker consumer
//! (`trigger:<pipeline>`), so delivery rides the broker's at-least-once
//! cursor machinery: data published while the pipeline is idle is *not
//! lost* — the next activation resumes from the cursor, and per-key
//! order is preserved end-to-end (per-topic FIFO × the executor's
//! keyed-shuffle guarantee). Activation → feed → idle-decommission →
//! re-activation therefore loses no tuples (property-tested in
//! `rust/tests/trigger_plane.rs`, pre-validated by
//! `python/sims/trigger_sim.py`).
//!
//! **Idle watermark.** Scale-to-zero reuses the broker's
//! [`RetirePolicy`] watermark machinery verbatim: `decide(age,
//! publish_idle, fetch_idle)` is evaluated with *age* = time since
//! activation and both idle distances = time since the last matching
//! tuple was fed. The same policy type that retires idle topics
//! retires idle pipelines.
//!
//! **Faults.** A pipeline that faults mid-activation (operator panic /
//! error) is torn down best-effort, counted in `trigger.faults`, and
//! the binding returns to idle — the next matching data cold-starts a
//! fresh instance. Tuples fed to the faulted activation follow the
//! executor's first-fault drain contract (in-flight output may be
//! lost; the broker cursor has already advanced — at-least-once ends
//! at the mouth of a faulted pipeline).
//!
//! Metrics: `trigger.activations`, `trigger.decommissions`,
//! `trigger.faults`, `trigger.tuples_fed` (plus per-binding
//! [`TriggerStats`] with the last cold-start latency). Measured by
//! `benches/fig17_ondemand_pipeline.rs` against a pre-deployed
//! topology.

use crate::ar::profile::Profile;
use crate::ar::shard::MatchingPlane;
use crate::error::{Error, Result};
use crate::metrics::Registry;
use crate::mmq::pubsub::RetirePolicy;
use crate::stream::deploy::TopologyManager;
use crate::stream::engine::StreamEngine;
use crate::stream::pipeline::{Deployer, Pipeline, PipelineHandle};
use crate::stream::tuple::Tuple;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Max messages fetched per binding per pump pass.
const FETCH_MAX: usize = 1024;

/// Per-binding activation knobs.
#[derive(Debug, Clone)]
pub struct TriggerOptions {
    /// When to decommission an activated pipeline: evaluated as
    /// `decide(time since activation, time since last fed tuple, time
    /// since last fed tuple)` on every pump that fetched nothing for
    /// the binding. The default (10 min idle, 1 min grace) suits
    /// long-lived edge nodes; tests and benches shrink it.
    pub idle: RetirePolicy,
    /// Decode broker payloads with [`Tuple::decode`] (producers feed
    /// `Tuple::encode` frames — field-carrying tuples for keyed
    /// stages). When `false`, or when a payload does not decode, the
    /// payload bytes become a fresh tuple with a binding-assigned
    /// sequence number.
    pub decode_payloads: bool,
}

impl Default for TriggerOptions {
    fn default() -> Self {
        TriggerOptions { idle: RetirePolicy::default(), decode_payloads: true }
    }
}

/// Lifetime counters of one binding.
#[derive(Debug, Clone, Default)]
pub struct TriggerStats {
    /// Cold starts performed.
    pub activations: u64,
    /// Scale-to-zero decommissions (idle watermark or unbind).
    pub decommissions: u64,
    /// Activations torn down by a pipeline fault.
    pub faults: u64,
    /// Matching tuples fed across all activations.
    pub tuples_fed: u64,
    /// Deploy latency of the most recent cold start.
    pub last_cold_start: Option<Duration>,
}

/// A live activation.
struct Active {
    handle: PipelineHandle,
    activated_at: Instant,
    last_data: Instant,
}

/// One pipeline ↔ profile binding.
struct Binding {
    pipeline: Pipeline,
    consumer: String,
    opts: TriggerOptions,
    active: Option<Active>,
    outputs: Vec<Tuple>,
    raw_seq: u64,
    stats: TriggerStats,
}

/// Binds pipelines to data profiles over any [`Deployer`] surface and
/// drives the activate/feed/decommission lifecycle. Single-threaded by
/// design: [`TriggerManager::pump`] is called from whatever loop owns
/// the broker (a node's housekeeping tick, a bench driver), so
/// activation decisions are deterministic and test-friendly.
pub struct TriggerManager<D: Deployer> {
    deployer: D,
    bindings: BTreeMap<String, Binding>,
    metrics: Registry,
}

impl TriggerManager<TopologyManager> {
    /// The common composition: trigger-activated pipelines running on
    /// an in-process executor.
    pub fn in_process() -> Self {
        Self::new(TopologyManager::new(StreamEngine::new()))
    }
}

impl<D: Deployer> TriggerManager<D> {
    /// Bind the lifecycle to an existing deploy surface.
    pub fn new(deployer: D) -> Self {
        Self::with_metrics(deployer, Registry::new())
    }

    /// Share a metrics registry (node/bench composition).
    pub fn with_metrics(deployer: D, metrics: Registry) -> Self {
        TriggerManager { deployer, bindings: BTreeMap::new(), metrics }
    }

    /// The underlying deploy surface.
    pub fn deployer(&self) -> &D {
        &self.deployer
    }

    pub fn deployer_mut(&mut self) -> &mut D {
        &mut self.deployer
    }

    /// Activation/decommission counters.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Bind `pipeline` to `profile`: matching data arriving at `broker`
    /// from now on activates the pipeline on demand. The binding works
    /// against any [`MatchingPlane`] — a single
    /// [`Broker`](crate::mmq::pubsub::Broker) or the sharded router
    /// ([`crate::ar::shard::ShardedBroker`]), so triggers
    /// bind through the shard router unchanged. The pipeline is
    /// fully validated against the deploy surface *here* — an invalid
    /// definition is rejected at bind time, never at 3am when the
    /// first matching tuple arrives. Binding names (pipeline names)
    /// are unique.
    pub fn bind(
        &mut self,
        broker: &mut impl MatchingPlane,
        pipeline: Pipeline,
        profile: Profile,
        opts: TriggerOptions,
    ) -> Result<()> {
        if self.bindings.contains_key(pipeline.name()) {
            return Err(Error::Stream(format!(
                "pipeline `{}` is already bound",
                pipeline.name()
            )));
        }
        self.deployer.validate(&pipeline)?;
        let consumer = format!("trigger:{}", pipeline.name());
        broker.subscribe(&consumer, profile);
        self.bindings.insert(
            pipeline.name().to_string(),
            Binding {
                pipeline,
                consumer,
                opts,
                active: None,
                outputs: Vec::new(),
                raw_seq: 0,
                stats: TriggerStats::default(),
            },
        );
        Ok(())
    }

    /// Remove a binding: unsubscribe its consumer, decommission any
    /// live activation (zero-loss drain) and return everything the
    /// binding ever produced that was not yet taken.
    pub fn unbind(&mut self, broker: &mut impl MatchingPlane, name: &str) -> Result<Vec<Tuple>> {
        let mut b = self
            .bindings
            .remove(name)
            .ok_or_else(|| Error::NotFound(format!("no trigger binding `{name}`")))?;
        broker.unsubscribe(&b.consumer);
        if let Some(active) = b.active.take() {
            let tail = self.deployer.stop(&active.handle)?;
            b.outputs.extend(tail);
            b.stats.decommissions += 1;
            self.metrics.counter("trigger.decommissions").inc();
        }
        Ok(b.outputs)
    }

    /// One lifecycle pass over every binding: fetch matching messages
    /// from the broker cursor, cold-start idle pipelines that received
    /// data, feed, drain available outputs, and decommission
    /// activations whose idle watermark has passed. A faulted binding
    /// is torn down and reported; the other bindings still complete
    /// their pass (first error wins).
    pub fn pump(&mut self, broker: &mut impl MatchingPlane) -> Result<()> {
        let names: Vec<String> = self.bindings.keys().cloned().collect();
        let mut first_err: Option<Error> = None;
        for name in names {
            if let Err(e) = self.pump_one(broker, &name) {
                self.fail_binding(&name);
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn pump_one(&mut self, broker: &mut impl MatchingPlane, name: &str) -> Result<()> {
        let Self { deployer, bindings, metrics } = self;
        let b = bindings.get_mut(name).expect("binding exists");
        let msgs = broker.fetch(&b.consumer, FETCH_MAX)?;
        let now = Instant::now();
        if !msgs.is_empty() {
            if b.active.is_none() {
                let started = Instant::now();
                let handle = deployer.deploy(&b.pipeline)?;
                b.stats.last_cold_start = Some(started.elapsed());
                b.stats.activations += 1;
                metrics.counter("trigger.activations").inc();
                b.active = Some(Active { handle, activated_at: now, last_data: now });
            }
            let mut batch = Vec::with_capacity(msgs.len());
            for (_topic, payload) in &msgs {
                batch.push(as_tuple(b.opts.decode_payloads, &mut b.raw_seq, payload));
            }
            b.stats.tuples_fed += batch.len() as u64;
            metrics.counter("trigger.tuples_fed").add(batch.len() as u64);
            let active = b.active.as_mut().expect("just activated");
            active.last_data = now;
            deployer.send_batch(&active.handle, batch)?;
        }
        if let Some(active) = &b.active {
            b.outputs.extend(deployer.poll(&active.handle, usize::MAX)?);
            let age = now.duration_since(active.activated_at);
            let idle = now.duration_since(active.last_data);
            if msgs.is_empty() && b.opts.idle.decide(age, idle, idle) {
                let active = b.active.take().expect("checked above");
                let tail = deployer.stop(&active.handle)?;
                b.outputs.extend(tail);
                b.stats.decommissions += 1;
                metrics.counter("trigger.decommissions").inc();
            }
        }
        Ok(())
    }

    /// Best-effort teardown after a pump error: the activation (if
    /// any) is stopped and discarded, the binding returns to idle so
    /// the next matching data cold-starts a fresh instance.
    fn fail_binding(&mut self, name: &str) {
        let Self { deployer, bindings, metrics } = self;
        let Some(b) = bindings.get_mut(name) else { return };
        if let Some(active) = b.active.take() {
            match deployer.stop(&active.handle) {
                Ok(tail) => b.outputs.extend(tail),
                Err(e) => log::warn!("trigger `{name}`: teardown after fault: {e}"),
            }
        }
        b.stats.faults += 1;
        metrics.counter("trigger.faults").inc();
    }

    /// Keep pumping until every binding is idle (each backlog fed and
    /// each idle watermark passed) or `timeout` elapses; errors
    /// surface immediately. Convenience for drains in tests/benches.
    pub fn pump_until_idle(
        &mut self,
        broker: &mut impl MatchingPlane,
        timeout: Duration,
    ) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            self.pump(broker)?;
            if self.active().is_empty() {
                return Ok(());
            }
            if Instant::now() >= deadline {
                return Err(Error::Timeout(format!(
                    "trigger bindings still active after {timeout:?}: {:?}",
                    self.active()
                )));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Force every activation to zero *now* (node shutdown), ignoring
    /// idle watermarks. Outputs stay buffered for [`Self::take_outputs`].
    pub fn decommission_all(&mut self) -> Result<()> {
        let mut first_err: Option<Error> = None;
        let Self { deployer, bindings, metrics } = self;
        for (name, b) in bindings.iter_mut() {
            if let Some(active) = b.active.take() {
                match deployer.stop(&active.handle) {
                    Ok(tail) => {
                        b.outputs.extend(tail);
                        b.stats.decommissions += 1;
                        metrics.counter("trigger.decommissions").inc();
                    }
                    Err(e) => {
                        log::error!("trigger `{name}`: decommission: {e}");
                        b.stats.faults += 1;
                        metrics.counter("trigger.faults").inc();
                        first_err.get_or_insert(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Take everything a binding's activations have produced so far.
    pub fn take_outputs(&mut self, name: &str) -> Vec<Tuple> {
        self.bindings
            .get_mut(name)
            .map(|b| std::mem::take(&mut b.outputs))
            .unwrap_or_default()
    }

    /// Whether a binding currently has a live activation.
    pub fn is_active(&self, name: &str) -> bool {
        self.bindings.get(name).is_some_and(|b| b.active.is_some())
    }

    /// Names of bindings with live activations.
    pub fn active(&self) -> Vec<String> {
        self.bindings
            .iter()
            .filter(|(_, b)| b.active.is_some())
            .map(|(n, _)| n.clone())
            .collect()
    }

    /// All binding names.
    pub fn bound(&self) -> Vec<String> {
        self.bindings.keys().cloned().collect()
    }

    /// A binding's lifetime counters.
    pub fn stats(&self, name: &str) -> Option<TriggerStats> {
        self.bindings.get(name).map(|b| b.stats.clone())
    }
}

impl<D: Deployer> std::fmt::Debug for TriggerManager<D> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TriggerManager(bindings={}, active={})",
            self.bindings.len(),
            self.active().len()
        )
    }
}

/// Broker payload → tuple. Encoded frames carry their own seq and
/// fields; raw payloads get a binding-assigned sequence number.
fn as_tuple(decode: bool, raw_seq: &mut u64, payload: &[u8]) -> Tuple {
    if decode {
        if let Ok(t) = Tuple::decode(payload) {
            return t;
        }
    }
    let t = Tuple::new(*raw_seq, payload.to_vec());
    *raw_seq += 1;
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::shard::ShardedBroker;
    use crate::mmq::pubsub::Broker;
    use crate::mmq::queue::QueueOptions;
    use crate::stream::operator::{Operator, OperatorKind};
    use crate::stream::pipeline::PipelineStage;

    fn broker(name: &str) -> Broker {
        let dir = std::env::temp_dir()
            .join("rpulsar-trigger-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Broker::new(QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 })
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    fn inc_pipeline(name: &str) -> Pipeline {
        Pipeline::builder(name)
            .stage(PipelineStage::new("inc").operator(|| {
                Box::new(OperatorKind::map("inc", |mut t| {
                    let v = t.get("X").unwrap_or(0.0);
                    t.set("X", v + 1.0);
                    t
                })) as Box<dyn Operator>
            }))
            .build()
            .unwrap()
    }

    fn eager() -> TriggerOptions {
        TriggerOptions {
            idle: RetirePolicy {
                max_publish_idle: Duration::ZERO,
                max_fetch_idle: Duration::ZERO,
                min_age: Duration::ZERO,
            },
            decode_payloads: true,
        }
    }

    #[test]
    fn data_arrival_cold_starts_and_idle_decommissions() {
        let mut broker = broker("lifecycle");
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut broker, inc_pipeline("job"), p("drone,*"), eager()).unwrap();
        // Bound but idle: no deploy has happened, pump is a no-op.
        assert!(!trig.is_active("job"));
        trig.pump(&mut broker).unwrap();
        assert!(!trig.is_active("job"));
        assert_eq!(trig.stats("job").unwrap().activations, 0);
        // Non-matching data does not activate.
        broker.publish(&p("truck,gps"), &Tuple::new(0, vec![]).encode()).unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(!trig.is_active("job"));
        // Matching data cold-starts the pipeline.
        broker
            .publish(&p("drone,lidar"), &Tuple::new(1, vec![]).with("X", 1.0).encode())
            .unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(trig.is_active("job"), "matching data must activate");
        let stats = trig.stats("job").unwrap();
        assert_eq!(stats.activations, 1);
        assert!(stats.last_cold_start.is_some());
        assert_eq!(stats.tuples_fed, 1);
        // Next pump fetches nothing → the zero-threshold idle policy
        // decommissions back to zero.
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        assert!(!trig.is_active("job"));
        let stats = trig.stats("job").unwrap();
        assert_eq!(stats.decommissions, 1);
        assert_eq!(trig.metrics().counter("trigger.activations").get(), 1);
        assert_eq!(trig.metrics().counter("trigger.decommissions").get(), 1);
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(2.0));
        // Re-activation on the next matching publish.
        broker
            .publish(&p("drone,lidar"), &Tuple::new(2, vec![]).with("X", 5.0).encode())
            .unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(trig.is_active("job"));
        assert_eq!(trig.stats("job").unwrap().activations, 2);
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(6.0));
    }

    #[test]
    fn triggers_bind_through_the_shard_router() {
        // Same lifecycle, but the matching plane is a ShardedBroker:
        // publishes land on owner shards, the trigger's consumer is
        // registered on every shard, and activation still fires.
        let dir = std::env::temp_dir()
            .join("rpulsar-trigger-tests")
            .join(format!("sharded-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut plane = ShardedBroker::new(
            QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 },
            ["s0", "s1", "s2"],
        );
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut plane, inc_pipeline("job"), p("drone*,*"), eager()).unwrap();
        for i in 0..6u64 {
            plane
                .publish(
                    &p(&format!("drone{i:02},lidar")),
                    &Tuple::new(i, vec![]).with("X", i as f64).encode(),
                )
                .unwrap();
        }
        trig.pump_until_idle(&mut plane, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 6, "tuples from every shard must reach the pipeline");
        assert_eq!(trig.stats("job").unwrap().tuples_fed, 6);
        assert!(trig.unbind(&mut plane, "job").is_ok());
        assert!(!plane.is_registered("trigger:job"));
    }

    #[test]
    fn data_published_while_idle_is_not_lost() {
        // The binding's cursor holds the backlog across the idle gap.
        let mut broker = broker("backlog");
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), eager()).unwrap();
        for i in 0..5u64 {
            broker
                .publish(&p("s,t"), &Tuple::new(i, vec![]).with("X", i as f64).encode())
                .unwrap();
        }
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        assert_eq!(trig.take_outputs("job").len(), 5);
        // Published while decommissioned…
        for i in 5..9u64 {
            broker
                .publish(&p("s,t"), &Tuple::new(i, vec![]).with("X", i as f64).encode())
                .unwrap();
        }
        // …and delivered in full by the next activation.
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 4, "backlog across the idle gap must survive");
        assert_eq!(trig.stats("job").unwrap().activations, 2);
    }

    #[test]
    fn invalid_pipeline_rejected_at_bind_not_at_first_tuple() {
        let mut broker = broker("invalid");
        let mut trig = TriggerManager::in_process();
        let bad = Pipeline::parse("ghostly", "ghost").unwrap();
        let err = trig.bind(&mut broker, bad, p("s,*"), eager()).unwrap_err();
        assert!(format!("{err}").contains("unknown stage `ghost`"), "{err}");
        assert!(trig.bound().is_empty());
    }

    #[test]
    fn duplicate_binding_rejected() {
        let mut broker = broker("dup");
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut broker, inc_pipeline("job"), p("a,*"), eager()).unwrap();
        let err = trig
            .bind(&mut broker, inc_pipeline("job"), p("b,*"), eager())
            .unwrap_err();
        assert!(format!("{err}").contains("already bound"), "{err}");
    }

    #[test]
    fn unbind_decommissions_and_returns_outputs() {
        let mut broker = broker("unbind");
        let mut trig = TriggerManager::in_process();
        // Patient policy: stays active until unbind.
        let opts = TriggerOptions::default();
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), opts).unwrap();
        broker.publish(&p("s,t"), &Tuple::new(0, vec![]).with("X", 1.0).encode()).unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(trig.is_active("job"));
        let out = trig.unbind(&mut broker, "job").unwrap();
        assert_eq!(out.len(), 1);
        assert!(trig.bound().is_empty());
        assert!(trig.unbind(&mut broker, "job").is_err());
    }

    #[test]
    fn raw_payloads_flow_with_assigned_seqs() {
        let mut broker = broker("raw");
        let mut trig = TriggerManager::in_process();
        let opts = TriggerOptions { decode_payloads: false, ..eager() };
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), opts).unwrap();
        broker.publish(&p("s,t"), b"not-a-tuple").unwrap();
        broker.publish(&p("s,t"), b"also-raw").unwrap();
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].payload, b"not-a-tuple");
    }

    #[test]
    fn faulted_activation_returns_to_zero_and_restarts_fresh() {
        let mut broker = broker("fault");
        let mut trig = TriggerManager::in_process();
        let boom = Pipeline::builder("boom")
            .stage(PipelineStage::new("explode").operator(|| {
                Box::new(OperatorKind::map("explode", |t| {
                    if t.get("BAD") == Some(1.0) {
                        panic!("injected trigger fault");
                    }
                    t
                })) as Box<dyn Operator>
            }))
            .build()
            .unwrap();
        trig.bind(&mut broker, boom, p("s,*"), eager()).unwrap();
        broker.publish(&p("s,t"), &Tuple::new(0, vec![]).with("BAD", 1.0).encode()).unwrap();
        // The panic surfaces from some pump pass (feed or drain), the
        // binding is torn down and idle again.
        let mut failed = false;
        for _ in 0..50 {
            match trig.pump(&mut broker) {
                Err(e) => {
                    assert!(format!("{e}").contains("injected trigger fault"), "{e}");
                    failed = true;
                    break;
                }
                Ok(()) if !trig.is_active("boom") && trig.stats("boom").unwrap().faults > 0 => {
                    failed = true;
                    break;
                }
                Ok(()) => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert!(failed, "fault must surface");
        assert!(!trig.is_active("boom"));
        assert_eq!(trig.stats("boom").unwrap().faults, 1);
        // A clean tuple re-activates a fresh instance end to end.
        broker.publish(&p("s,t"), &Tuple::new(1, vec![]).with("X", 1.0).encode()).unwrap();
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        assert_eq!(trig.stats("boom").unwrap().activations, 2);
        let out = trig.take_outputs("boom");
        assert_eq!(out.len(), 1, "fresh activation must process cleanly");
    }
}
