//! Data-driven pipeline activation (paper §IV-D2 + the serverless-edge
//! gap named by the related work): a [`Pipeline`] bound to an AR
//! [`Profile`] is *not* deployed until matching data actually arrives
//! at the broker — then it cold-starts on demand, is fed from its
//! topic cursor, and is decommissioned back to **zero** running
//! replicas once an idle watermark passes. This is what makes the
//! platform serverless rather than just streaming: compute exists only
//! while data flows.
//!
//! Since PR 9 the plane is built to host *thousands* of bindings:
//!
//! - **Admission control** ([`AdmissionControl`]): in-flight
//!   activations are bounded. A refused binding is *not fetched* — its
//!   broker cursor never advances — so refusal + retry loses nothing;
//!   strict mode surfaces the refusal as a structured
//!   [`Error::Admission`] instead of a silent deferral.
//! - **Per-tenant fair scheduling** ([`FairScheduler`]): the pump
//!   visits bindings tenant-interleaved, tenants ordered by lifetime
//!   admitted activations (deficit) with a rotating tie-break, and
//!   each tenant's own binding list rotates too — one hot tenant
//!   cannot starve the rest, and the pre-PR-9 fixed-map-order
//!   starvation is gone from the sequential pump as well.
//! - **Warm pools** ([`WarmPolicy`], `pipeline/pool.rs`): opt-in
//!   retention of decommissioned pipelines so re-activation
//!   approaches re-attach latency instead of a full deploy.
//! - **Concurrent pumping** (`pipeline/concurrent.rs`): the
//!   [`TriggerPool`](crate::pipeline::concurrent::TriggerPool) worker
//!   pool runs the same per-binding lifecycle (this module's
//!   `BindingRunner`) across threads; `RPULSAR_TRIGGERPLANE=sync`
//!   ([`TRIGGERPLANE_ENV`]) keeps the sequential manager as the A/B
//!   baseline.
//!
//! **Cursor contract.** A binding subscribes its own broker consumer
//! (`trigger:<pipeline>`), so delivery rides the broker's at-least-once
//! cursor machinery: data published while the pipeline is idle is *not
//! lost* — the next activation resumes from the cursor, and per-key
//! order is preserved end-to-end (per-topic FIFO × the executor's
//! keyed-shuffle guarantee). Activation → feed → idle-decommission →
//! re-activation therefore loses no tuples (property-tested in
//! `rust/tests/trigger_plane.rs` and `rust/tests/trigger_scale.rs`,
//! pre-validated by `python/sims/trigger_sim.py` and
//! `python/sims/trigger_scale_sim.py`).
//!
//! **Idle watermark.** Scale-to-zero reuses the broker's
//! [`RetirePolicy`] watermark machinery verbatim: `decide(age,
//! publish_idle, fetch_idle)` is evaluated with *age* = time since
//! activation and both idle distances = time since the last matching
//! tuple was fed. The same policy type that retires idle topics
//! retires idle pipelines.
//!
//! **Faults.** A pipeline that faults mid-activation (operator panic /
//! error) is torn down best-effort, counted in `trigger.faults`, and
//! the binding returns to idle — the next matching data cold-starts a
//! fresh instance. Tuples fed to the faulted activation follow the
//! executor's first-fault drain contract (in-flight output may be
//! lost; the broker cursor has already advanced — at-least-once ends
//! at the mouth of a faulted pipeline).
//!
//! Metrics: `trigger.{activations,decommissions,faults,tuples_fed}`
//! plus the scale counters `trigger.{admitted,rejected,warm_hits,
//! warm_misses,pool_evictions}` and the `trigger.cold_start_us` /
//! `trigger.warm_start_us` latency histograms (p50/p95/p99). Measured
//! by `benches/fig17_ondemand_pipeline.rs` against a pre-deployed
//! topology; the full contract is `docs/serverless-scale.md`.

use crate::ar::profile::Profile;
use crate::ar::shard::MatchingPlane;
use crate::error::{Error, Result};
use crate::metrics::Registry;
use crate::mmq::pubsub::RetirePolicy;
use crate::pipeline::pool::{WarmPolicy, WarmPool};
use crate::stream::deploy::TopologyManager;
use crate::stream::engine::StreamEngine;
use crate::stream::pipeline::{Deployer, Pipeline, PipelineHandle};
use crate::stream::tuple::Tuple;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Max messages fetched per binding per pump pass.
pub(crate) const FETCH_MAX: usize = 1024;

/// Env var selecting the trigger-plane pump mode for composed surfaces
/// (benches, `Node`): anything but `"sync"` (including unset) means
/// the concurrent worker pool is the default where one is available;
/// `"sync"` keeps the sequential [`TriggerManager::pump`] as the A/B
/// baseline. Same idiom as `RPULSAR_NETPLANE`.
pub const TRIGGERPLANE_ENV: &str = "RPULSAR_TRIGGERPLANE";

/// Whether composed surfaces should default to the concurrent pump
/// (see [`TRIGGERPLANE_ENV`]).
pub fn concurrent_default() -> bool {
    !matches!(std::env::var(TRIGGERPLANE_ENV).as_deref(), Ok("sync"))
}

/// Per-binding activation knobs.
#[derive(Debug, Clone)]
pub struct TriggerOptions {
    /// When to decommission an activated pipeline: evaluated as
    /// `decide(time since activation, time since last fed tuple, time
    /// since last fed tuple)` on every pump that fetched nothing for
    /// the binding. The default (10 min idle, 1 min grace) suits
    /// long-lived edge nodes; tests and benches shrink it.
    pub idle: RetirePolicy,
    /// Decode broker payloads with [`Tuple::decode`] (producers feed
    /// `Tuple::encode` frames — field-carrying tuples for keyed
    /// stages). When `false`, or when a payload does not decode, the
    /// payload bytes become a fresh tuple with a binding-assigned
    /// sequence number.
    pub decode_payloads: bool,
    /// The tenant this binding belongs to, for fair scheduling under
    /// burst ([`FairScheduler`]). `None` makes the binding its own
    /// tenant — the pre-multi-tenant behavior.
    pub tenant: Option<String>,
}

impl Default for TriggerOptions {
    fn default() -> Self {
        TriggerOptions {
            idle: RetirePolicy::default(),
            decode_payloads: true,
            tenant: None,
        }
    }
}

/// Lifetime counters of one binding.
#[derive(Debug, Clone, Default)]
pub struct TriggerStats {
    /// Activations performed (cold starts + warm starts).
    pub activations: u64,
    /// Activations served from the warm pool (subset of
    /// `activations`).
    pub warm_starts: u64,
    /// Scale-to-zero decommissions (idle watermark or unbind).
    pub decommissions: u64,
    /// Activations torn down by a pipeline fault.
    pub faults: u64,
    /// Activation attempts refused by admission control (each later
    /// retried from an unmoved cursor).
    pub rejections: u64,
    /// Matching tuples fed across all activations.
    pub tuples_fed: u64,
    /// Deploy latency of the most recent cold start.
    pub last_cold_start: Option<Duration>,
}

/// Bounded in-flight activations: the trigger plane's back door
/// against activation storms. Mechanism only — the bound is the
/// policy knob. A refused binding's cursor has not advanced, so the
/// next pump retries it with nothing lost; `strict` additionally
/// surfaces each refusal as a structured [`Error::Admission`] from
/// `pump` (the pass still completes — refusal never tears a binding
/// down).
#[derive(Debug, Clone)]
pub struct AdmissionControl {
    /// Max concurrently live activations across the plane. Slots
    /// freed by a mid-pass decommission become available on the
    /// *next* pass (snapshot semantics — identical decisions in
    /// sequential and concurrent mode).
    pub max_active: usize,
    /// Surface refusals as [`Error::Admission`] from `pump` instead
    /// of silent deferral.
    pub strict: bool,
}

impl Default for AdmissionControl {
    fn default() -> Self {
        AdmissionControl::unlimited()
    }
}

impl AdmissionControl {
    /// No bound (the default — pre-PR-9 behavior).
    pub fn unlimited() -> Self {
        AdmissionControl { max_active: usize::MAX, strict: false }
    }

    /// Bound in-flight activations; refusals defer silently.
    pub fn bounded(max_active: usize) -> Self {
        AdmissionControl { max_active, strict: false }
    }

    /// Bound in-flight activations; refusals surface as
    /// [`Error::Admission`].
    pub fn strict(max_active: usize) -> Self {
        AdmissionControl { max_active, strict: true }
    }

    /// May another activation start while `active_now` are live?
    pub fn admit(&self, active_now: usize) -> bool {
        active_now < self.max_active
    }

    /// The structured refusal.
    pub fn refusal(&self, name: &str, active_now: usize) -> Error {
        Error::Admission(format!(
            "binding `{name}`: {active_now}/{} activations in flight; \
             cursor unmoved, retry next pump",
            self.max_active
        ))
    }
}

/// Per-tenant fair pass order: tenants sorted by lifetime admitted
/// activations (deficit first), ties broken by a rotating start, each
/// tenant's own binding list rotated per pass, then interleaved one
/// binding per tenant per round. Under a tight admission cap this
/// guarantees a bursting tenant cannot starve the rest; with every
/// binding its own tenant it degrades to plain rotation — the
/// round-robin fix for the old fixed-map-order sequential pump.
#[derive(Debug, Default)]
pub struct FairScheduler {
    rr: u64,
    rr_in_tenant: BTreeMap<String, u64>,
    admitted: BTreeMap<String, u64>,
}

impl FairScheduler {
    pub fn new() -> Self {
        FairScheduler::default()
    }

    /// Produce this pass's visit order from `(binding, tenant)` pairs
    /// (callers pass them name-sorted; `BTreeMap` iteration does).
    pub fn order(&mut self, roster: &[(String, String)]) -> Vec<String> {
        let mut groups: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
        for (name, tenant) in roster {
            groups.entry(tenant.as_str()).or_default().push(name.as_str());
        }
        let mut tenants: Vec<&str> = groups.keys().copied().collect();
        if !tenants.is_empty() {
            let rot = (self.rr % tenants.len() as u64) as usize;
            tenants.rotate_left(rot);
        }
        self.rr = self.rr.wrapping_add(1);
        // Stable sort: deficit decides, the rotation above breaks ties.
        tenants.sort_by_key(|t| self.admitted.get(*t).copied().unwrap_or(0));
        for t in &tenants {
            let names = groups.get_mut(*t).expect("tenant grouped above");
            let ctr = self.rr_in_tenant.entry((*t).to_string()).or_insert(0);
            let rot = (*ctr % names.len() as u64) as usize;
            names.rotate_left(rot);
            *ctr = ctr.wrapping_add(1);
        }
        let mut out = Vec::with_capacity(roster.len());
        let mut round = 0usize;
        loop {
            let before = out.len();
            for t in &tenants {
                if let Some(n) = groups[*t].get(round) {
                    out.push((*n).to_string());
                }
            }
            if out.len() == before {
                return out;
            }
            round += 1;
        }
    }

    /// Record an admitted activation against `tenant`'s deficit.
    pub fn charge(&mut self, tenant: &str) {
        *self.admitted.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Lifetime admitted activations per tenant (fairness assertions).
    pub fn admitted(&self) -> &BTreeMap<String, u64> {
        &self.admitted
    }
}

/// A live activation.
struct Active {
    handle: PipelineHandle,
    activated_at: Instant,
    last_data: Instant,
}

/// One pipeline ↔ profile binding.
struct Binding {
    pipeline: Pipeline,
    consumer: String,
    tenant: String,
    /// Any stage's operator is stateful (probed at bind; unresolvable
    /// stages count as stateful). Decides live-park vs flush-park.
    stateful: bool,
    opts: TriggerOptions,
    active: Option<Active>,
    outputs: Vec<Tuple>,
    raw_seq: u64,
    stats: TriggerStats,
}

/// What one [`BindingRunner::step`] did — the concurrent pool mirrors
/// caller-side state from these.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct StepEvents {
    pub activated: bool,
    pub decommissioned: bool,
}

/// The per-binding lifecycle engine: owns the deployer, the bindings
/// and the warm pool, and performs one binding's
/// fetch-result → activate → feed → poll → decommission step. It
/// never touches the broker — fetching stays with whoever owns the
/// broker (the sequential [`TriggerManager`] or the
/// [`TriggerPool`](crate::pipeline::concurrent::TriggerPool) front
/// end), which is what lets the same runner serve both pumps.
pub(crate) struct BindingRunner<D: Deployer> {
    deployer: D,
    bindings: BTreeMap<String, Binding>,
    warm: WarmPool,
    metrics: Registry,
}

impl<D: Deployer> BindingRunner<D> {
    pub(crate) fn new(deployer: D, metrics: Registry) -> Self {
        let warm = WarmPool::new(WarmPolicy::default(), metrics.clone());
        BindingRunner { deployer, bindings: BTreeMap::new(), warm, metrics }
    }

    pub(crate) fn deployer(&self) -> &D {
        &self.deployer
    }

    pub(crate) fn deployer_mut(&mut self) -> &mut D {
        &mut self.deployer
    }

    pub(crate) fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Validate, probe statefulness and register the binding. Returns
    /// the consumer name the caller must subscribe on its broker.
    pub(crate) fn attach(&mut self, pipeline: Pipeline, opts: TriggerOptions) -> Result<String> {
        if self.bindings.contains_key(pipeline.name()) {
            return Err(Error::Stream(format!(
                "pipeline `{}` is already bound",
                pipeline.name()
            )));
        }
        self.deployer.validate(&pipeline)?;
        let stateful = pipeline.stages().iter().any(|s| {
            s.factory_ref()
                .cloned()
                .or_else(|| self.deployer.stage_factory(s.name()))
                .map(|f| f().stateful())
                .unwrap_or(true)
        });
        let consumer = format!("trigger:{}", pipeline.name());
        let tenant = opts.tenant.clone().unwrap_or_else(|| pipeline.name().to_string());
        self.bindings.insert(
            pipeline.name().to_string(),
            Binding {
                pipeline,
                consumer: consumer.clone(),
                tenant,
                stateful,
                opts,
                active: None,
                outputs: Vec::new(),
                raw_seq: 0,
                stats: TriggerStats::default(),
            },
        );
        Ok(consumer)
    }

    /// Remove a binding: decommission any live activation (zero-loss
    /// drain), evict its warm entry, and return everything the binding
    /// produced that was not yet taken. The caller unsubscribes the
    /// consumer.
    pub(crate) fn detach(&mut self, name: &str) -> Result<Vec<Tuple>> {
        let Self { deployer, bindings, warm, metrics } = self;
        let mut b = bindings
            .remove(name)
            .ok_or_else(|| Error::NotFound(format!("no trigger binding `{name}`")))?;
        if let Some(active) = b.active.take() {
            let tail = deployer.stop(&active.handle)?;
            b.outputs.extend(tail);
            b.stats.decommissions += 1;
            metrics.counter("trigger.decommissions").inc();
        }
        if let Some(tail) = warm.remove(deployer, name)? {
            b.outputs.extend(tail);
        }
        Ok(b.outputs)
    }

    /// One binding's lifecycle step against an already-fetched batch:
    /// activate if data arrived while idle (warm pool first, full
    /// deploy on miss), feed, poll outputs, and park/stop when the
    /// idle watermark passes on an empty fetch.
    pub(crate) fn step(
        &mut self,
        name: &str,
        msgs: Vec<(String, Arc<[u8]>)>,
    ) -> Result<StepEvents> {
        let Self { deployer, bindings, warm, metrics } = self;
        let b = bindings
            .get_mut(name)
            .ok_or_else(|| Error::NotFound(format!("no trigger binding `{name}`")))?;
        let mut events = StepEvents::default();
        let mut evicted_tails = Vec::new();
        let now = Instant::now();
        if !msgs.is_empty() {
            if b.active.is_none() {
                let started = Instant::now();
                let mut parked = warm.take(name);
                if let Some(h) = &parked {
                    if !deployer.is_deployed(h) {
                        parked = None;
                    }
                }
                let (handle, was_warm) = match parked {
                    Some(handle) => {
                        metrics.counter("trigger.warm_hits").inc();
                        (handle, true)
                    }
                    None => {
                        metrics.counter("trigger.warm_misses").inc();
                        (deployer.deploy(&b.pipeline)?, false)
                    }
                };
                let latency = started.elapsed();
                if was_warm {
                    b.stats.warm_starts += 1;
                    metrics.histogram("trigger.warm_start_us").record_duration(latency);
                } else {
                    b.stats.last_cold_start = Some(latency);
                    metrics.histogram("trigger.cold_start_us").record_duration(latency);
                }
                b.stats.activations += 1;
                metrics.counter("trigger.activations").inc();
                b.active = Some(Active { handle, activated_at: now, last_data: now });
                events.activated = true;
            }
            let mut batch = Vec::with_capacity(msgs.len());
            for (_topic, payload) in &msgs {
                batch.push(as_tuple(b.opts.decode_payloads, &mut b.raw_seq, payload));
            }
            b.stats.tuples_fed += batch.len() as u64;
            metrics.counter("trigger.tuples_fed").add(batch.len() as u64);
            let active = b.active.as_mut().expect("just activated");
            active.last_data = now;
            deployer.send_batch(&active.handle, batch)?;
        }
        if let Some(active) = &b.active {
            b.outputs.extend(deployer.poll(&active.handle, usize::MAX)?);
            let age = now.duration_since(active.activated_at);
            let idle = now.duration_since(active.last_data);
            if msgs.is_empty() && b.opts.idle.decide(age, idle, idle) {
                let active = b.active.take().expect("checked above");
                let outcome =
                    warm.park(deployer, name, active.handle, b.stateful, &b.pipeline)?;
                b.outputs.extend(outcome.tail);
                b.stats.decommissions += 1;
                metrics.counter("trigger.decommissions").inc();
                events.decommissioned = true;
                evicted_tails = outcome.evicted;
            }
        }
        for (owner, tail) in evicted_tails {
            if let Some(other) = bindings.get_mut(&owner) {
                other.outputs.extend(tail);
            }
        }
        Ok(events)
    }

    /// Best-effort teardown after a step error: the activation (if
    /// any) is stopped and discarded, the binding returns to idle so
    /// the next matching data cold-starts a fresh instance.
    pub(crate) fn fail(&mut self, name: &str) {
        let Self { deployer, bindings, metrics, .. } = self;
        let Some(b) = bindings.get_mut(name) else { return };
        if let Some(active) = b.active.take() {
            match deployer.stop(&active.handle) {
                Ok(tail) => b.outputs.extend(tail),
                Err(e) => log::warn!("trigger `{name}`: teardown after fault: {e}"),
            }
        }
        b.stats.faults += 1;
        metrics.counter("trigger.faults").inc();
    }

    /// Count a refused activation attempt against the binding.
    pub(crate) fn note_rejection(&mut self, name: &str) {
        if let Some(b) = self.bindings.get_mut(name) {
            b.stats.rejections += 1;
        }
        self.metrics.counter("trigger.rejected").inc();
    }

    /// Force every activation to zero *now* (shutdown), ignoring idle
    /// watermarks, and drain the warm pool. Outputs stay buffered.
    pub(crate) fn decommission_all(&mut self) -> Result<()> {
        let mut first_err: Option<Error> = None;
        let Self { deployer, bindings, warm, metrics } = self;
        for (name, b) in bindings.iter_mut() {
            if let Some(active) = b.active.take() {
                match deployer.stop(&active.handle) {
                    Ok(tail) => {
                        b.outputs.extend(tail);
                        b.stats.decommissions += 1;
                        metrics.counter("trigger.decommissions").inc();
                    }
                    Err(e) => {
                        log::error!("trigger `{name}`: decommission: {e}");
                        b.stats.faults += 1;
                        metrics.counter("trigger.faults").inc();
                        first_err.get_or_insert(e);
                    }
                }
            }
        }
        match warm.drain_all(deployer) {
            Ok(tails) => {
                for (owner, tail) in tails {
                    if let Some(b) = bindings.get_mut(&owner) {
                        b.outputs.extend(tail);
                    }
                }
            }
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Evict warm entries whose warmth expired; tails are routed back
    /// to their bindings' output buffers.
    pub(crate) fn sweep_warm(&mut self) -> Result<()> {
        let Self { deployer, bindings, warm, .. } = self;
        for (owner, tail) in warm.sweep(deployer)? {
            if let Some(b) = bindings.get_mut(&owner) {
                b.outputs.extend(tail);
            }
        }
        Ok(())
    }

    /// Memory-pressure reclaim: shrink the warm pool to `keep`
    /// entries, coldest-first. Returns how many were evicted.
    pub(crate) fn reclaim_warm(&mut self, keep: usize) -> Result<usize> {
        let Self { deployer, bindings, warm, .. } = self;
        let (evicted, tails) = warm.reclaim(deployer, keep)?;
        for (owner, tail) in tails {
            if let Some(b) = bindings.get_mut(&owner) {
                b.outputs.extend(tail);
            }
        }
        Ok(evicted)
    }

    pub(crate) fn set_warm_policy(&mut self, policy: WarmPolicy) {
        self.warm.set_policy(policy);
    }

    pub(crate) fn warm_resident(&self) -> usize {
        self.warm.resident()
    }

    pub(crate) fn take_outputs(&mut self, name: &str) -> Vec<Tuple> {
        self.bindings
            .get_mut(name)
            .map(|b| std::mem::take(&mut b.outputs))
            .unwrap_or_default()
    }

    /// Drain every non-empty output buffer (the concurrent pool ships
    /// these back to the caller with each step result).
    pub(crate) fn drain_outputs(&mut self) -> Vec<(String, Vec<Tuple>)> {
        self.bindings
            .iter_mut()
            .filter(|(_, b)| !b.outputs.is_empty())
            .map(|(n, b)| (n.clone(), std::mem::take(&mut b.outputs)))
            .collect()
    }

    pub(crate) fn is_active(&self, name: &str) -> bool {
        self.bindings.get(name).is_some_and(|b| b.active.is_some())
    }

    pub(crate) fn active(&self) -> Vec<String> {
        self.bindings
            .iter()
            .filter(|(_, b)| b.active.is_some())
            .map(|(n, _)| n.clone())
            .collect()
    }

    pub(crate) fn active_count(&self) -> usize {
        self.bindings.values().filter(|b| b.active.is_some()).count()
    }

    pub(crate) fn bound(&self) -> Vec<String> {
        self.bindings.keys().cloned().collect()
    }

    /// `(binding, tenant)` pairs in name order (scheduler input).
    pub(crate) fn roster(&self) -> Vec<(String, String)> {
        self.bindings.iter().map(|(n, b)| (n.clone(), b.tenant.clone())).collect()
    }

    pub(crate) fn consumer(&self, name: &str) -> Option<String> {
        self.bindings.get(name).map(|b| b.consumer.clone())
    }

    pub(crate) fn tenant(&self, name: &str) -> Option<String> {
        self.bindings.get(name).map(|b| b.tenant.clone())
    }

    pub(crate) fn stats(&self, name: &str) -> Option<TriggerStats> {
        self.bindings.get(name).map(|b| b.stats.clone())
    }
}

/// Binds pipelines to data profiles over any [`Deployer`] surface and
/// drives the activate/feed/decommission lifecycle from the caller's
/// thread — the *sequential* pump, kept as the deterministic baseline
/// (`RPULSAR_TRIGGERPLANE=sync`) of the concurrent
/// [`TriggerPool`](crate::pipeline::concurrent::TriggerPool). Both
/// pumps share the same admission, fairness and warm-pool semantics;
/// `rust/tests/trigger_scale.rs` property-tests their output
/// equivalence.
pub struct TriggerManager<D: Deployer> {
    runner: BindingRunner<D>,
    admission: AdmissionControl,
    sched: FairScheduler,
}

impl TriggerManager<TopologyManager> {
    /// The common composition: trigger-activated pipelines running on
    /// an in-process executor.
    pub fn in_process() -> Self {
        Self::new(TopologyManager::new(StreamEngine::new()))
    }
}

impl<D: Deployer> TriggerManager<D> {
    /// Bind the lifecycle to an existing deploy surface.
    pub fn new(deployer: D) -> Self {
        Self::with_metrics(deployer, Registry::new())
    }

    /// Share a metrics registry (node/bench composition).
    pub fn with_metrics(deployer: D, metrics: Registry) -> Self {
        TriggerManager {
            runner: BindingRunner::new(deployer, metrics),
            admission: AdmissionControl::default(),
            sched: FairScheduler::new(),
        }
    }

    /// The underlying deploy surface.
    pub fn deployer(&self) -> &D {
        self.runner.deployer()
    }

    pub fn deployer_mut(&mut self) -> &mut D {
        self.runner.deployer_mut()
    }

    /// Activation/decommission counters + cold/warm-start histograms.
    pub fn metrics(&self) -> &Registry {
        self.runner.metrics()
    }

    /// Bound in-flight activations (default: unlimited).
    pub fn set_admission(&mut self, admission: AdmissionControl) {
        self.admission = admission;
    }

    pub fn admission(&self) -> &AdmissionControl {
        &self.admission
    }

    /// Opt into warm pooling (default: [`WarmPolicy::disabled`]).
    pub fn set_warm_policy(&mut self, policy: WarmPolicy) {
        self.runner.set_warm_policy(policy);
    }

    /// Parked warm pipelines right now.
    pub fn warm_resident(&self) -> usize {
        self.runner.warm_resident()
    }

    /// Memory-pressure reclaim: shrink the warm pool to `keep`
    /// entries, coldest-first. Returns how many were evicted.
    pub fn reclaim_warm(&mut self, keep: usize) -> Result<usize> {
        self.runner.reclaim_warm(keep)
    }

    /// Lifetime admitted activations per tenant.
    pub fn admitted_by_tenant(&self) -> &BTreeMap<String, u64> {
        self.sched.admitted()
    }

    /// Bind `pipeline` to `profile`: matching data arriving at `broker`
    /// from now on activates the pipeline on demand. The binding works
    /// against any [`MatchingPlane`] — a single
    /// [`Broker`](crate::mmq::pubsub::Broker) or the sharded router
    /// ([`crate::ar::shard::ShardedBroker`]), so triggers
    /// bind through the shard router unchanged. The pipeline is
    /// fully validated against the deploy surface *here* — an invalid
    /// definition is rejected at bind time, never at 3am when the
    /// first matching tuple arrives. Binding names (pipeline names)
    /// are unique.
    pub fn bind(
        &mut self,
        broker: &mut impl MatchingPlane,
        pipeline: Pipeline,
        profile: Profile,
        opts: TriggerOptions,
    ) -> Result<()> {
        let consumer = self.runner.attach(pipeline, opts)?;
        broker.subscribe(&consumer, profile);
        Ok(())
    }

    /// Remove a binding: unsubscribe its consumer, decommission any
    /// live activation (zero-loss drain) and return everything the
    /// binding ever produced that was not yet taken.
    pub fn unbind(&mut self, broker: &mut impl MatchingPlane, name: &str) -> Result<Vec<Tuple>> {
        let consumer = self
            .runner
            .consumer(name)
            .ok_or_else(|| Error::NotFound(format!("no trigger binding `{name}`")))?;
        broker.unsubscribe(&consumer);
        self.runner.detach(name)
    }

    /// One lifecycle pass over every binding, in the fair scheduler's
    /// order: idle bindings are `lag`-gated (no backlog → no fetch)
    /// and admission-gated (cap reached → deferred with the cursor
    /// unmoved); admitted and already-active bindings fetch and run
    /// their lifecycle step. A faulted binding is torn down and
    /// reported; the other bindings still complete their pass (first
    /// error wins).
    pub fn pump(&mut self, broker: &mut impl MatchingPlane) -> Result<()> {
        self.runner.sweep_warm()?;
        let order = self.sched.order(&self.runner.roster());
        // Snapshot semantics: slots freed mid-pass open up next pass.
        let mut active_now = self.runner.active_count();
        let mut first_err: Option<Error> = None;
        for name in order {
            let Some(consumer) = self.runner.consumer(&name) else { continue };
            if !self.runner.is_active(&name) {
                let lag = match broker.lag(&consumer) {
                    Ok(lag) => lag,
                    Err(e) => {
                        self.runner.fail(&name);
                        first_err.get_or_insert(e);
                        continue;
                    }
                };
                if lag == 0 {
                    continue;
                }
                if !self.admission.admit(active_now) {
                    self.runner.note_rejection(&name);
                    if self.admission.strict {
                        first_err.get_or_insert(self.admission.refusal(&name, active_now));
                    }
                    continue;
                }
                active_now += 1;
                let tenant = self.runner.tenant(&name).unwrap_or_else(|| name.clone());
                self.sched.charge(&tenant);
                self.runner.metrics().counter("trigger.admitted").inc();
            }
            let msgs = match broker.fetch(&consumer, FETCH_MAX) {
                Ok(msgs) => msgs,
                Err(e) => {
                    self.runner.fail(&name);
                    first_err.get_or_insert(e);
                    continue;
                }
            };
            if let Err(e) = self.runner.step(&name, msgs) {
                self.runner.fail(&name);
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Total unfetched backlog across every binding's consumer.
    pub fn backlog(&self, broker: &impl MatchingPlane) -> Result<u64> {
        let mut total = 0;
        for (name, _) in self.runner.roster() {
            if let Some(consumer) = self.runner.consumer(&name) {
                total += broker.lag(&consumer)?;
            }
        }
        Ok(total)
    }

    /// Keep pumping until every binding is idle *and* every backlog is
    /// drained (admission may defer backlog across passes), or
    /// `timeout` elapses; errors surface immediately. Convenience for
    /// drains in tests/benches.
    pub fn pump_until_idle(
        &mut self,
        broker: &mut impl MatchingPlane,
        timeout: Duration,
    ) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            self.pump(broker)?;
            if self.active().is_empty() && self.backlog(broker)? == 0 {
                return Ok(());
            }
            if Instant::now() >= deadline {
                return Err(Error::Timeout(format!(
                    "trigger bindings still active after {timeout:?}: {:?}",
                    self.active()
                )));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Force every activation to zero *now* (node shutdown), ignoring
    /// idle watermarks, and drain the warm pool. Outputs stay buffered
    /// for [`Self::take_outputs`].
    pub fn decommission_all(&mut self) -> Result<()> {
        self.runner.decommission_all()
    }

    /// Take everything a binding's activations have produced so far.
    pub fn take_outputs(&mut self, name: &str) -> Vec<Tuple> {
        self.runner.take_outputs(name)
    }

    /// Whether a binding currently has a live activation.
    pub fn is_active(&self, name: &str) -> bool {
        self.runner.is_active(name)
    }

    /// Names of bindings with live activations.
    pub fn active(&self) -> Vec<String> {
        self.runner.active()
    }

    /// All binding names.
    pub fn bound(&self) -> Vec<String> {
        self.runner.bound()
    }

    /// A binding's lifetime counters.
    pub fn stats(&self, name: &str) -> Option<TriggerStats> {
        self.runner.stats(name)
    }
}

impl<D: Deployer> std::fmt::Debug for TriggerManager<D> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TriggerManager(bindings={}, active={}, warm={})",
            self.runner.bound().len(),
            self.runner.active_count(),
            self.runner.warm_resident()
        )
    }
}

/// Broker payload → tuple. Encoded frames carry their own seq and
/// fields; raw payloads get a binding-assigned sequence number.
fn as_tuple(decode: bool, raw_seq: &mut u64, payload: &[u8]) -> Tuple {
    if decode {
        if let Ok(t) = Tuple::decode(payload) {
            return t;
        }
    }
    let t = Tuple::new(*raw_seq, payload.to_vec());
    *raw_seq += 1;
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::shard::ShardedBroker;
    use crate::mmq::pubsub::Broker;
    use crate::mmq::queue::QueueOptions;
    use crate::stream::operator::{Operator, OperatorKind};
    use crate::stream::pipeline::PipelineStage;

    fn broker(name: &str) -> Broker {
        let dir = std::env::temp_dir()
            .join("rpulsar-trigger-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Broker::new(QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 })
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    fn inc_pipeline(name: &str) -> Pipeline {
        Pipeline::builder(name)
            .stage(PipelineStage::new("inc").operator(|| {
                Box::new(OperatorKind::map("inc", |mut t| {
                    let v = t.get("X").unwrap_or(0.0);
                    t.set("X", v + 1.0);
                    t
                })) as Box<dyn Operator>
            }))
            .build()
            .unwrap()
    }

    fn window_pipeline(name: &str) -> Pipeline {
        Pipeline::builder(name)
            .stage(PipelineStage::new("kwin").keyed("K").operator(|| {
                Box::new(OperatorKind::window_by("kwin", "X", 4, "K")) as Box<dyn Operator>
            }))
            .build()
            .unwrap()
    }

    fn eager() -> TriggerOptions {
        TriggerOptions {
            idle: RetirePolicy {
                max_publish_idle: Duration::ZERO,
                max_fetch_idle: Duration::ZERO,
                min_age: Duration::ZERO,
            },
            decode_payloads: true,
            tenant: None,
        }
    }

    #[test]
    fn data_arrival_cold_starts_and_idle_decommissions() {
        let mut broker = broker("lifecycle");
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut broker, inc_pipeline("job"), p("drone,*"), eager()).unwrap();
        // Bound but idle: no deploy has happened, pump is a no-op.
        assert!(!trig.is_active("job"));
        trig.pump(&mut broker).unwrap();
        assert!(!trig.is_active("job"));
        assert_eq!(trig.stats("job").unwrap().activations, 0);
        // Non-matching data does not activate.
        broker.publish(&p("truck,gps"), &Tuple::new(0, vec![]).encode()).unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(!trig.is_active("job"));
        // Matching data cold-starts the pipeline.
        broker
            .publish(&p("drone,lidar"), &Tuple::new(1, vec![]).with("X", 1.0).encode())
            .unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(trig.is_active("job"), "matching data must activate");
        let stats = trig.stats("job").unwrap();
        assert_eq!(stats.activations, 1);
        assert!(stats.last_cold_start.is_some());
        assert_eq!(stats.tuples_fed, 1);
        // Next pump fetches nothing → the zero-threshold idle policy
        // decommissions back to zero.
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        assert!(!trig.is_active("job"));
        let stats = trig.stats("job").unwrap();
        assert_eq!(stats.decommissions, 1);
        assert_eq!(trig.metrics().counter("trigger.activations").get(), 1);
        assert_eq!(trig.metrics().counter("trigger.decommissions").get(), 1);
        assert_eq!(trig.metrics().histogram("trigger.cold_start_us").count(), 1);
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(2.0));
        // Re-activation on the next matching publish.
        broker
            .publish(&p("drone,lidar"), &Tuple::new(2, vec![]).with("X", 5.0).encode())
            .unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(trig.is_active("job"));
        assert_eq!(trig.stats("job").unwrap().activations, 2);
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(6.0));
    }

    #[test]
    fn triggers_bind_through_the_shard_router() {
        // Same lifecycle, but the matching plane is a ShardedBroker:
        // publishes land on owner shards, the trigger's consumer is
        // registered on every shard, and activation still fires.
        let dir = std::env::temp_dir()
            .join("rpulsar-trigger-tests")
            .join(format!("sharded-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut plane = ShardedBroker::new(
            QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 },
            ["s0", "s1", "s2"],
        );
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut plane, inc_pipeline("job"), p("drone*,*"), eager()).unwrap();
        for i in 0..6u64 {
            plane
                .publish(
                    &p(&format!("drone{i:02},lidar")),
                    &Tuple::new(i, vec![]).with("X", i as f64).encode(),
                )
                .unwrap();
        }
        trig.pump_until_idle(&mut plane, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 6, "tuples from every shard must reach the pipeline");
        assert_eq!(trig.stats("job").unwrap().tuples_fed, 6);
        assert!(trig.unbind(&mut plane, "job").is_ok());
        assert!(!plane.is_registered("trigger:job"));
    }

    #[test]
    fn data_published_while_idle_is_not_lost() {
        // The binding's cursor holds the backlog across the idle gap.
        let mut broker = broker("backlog");
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), eager()).unwrap();
        for i in 0..5u64 {
            broker
                .publish(&p("s,t"), &Tuple::new(i, vec![]).with("X", i as f64).encode())
                .unwrap();
        }
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        assert_eq!(trig.take_outputs("job").len(), 5);
        // Published while decommissioned…
        for i in 5..9u64 {
            broker
                .publish(&p("s,t"), &Tuple::new(i, vec![]).with("X", i as f64).encode())
                .unwrap();
        }
        // …and delivered in full by the next activation.
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 4, "backlog across the idle gap must survive");
        assert_eq!(trig.stats("job").unwrap().activations, 2);
    }

    #[test]
    fn invalid_pipeline_rejected_at_bind_not_at_first_tuple() {
        let mut broker = broker("invalid");
        let mut trig = TriggerManager::in_process();
        let bad = Pipeline::parse("ghostly", "ghost").unwrap();
        let err = trig.bind(&mut broker, bad, p("s,*"), eager()).unwrap_err();
        assert!(format!("{err}").contains("unknown stage `ghost`"), "{err}");
        assert!(trig.bound().is_empty());
    }

    #[test]
    fn duplicate_binding_rejected() {
        let mut broker = broker("dup");
        let mut trig = TriggerManager::in_process();
        trig.bind(&mut broker, inc_pipeline("job"), p("a,*"), eager()).unwrap();
        let err = trig
            .bind(&mut broker, inc_pipeline("job"), p("b,*"), eager())
            .unwrap_err();
        assert!(format!("{err}").contains("already bound"), "{err}");
    }

    #[test]
    fn unbind_decommissions_and_returns_outputs() {
        let mut broker = broker("unbind");
        let mut trig = TriggerManager::in_process();
        // Patient policy: stays active until unbind.
        let opts = TriggerOptions::default();
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), opts).unwrap();
        broker.publish(&p("s,t"), &Tuple::new(0, vec![]).with("X", 1.0).encode()).unwrap();
        trig.pump(&mut broker).unwrap();
        assert!(trig.is_active("job"));
        let out = trig.unbind(&mut broker, "job").unwrap();
        assert_eq!(out.len(), 1);
        assert!(trig.bound().is_empty());
        assert!(trig.unbind(&mut broker, "job").is_err());
    }

    #[test]
    fn raw_payloads_flow_with_assigned_seqs() {
        let mut broker = broker("raw");
        let mut trig = TriggerManager::in_process();
        let opts = TriggerOptions { decode_payloads: false, ..eager() };
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), opts).unwrap();
        broker.publish(&p("s,t"), b"not-a-tuple").unwrap();
        broker.publish(&p("s,t"), b"also-raw").unwrap();
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].payload, b"not-a-tuple");
    }

    #[test]
    fn faulted_activation_returns_to_zero_and_restarts_fresh() {
        let mut broker = broker("fault");
        let mut trig = TriggerManager::in_process();
        let boom = Pipeline::builder("boom")
            .stage(PipelineStage::new("explode").operator(|| {
                Box::new(OperatorKind::map("explode", |t| {
                    if t.get("BAD") == Some(1.0) {
                        panic!("injected trigger fault");
                    }
                    t
                })) as Box<dyn Operator>
            }))
            .build()
            .unwrap();
        trig.bind(&mut broker, boom, p("s,*"), eager()).unwrap();
        broker.publish(&p("s,t"), &Tuple::new(0, vec![]).with("BAD", 1.0).encode()).unwrap();
        // The panic surfaces from some pump pass (feed or drain), the
        // binding is torn down and idle again.
        let mut failed = false;
        for _ in 0..50 {
            match trig.pump(&mut broker) {
                Err(e) => {
                    assert!(format!("{e}").contains("injected trigger fault"), "{e}");
                    failed = true;
                    break;
                }
                Ok(()) if !trig.is_active("boom") && trig.stats("boom").unwrap().faults > 0 => {
                    failed = true;
                    break;
                }
                Ok(()) => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert!(failed, "fault must surface");
        assert!(!trig.is_active("boom"));
        assert_eq!(trig.stats("boom").unwrap().faults, 1);
        // A clean tuple re-activates a fresh instance end to end.
        broker.publish(&p("s,t"), &Tuple::new(1, vec![]).with("X", 1.0).encode()).unwrap();
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        assert_eq!(trig.stats("boom").unwrap().activations, 2);
        let out = trig.take_outputs("boom");
        assert_eq!(out.len(), 1, "fresh activation must process cleanly");
    }

    #[test]
    fn fair_scheduler_rotates_and_pays_deficit() {
        let roster = vec![
            ("a1".to_string(), "ta".to_string()),
            ("a2".to_string(), "ta".to_string()),
            ("z0".to_string(), "tz".to_string()),
        ];
        let mut sched = FairScheduler::new();
        // Pass 1: no deficit, no rotation → tenant-interleaved name
        // order.
        assert_eq!(sched.order(&roster), ["a1", "z0", "a2"]);
        // `ta` gets an activation; pass 2 must front the zero-deficit
        // tenant and rotate within `ta`.
        sched.charge("ta");
        assert_eq!(sched.order(&roster), ["z0", "a2", "a1"]);
        // Equal deficit again → the rotating start breaks the tie the
        // other way on some later pass (starvation-free even on ties).
        sched.charge("tz");
        let pass3 = sched.order(&roster);
        assert_eq!(pass3.len(), 3);
        assert!(pass3.contains(&"z0".to_string()));
    }

    #[test]
    fn strict_admission_surfaces_structured_refusal_and_retry_loses_nothing() {
        let mut broker = broker("admission");
        let mut trig = TriggerManager::in_process();
        trig.set_admission(AdmissionControl::strict(0));
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), eager()).unwrap();
        broker.publish(&p("s,t"), &Tuple::new(0, vec![]).with("X", 1.0).encode()).unwrap();
        let err = trig.pump(&mut broker).unwrap_err();
        assert_eq!(err.kind(), "admission", "{err}");
        assert!(!trig.is_active("job"), "a refused binding must not activate");
        assert_eq!(trig.stats("job").unwrap().rejections, 1);
        assert_eq!(trig.metrics().counter("trigger.rejected").get(), 1);
        // Lifting the cap delivers the full backlog: refusal left the
        // cursor unmoved.
        trig.set_admission(AdmissionControl::unlimited());
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("job");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(2.0));
    }

    #[test]
    fn warm_pool_serves_reactivation_without_redeploy() {
        let mut broker = broker("warm");
        let mut trig = TriggerManager::in_process();
        trig.set_warm_policy(WarmPolicy::retain(2));
        trig.bind(&mut broker, inc_pipeline("job"), p("s,*"), eager()).unwrap();
        broker.publish(&p("s,t"), &Tuple::new(0, vec![]).with("X", 1.0).encode()).unwrap();
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        // Decommissioned to idle — but parked warm (live), not stopped.
        assert!(!trig.is_active("job"));
        assert_eq!(trig.warm_resident(), 1);
        assert_eq!(trig.stats("job").unwrap().decommissions, 1);
        // Re-activation takes the parked instance: a warm start.
        broker.publish(&p("s,t"), &Tuple::new(1, vec![]).with("X", 5.0).encode()).unwrap();
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let stats = trig.stats("job").unwrap();
        assert_eq!(stats.activations, 2);
        assert_eq!(stats.warm_starts, 1);
        assert_eq!(trig.metrics().counter("trigger.warm_hits").get(), 1);
        assert_eq!(trig.metrics().histogram("trigger.warm_start_us").count(), 1);
        // Reclaim under memory pressure drains the pool through the
        // deployer; the stop flushes everything the live-parked
        // instance still held — both bursts' outputs, none lost.
        assert_eq!(trig.reclaim_warm(0).unwrap(), 1);
        assert_eq!(trig.warm_resident(), 0);
        assert!(trig.deployer().running().is_empty());
        let mut xs: Vec<f64> =
            trig.take_outputs("job").iter().filter_map(|t| t.get("X")).collect();
        xs.sort_by(f64::total_cmp);
        assert_eq!(xs, [2.0, 6.0]);
    }

    #[test]
    fn stateful_pipelines_flush_when_parked() {
        // A keyed window must not carry open-window state across a
        // scale-to-zero boundary: parking flushes (warm ≡ cold), and
        // the warm standby starts stateless-fresh.
        let mut broker = broker("warm-stateful");
        let mut trig = TriggerManager::in_process();
        trig.set_warm_policy(WarmPolicy::retain(1));
        trig.bind(&mut broker, window_pipeline("win"), p("s,*"), eager()).unwrap();
        for i in 0..2u64 {
            broker
                .publish(
                    &p("s,t"),
                    &Tuple::new(i, vec![]).with("K", 1.0).with("X", 10.0).encode(),
                )
                .unwrap();
        }
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        // Park flushed the 2-element partial window.
        let out = trig.take_outputs("win");
        assert_eq!(out.len(), 1, "partial window must flush at park");
        assert_eq!(trig.warm_resident(), 1, "a fresh standby is parked");
        // Second burst is served warm and flushes its own partial —
        // exactly what a cold path would produce.
        for i in 2..4u64 {
            broker
                .publish(
                    &p("s,t"),
                    &Tuple::new(i, vec![]).with("K", 1.0).with("X", 20.0).encode(),
                )
                .unwrap();
        }
        trig.pump_until_idle(&mut broker, Duration::from_secs(10)).unwrap();
        let out = trig.take_outputs("win");
        assert_eq!(out.len(), 1, "state must not leak across the boundary");
        assert_eq!(trig.stats("win").unwrap().warm_starts, 1);
    }
}
