//! The paper's motivating use case (§II, §V-B): the disaster-recovery
//! data pipeline.
//!
//! - [`lidar`]: synthetic LiDAR trace reproducing the Hurricane-Sandy
//!   dataset's shape (741 images, log-normal size spread from 1.8 KB to
//!   33.8 MB — scaled down for CI) with damage-like image content.
//! - [`workflow`]: the end-to-end pipeline — drone capture → mmap
//!   collection → PJRT pre-processing → IF-THEN decision → store at the
//!   edge or forward to the core — plus the two baseline pipelines
//!   (Kafka+Edgent+{SQLite, Nitrite}) of Fig. 14.
//! - [`trigger`]: data-driven activation — a typed
//!   [`crate::stream::pipeline::Pipeline`] bound to an AR profile
//!   cold-starts when matching data reaches the broker, feeds from its
//!   topic cursor, and scales back to zero after an idle watermark
//!   (the serverless half of "data-driven pipelines"). Admission
//!   control and per-tenant fair scheduling live here too.
//! - [`concurrent`]: the scaled trigger plane — a shared worker pool
//!   pumping thousands of bindings concurrently with the same
//!   admission/fairness/output semantics as the sequential manager
//!   (`RPULSAR_TRIGGERPLANE=sync` selects the baseline).
//! - [`pool`]: warm pipeline pools — bounded retention of
//!   decommissioned pipelines so re-activation approaches re-attach
//!   latency instead of a full cold start.

pub mod concurrent;
pub mod lidar;
pub mod pool;
pub mod trigger;
pub mod workflow;

pub use concurrent::TriggerPool;
pub use lidar::{LidarImage, LidarTrace};
pub use pool::{SnapshotSource, WarmPolicy, WarmPool};
pub use trigger::{AdmissionControl, TriggerManager, TriggerOptions, TriggerStats};
pub use workflow::{BaselineKind, DisasterRecoveryPipeline, PipelineReport};
