//! The paper's motivating use case (§II, §V-B): the disaster-recovery
//! data pipeline.
//!
//! - [`lidar`]: synthetic LiDAR trace reproducing the Hurricane-Sandy
//!   dataset's shape (741 images, log-normal size spread from 1.8 KB to
//!   33.8 MB — scaled down for CI) with damage-like image content.
//! - [`workflow`]: the end-to-end pipeline — drone capture → mmap
//!   collection → PJRT pre-processing → IF-THEN decision → store at the
//!   edge or forward to the core — plus the two baseline pipelines
//!   (Kafka+Edgent+{SQLite, Nitrite}) of Fig. 14.
//! - [`trigger`]: data-driven activation — a typed
//!   [`crate::stream::pipeline::Pipeline`] bound to an AR profile
//!   cold-starts when matching data reaches the broker, feeds from its
//!   topic cursor, and scales back to zero after an idle watermark
//!   (the serverless half of "data-driven pipelines").

pub mod lidar;
pub mod trigger;
pub mod workflow;

pub use lidar::{LidarImage, LidarTrace};
pub use trigger::{TriggerManager, TriggerOptions, TriggerStats};
pub use workflow::{BaselineKind, DisasterRecoveryPipeline, PipelineReport};
