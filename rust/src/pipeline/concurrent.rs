//! Concurrent trigger plane: a shared worker pool pumping many
//! bindings off one [`MatchingPlane`] at once.
//!
//! The sequential [`TriggerManager`](super::trigger::TriggerManager)
//! activates bindings one at a time from the caller's thread — fine
//! for a dozen bindings, a bottleneck for a node hosting thousands
//! (the ISSUE's serverless-at-scale gap: every cold start serializes
//! behind every other). [`TriggerPool`] splits the plane in two:
//!
//! - The **front end** (caller thread) owns the broker. Each
//!   [`TriggerPool::pump`] pass runs the *same* gating as the
//!   sequential pump — fair-scheduler order, `lag`-gate, admission
//!   cap with pass-start snapshot semantics — then fetches each
//!   admitted binding's batch and dispatches it to the binding's
//!   worker. Because gating and fetching stay single-threaded on the
//!   broker owner, concurrent and sequential mode take *identical*
//!   admission decisions and deliver identical batches; only the
//!   lifecycle work (deploy, feed, poll, park) runs in parallel.
//! - Each **worker** owns a full
//!   [`BindingRunner`](super::trigger::BindingRunner) — deployer,
//!   bindings, warm pool — built from a deployer factory invoked *on*
//!   the worker thread (so non-`Send` deployers work). A binding
//!   lives on exactly one worker (round-robin at bind), so per-binding
//!   order is preserved: batches for one binding execute in dispatch
//!   order on one thread.
//!
//! **Faults** follow the shipper idiom (PR 6): a panicking step is
//! caught per-worker (`catch_unwind`), the binding is torn down
//! best-effort, the pass reports the first error, and every other
//! binding keeps processing — first-fault-wins without poisoning the
//! pool. `rust/tests/failure_injection.rs` drives this with the
//! `RPULSAR_TEST_TRIGGER_PANIC` hook.
//!
//! Output equivalence (concurrent ≡ sequential, multiset per binding)
//! is property-tested in `rust/tests/trigger_scale.rs` and
//! pre-validated by `python/sims/trigger_scale_sim.py`; throughput is
//! measured by the fig17 10k-binding burst arm.

use crate::ar::profile::Profile;
use crate::ar::shard::MatchingPlane;
use crate::error::{Error, Result};
use crate::metrics::Registry;
use crate::pipeline::pool::WarmPolicy;
use crate::pipeline::trigger::{
    AdmissionControl, BindingRunner, FairScheduler, StepEvents, TriggerOptions, TriggerStats,
    FETCH_MAX,
};
use crate::stream::deploy::TopologyManager;
use crate::stream::engine::StreamEngine;
use crate::stream::pipeline::{Deployer, Pipeline};
use crate::stream::tuple::Tuple;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Test hook (failure injection): when this env var equals a binding
/// name, the worker stepping that binding panics mid-activation.
const TRIGGER_PANIC_ENV: &str = "RPULSAR_TEST_TRIGGER_PANIC";

/// Commands the front end sends to a worker.
enum Cmd {
    Attach { pipeline: Pipeline, opts: TriggerOptions, reply: Sender<Result<String>> },
    Detach { name: String, reply: Sender<Result<Vec<Tuple>>> },
    Step { name: String, msgs: Vec<(String, Arc<[u8]>)> },
    NoteRejection { name: String },
    Stats { name: String, reply: Sender<Option<TriggerStats>> },
    TakeOutputs { name: String, reply: Sender<Vec<Tuple>> },
    DecommissionAll { reply: Sender<(Result<()>, Vec<(String, Vec<Tuple>)>)> },
    SweepWarm,
    SetWarmPolicy { policy: WarmPolicy },
    WarmResident { reply: Sender<usize> },
    ReclaimWarm { keep: usize, reply: Sender<Result<usize>> },
    Shutdown,
}

/// One step's outcome, shipped back to the front end.
struct StepResult {
    name: String,
    events: Result<StepEvents>,
    /// Every non-empty output buffer on the worker — carries the
    /// stepped binding's outputs *and* any park-eviction tails routed
    /// to sibling bindings.
    outputs: Vec<(String, Vec<Tuple>)>,
}

/// Front-end view of one binding.
struct BindingMeta {
    consumer: String,
    tenant: String,
    worker: usize,
    active: bool,
}

struct Worker {
    tx: Sender<Cmd>,
    join: Option<JoinHandle<()>>,
}

/// The concurrent trigger plane: same binding lifecycle and admission
/// semantics as [`TriggerManager`](super::trigger::TriggerManager),
/// pumped by a shared pool of worker threads. Selected by default
/// where a pool is composed in (see
/// [`TRIGGERPLANE_ENV`](super::trigger::TRIGGERPLANE_ENV)).
pub struct TriggerPool {
    workers: Vec<Worker>,
    results: Receiver<StepResult>,
    bindings: BTreeMap<String, BindingMeta>,
    outputs: BTreeMap<String, Vec<Tuple>>,
    admission: AdmissionControl,
    sched: FairScheduler,
    metrics: Registry,
    next_worker: usize,
}

impl TriggerPool {
    /// A pool of `workers` threads, each owning a deployer built by
    /// `make` *on the worker thread* (register stages inside `make`;
    /// the deployer itself never crosses threads).
    pub fn new<D, F>(workers: usize, make: F) -> Self
    where
        D: Deployer + 'static,
        F: Fn() -> D + Send + Sync + 'static,
    {
        Self::with_metrics(workers, Registry::new(), make)
    }

    /// Same, sharing a metrics registry (node/bench composition).
    pub fn with_metrics<D, F>(workers: usize, metrics: Registry, make: F) -> Self
    where
        D: Deployer + 'static,
        F: Fn() -> D + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let make = Arc::new(make);
        let (res_tx, res_rx) = channel::<StepResult>();
        let mut pool = Vec::with_capacity(workers);
        for w in 0..workers {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            let make = Arc::clone(&make);
            let metrics = metrics.clone();
            let res_tx = res_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("trigger-worker-{w}"))
                .spawn(move || worker_loop(cmd_rx, res_tx, make(), metrics))
                .expect("spawn trigger worker");
            pool.push(Worker { tx: cmd_tx, join: Some(join) });
        }
        TriggerPool {
            workers: pool,
            results: res_rx,
            bindings: BTreeMap::new(),
            outputs: BTreeMap::new(),
            admission: AdmissionControl::default(),
            sched: FairScheduler::new(),
            metrics,
            next_worker: 0,
        }
    }

    /// The common composition: each worker gets its own in-process
    /// executor surface.
    pub fn in_process(workers: usize) -> Self {
        Self::new(workers, || TopologyManager::new(StreamEngine::new()))
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Bound in-flight activations across the whole pool (default:
    /// unlimited). Same snapshot semantics as the sequential pump.
    pub fn set_admission(&mut self, admission: AdmissionControl) {
        self.admission = admission;
    }

    pub fn admission(&self) -> &AdmissionControl {
        &self.admission
    }

    /// Opt every worker's warm pool into retention. `capacity` applies
    /// *per worker* — a pool of 4 workers with capacity 8 holds up to
    /// 32 warm pipelines.
    pub fn set_warm_policy(&mut self, policy: WarmPolicy) {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::SetWarmPolicy { policy: policy.clone() });
        }
    }

    /// Parked warm pipelines across all workers.
    pub fn warm_resident(&self) -> usize {
        let mut total = 0;
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Cmd::WarmResident { reply: tx }).is_ok() {
                total += rx.recv().unwrap_or(0);
            }
        }
        total
    }

    /// Memory-pressure reclaim: shrink the pool-wide warm population
    /// to at most `keep`. Quotas are assigned worker-by-worker
    /// (each worker evicts its own coldest-first); cross-worker
    /// coldness is approximated, not total-ordered — reclaim is a
    /// pressure valve, not a strict LRU.
    pub fn reclaim_warm(&mut self, keep: usize) -> Result<usize> {
        let residents: Vec<usize> = self
            .workers
            .iter()
            .map(|w| {
                let (tx, rx) = channel();
                if w.tx.send(Cmd::WarmResident { reply: tx }).is_ok() {
                    rx.recv().unwrap_or(0)
                } else {
                    0
                }
            })
            .collect();
        let mut budget = keep;
        let mut evicted_total = 0;
        for (w, &resident) in self.workers.iter().zip(&residents) {
            let keep_here = budget.min(resident);
            budget -= keep_here;
            if resident > keep_here {
                let (tx, rx) = channel();
                w.tx.send(Cmd::ReclaimWarm { keep: keep_here, reply: tx })
                    .map_err(|_| Error::Stream("trigger worker gone".into()))?;
                evicted_total += rx
                    .recv()
                    .map_err(|_| Error::Stream("trigger worker gone".into()))??;
            }
        }
        Ok(evicted_total)
    }

    /// Lifetime admitted activations per tenant.
    pub fn admitted_by_tenant(&self) -> &BTreeMap<String, u64> {
        self.sched.admitted()
    }

    /// Bind `pipeline` to `profile` on the next worker (round-robin).
    /// Validation happens on the worker's own deploy surface at bind
    /// time, same contract as the sequential manager.
    pub fn bind(
        &mut self,
        broker: &mut impl MatchingPlane,
        pipeline: Pipeline,
        profile: Profile,
        opts: TriggerOptions,
    ) -> Result<()> {
        if self.bindings.contains_key(pipeline.name()) {
            return Err(Error::Stream(format!(
                "pipeline `{}` is already bound",
                pipeline.name()
            )));
        }
        let name = pipeline.name().to_string();
        let tenant = opts.tenant.clone().unwrap_or_else(|| name.clone());
        let worker = self.next_worker % self.workers.len();
        let (tx, rx) = channel();
        self.workers[worker]
            .tx
            .send(Cmd::Attach { pipeline, opts, reply: tx })
            .map_err(|_| Error::Stream("trigger worker gone".into()))?;
        let consumer = rx
            .recv()
            .map_err(|_| Error::Stream("trigger worker gone".into()))??;
        self.next_worker = self.next_worker.wrapping_add(1);
        broker.subscribe(&consumer, profile);
        self.bindings
            .insert(name, BindingMeta { consumer, tenant, worker, active: false });
        Ok(())
    }

    /// Remove a binding: unsubscribe, decommission on its worker, and
    /// return everything it produced that was not yet taken.
    pub fn unbind(&mut self, broker: &mut impl MatchingPlane, name: &str) -> Result<Vec<Tuple>> {
        let meta = self
            .bindings
            .get(name)
            .ok_or_else(|| Error::NotFound(format!("no trigger binding `{name}`")))?;
        broker.unsubscribe(&meta.consumer);
        let (tx, rx) = channel();
        self.workers[meta.worker]
            .tx
            .send(Cmd::Detach { name: name.to_string(), reply: tx })
            .map_err(|_| Error::Stream("trigger worker gone".into()))?;
        let mut out = rx
            .recv()
            .map_err(|_| Error::Stream("trigger worker gone".into()))??;
        self.bindings.remove(name);
        if let Some(buffered) = self.outputs.remove(name) {
            let mut all = buffered;
            all.extend(out);
            out = all;
        }
        Ok(out)
    }

    /// One concurrent lifecycle pass: gate and fetch every binding on
    /// this thread (fair order, lag-gate, snapshot admission — the
    /// exact sequential semantics), dispatch admitted batches to the
    /// workers, then collect every step result. A faulted binding is
    /// torn down on its worker and reported; the other bindings still
    /// complete their pass (first error wins).
    pub fn pump(&mut self, broker: &mut impl MatchingPlane) -> Result<()> {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::SweepWarm);
        }
        let roster: Vec<(String, String)> = self
            .bindings
            .iter()
            .map(|(n, m)| (n.clone(), m.tenant.clone()))
            .collect();
        let order = self.sched.order(&roster);
        // Snapshot semantics: slots freed mid-pass open up next pass,
        // so the decisions below match the sequential pump exactly.
        let mut active_now = self.bindings.values().filter(|m| m.active).count();
        let mut first_err: Option<Error> = None;
        let mut dispatched = 0usize;
        for name in order {
            let Some(meta) = self.bindings.get(&name) else { continue };
            if !meta.active {
                let lag = match broker.lag(&meta.consumer) {
                    Ok(lag) => lag,
                    Err(e) => {
                        first_err.get_or_insert(e);
                        continue;
                    }
                };
                if lag == 0 {
                    continue;
                }
                if !self.admission.admit(active_now) {
                    let _ = self.workers[meta.worker]
                        .tx
                        .send(Cmd::NoteRejection { name: name.clone() });
                    if self.admission.strict {
                        first_err.get_or_insert(self.admission.refusal(&name, active_now));
                    }
                    continue;
                }
                active_now += 1;
                self.sched.charge(&meta.tenant.clone());
                self.metrics.counter("trigger.admitted").inc();
            }
            let msgs = match broker.fetch(&meta.consumer, FETCH_MAX) {
                Ok(msgs) => msgs,
                Err(e) => {
                    first_err.get_or_insert(e);
                    continue;
                }
            };
            let worker = meta.worker;
            if self.workers[worker]
                .tx
                .send(Cmd::Step { name: name.clone(), msgs })
                .is_err()
            {
                first_err.get_or_insert(Error::Stream(format!(
                    "trigger worker gone stepping `{name}`"
                )));
                continue;
            }
            dispatched += 1;
        }
        for _ in 0..dispatched {
            let res = self
                .results
                .recv()
                .map_err(|_| Error::Stream("trigger worker gone".into()))?;
            for (owner, tail) in res.outputs {
                self.outputs.entry(owner).or_default().extend(tail);
            }
            let meta = self.bindings.get_mut(&res.name);
            match res.events {
                Ok(ev) => {
                    if let Some(meta) = meta {
                        if ev.activated {
                            meta.active = true;
                        }
                        if ev.decommissioned {
                            meta.active = false;
                        }
                    }
                }
                Err(e) => {
                    if let Some(meta) = meta {
                        meta.active = false;
                    }
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Total unfetched backlog across every binding's consumer.
    pub fn backlog(&self, broker: &impl MatchingPlane) -> Result<u64> {
        let mut total = 0;
        for meta in self.bindings.values() {
            total += broker.lag(&meta.consumer)?;
        }
        Ok(total)
    }

    /// Keep pumping until every binding is idle *and* every backlog is
    /// drained, or `timeout` elapses; errors surface immediately.
    pub fn pump_until_idle(
        &mut self,
        broker: &mut impl MatchingPlane,
        timeout: Duration,
    ) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            self.pump(broker)?;
            if self.active().is_empty() && self.backlog(broker)? == 0 {
                return Ok(());
            }
            if Instant::now() >= deadline {
                return Err(Error::Timeout(format!(
                    "trigger bindings still active after {timeout:?}: {:?}",
                    self.active()
                )));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Force every activation to zero *now* and drain all warm pools.
    /// Outputs stay buffered for [`Self::take_outputs`].
    pub fn decommission_all(&mut self) -> Result<()> {
        let mut first_err: Option<Error> = None;
        let mut replies = Vec::new();
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Cmd::DecommissionAll { reply: tx }).is_ok() {
                replies.push(rx);
            } else {
                first_err.get_or_insert(Error::Stream("trigger worker gone".into()));
            }
        }
        for rx in replies {
            match rx.recv() {
                Ok((res, outputs)) => {
                    for (owner, tail) in outputs {
                        self.outputs.entry(owner).or_default().extend(tail);
                    }
                    if let Err(e) = res {
                        first_err.get_or_insert(e);
                    }
                }
                Err(_) => {
                    first_err.get_or_insert(Error::Stream("trigger worker gone".into()));
                }
            }
        }
        for meta in self.bindings.values_mut() {
            meta.active = false;
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Take everything a binding's activations have produced so far
    /// (step results already shipped here, plus anything still
    /// buffered on the worker).
    pub fn take_outputs(&mut self, name: &str) -> Vec<Tuple> {
        let mut out = self.outputs.remove(name).unwrap_or_default();
        if let Some(meta) = self.bindings.get(name) {
            let (tx, rx) = channel();
            if self.workers[meta.worker]
                .tx
                .send(Cmd::TakeOutputs { name: name.to_string(), reply: tx })
                .is_ok()
            {
                if let Ok(tail) = rx.recv() {
                    out.extend(tail);
                }
            }
        }
        out
    }

    /// A binding's lifetime counters (fetched from its worker).
    pub fn stats(&self, name: &str) -> Option<TriggerStats> {
        let meta = self.bindings.get(name)?;
        let (tx, rx) = channel();
        self.workers[meta.worker]
            .tx
            .send(Cmd::Stats { name: name.to_string(), reply: tx })
            .ok()?;
        rx.recv().ok()?
    }

    /// Whether a binding currently has a live activation.
    pub fn is_active(&self, name: &str) -> bool {
        self.bindings.get(name).is_some_and(|m| m.active)
    }

    /// Names of bindings with live activations.
    pub fn active(&self) -> Vec<String> {
        self.bindings
            .iter()
            .filter(|(_, m)| m.active)
            .map(|(n, _)| n.clone())
            .collect()
    }

    /// All binding names.
    pub fn bound(&self) -> Vec<String> {
        self.bindings.keys().cloned().collect()
    }
}

impl std::fmt::Debug for TriggerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TriggerPool(workers={}, bindings={}, active={})",
            self.workers.len(),
            self.bindings.len(),
            self.active().len()
        )
    }
}

impl Drop for TriggerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}

/// The worker loop: owns one [`BindingRunner`] and serves commands
/// until shutdown. Steps are panic-isolated.
fn worker_loop<D: Deployer>(
    cmds: Receiver<Cmd>,
    results: Sender<StepResult>,
    deployer: D,
    metrics: Registry,
) {
    let mut runner = BindingRunner::new(deployer, metrics);
    while let Ok(cmd) = cmds.recv() {
        match cmd {
            Cmd::Attach { pipeline, opts, reply } => {
                let _ = reply.send(runner.attach(pipeline, opts));
            }
            Cmd::Detach { name, reply } => {
                let _ = reply.send(runner.detach(&name));
            }
            Cmd::Step { name, msgs } => {
                let events = catch_unwind(AssertUnwindSafe(|| {
                    if std::env::var(TRIGGER_PANIC_ENV).as_deref() == Ok(name.as_str()) {
                        panic!("injected trigger worker panic");
                    }
                    runner.step(&name, msgs)
                }))
                .unwrap_or_else(|payload| {
                    let cause = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "unknown panic".to_string());
                    Err(Error::Stream(format!(
                        "trigger worker panicked pumping `{name}`: {cause}"
                    )))
                });
                if events.is_err() {
                    runner.fail(&name);
                }
                let outputs = runner.drain_outputs();
                if results.send(StepResult { name, events, outputs }).is_err() {
                    break; // front end gone — shut down
                }
            }
            Cmd::NoteRejection { name } => runner.note_rejection(&name),
            Cmd::Stats { name, reply } => {
                let _ = reply.send(runner.stats(&name));
            }
            Cmd::TakeOutputs { name, reply } => {
                let _ = reply.send(runner.take_outputs(&name));
            }
            Cmd::DecommissionAll { reply } => {
                let res = runner.decommission_all();
                let _ = reply.send((res, runner.drain_outputs()));
            }
            Cmd::SweepWarm => {
                if let Err(e) = runner.sweep_warm() {
                    log::warn!("trigger worker: warm sweep: {e}");
                }
            }
            Cmd::SetWarmPolicy { policy } => runner.set_warm_policy(policy),
            Cmd::WarmResident { reply } => {
                let _ = reply.send(runner.warm_resident());
            }
            Cmd::ReclaimWarm { keep, reply } => {
                let _ = reply.send(runner.reclaim_warm(keep));
            }
            Cmd::Shutdown => {
                if let Err(e) = runner.decommission_all() {
                    log::warn!("trigger worker: shutdown decommission: {e}");
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mmq::pubsub::{Broker, RetirePolicy};
    use crate::mmq::queue::QueueOptions;
    use crate::stream::operator::{Operator, OperatorKind};
    use crate::stream::pipeline::PipelineStage;

    fn broker(name: &str) -> Broker {
        let dir = std::env::temp_dir()
            .join("rpulsar-trigger-pool-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Broker::new(QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 })
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    fn inc_pipeline(name: &str) -> Pipeline {
        Pipeline::builder(name)
            .stage(PipelineStage::new("inc").operator(|| {
                Box::new(OperatorKind::map("inc", |mut t| {
                    let v = t.get("X").unwrap_or(0.0);
                    t.set("X", v + 1.0);
                    t
                })) as Box<dyn Operator>
            }))
            .build()
            .unwrap()
    }

    fn eager() -> TriggerOptions {
        TriggerOptions {
            idle: RetirePolicy {
                max_publish_idle: Duration::ZERO,
                max_fetch_idle: Duration::ZERO,
                min_age: Duration::ZERO,
            },
            decode_payloads: true,
            tenant: None,
        }
    }

    #[test]
    fn pool_runs_many_bindings_and_loses_nothing() {
        let mut broker = broker("fanout");
        let mut pool = TriggerPool::in_process(3);
        for i in 0..8 {
            pool.bind(
                &mut broker,
                inc_pipeline(&format!("job{i}")),
                p(&format!("s{i},*")),
                eager(),
            )
            .unwrap();
        }
        for i in 0..8u64 {
            for k in 0..5u64 {
                broker
                    .publish(
                        &p(&format!("s{i},t")),
                        &Tuple::new(k, vec![]).with("X", (i * 10 + k) as f64).encode(),
                    )
                    .unwrap();
            }
        }
        pool.pump_until_idle(&mut broker, Duration::from_secs(20)).unwrap();
        for i in 0..8u64 {
            let name = format!("job{i}");
            let mut xs: Vec<f64> =
                pool.take_outputs(&name).iter().filter_map(|t| t.get("X")).collect();
            xs.sort_by(f64::total_cmp);
            let want: Vec<f64> = (0..5).map(|k| (i * 10 + k) as f64 + 1.0).collect();
            assert_eq!(xs, want, "binding {name} lost or corrupted tuples");
            assert_eq!(pool.stats(&name).unwrap().tuples_fed, 5);
        }
        assert!(pool.active().is_empty());
    }

    #[test]
    fn pool_admission_defers_and_retry_drains() {
        let mut broker = broker("pool-admission");
        let mut pool = TriggerPool::in_process(2);
        pool.set_admission(AdmissionControl::bounded(1));
        for i in 0..4 {
            pool.bind(
                &mut broker,
                inc_pipeline(&format!("job{i}")),
                p(&format!("s{i},*")),
                eager(),
            )
            .unwrap();
        }
        for i in 0..4u64 {
            broker
                .publish(&p(&format!("s{i},t")), &Tuple::new(0, vec![]).with("X", 1.0).encode())
                .unwrap();
        }
        // A single pass admits exactly one activation…
        pool.pump(&mut broker).unwrap();
        assert!(pool.active().len() <= 1);
        assert!(pool.metrics().counter("trigger.rejected").get() >= 1);
        // …and retries drain everything with nothing lost.
        pool.pump_until_idle(&mut broker, Duration::from_secs(20)).unwrap();
        for i in 0..4u64 {
            assert_eq!(pool.take_outputs(&format!("job{i}")).len(), 1);
        }
    }

    #[test]
    fn pool_unbind_and_decommission_all() {
        let mut broker = broker("pool-unbind");
        let mut pool = TriggerPool::in_process(2);
        pool.bind(&mut broker, inc_pipeline("a"), p("a,*"), TriggerOptions::default())
            .unwrap();
        pool.bind(&mut broker, inc_pipeline("b"), p("b,*"), TriggerOptions::default())
            .unwrap();
        broker.publish(&p("a,t"), &Tuple::new(0, vec![]).with("X", 1.0).encode()).unwrap();
        broker.publish(&p("b,t"), &Tuple::new(0, vec![]).with("X", 2.0).encode()).unwrap();
        pool.pump(&mut broker).unwrap();
        assert_eq!(pool.active().len(), 2);
        let out = pool.unbind(&mut broker, "a").unwrap();
        assert_eq!(out.len(), 1);
        assert!(pool.unbind(&mut broker, "a").is_err());
        pool.decommission_all().unwrap();
        assert!(pool.active().is_empty());
        assert_eq!(pool.take_outputs("b").len(), 1);
    }
}
