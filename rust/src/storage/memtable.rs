//! In-memory write buffer: sorted map with byte accounting and
//! tombstones. The "most recently used data in main memory" half of the
//! paper's RocksDB-style storage contract.

use std::collections::BTreeMap;
use std::ops::Bound;

/// A value or a deletion marker (tombstone).
#[derive(Debug, Clone, PartialEq)]
pub enum Entry {
    Value(Vec<u8>),
    Tombstone,
}

impl Entry {
    fn approx_bytes(&self) -> usize {
        match self {
            Entry::Value(v) => v.len(),
            Entry::Tombstone => 1,
        }
    }
}

/// Sorted in-memory table.
#[derive(Debug, Default)]
pub struct Memtable {
    map: BTreeMap<Vec<u8>, Entry>,
    approx_bytes: usize,
}

impl Memtable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or overwrite.
    pub fn put(&mut self, key: &[u8], value: Vec<u8>) {
        self.insert_entry(key, Entry::Value(value));
    }

    /// Record a deletion (tombstone shadows older sstable values).
    pub fn delete(&mut self, key: &[u8]) {
        self.insert_entry(key, Entry::Tombstone);
    }

    fn insert_entry(&mut self, key: &[u8], entry: Entry) {
        let add = key.len() + entry.approx_bytes();
        if let Some(old) = self.map.insert(key.to_vec(), entry) {
            self.approx_bytes -= key.len() + old.approx_bytes();
        }
        self.approx_bytes += add;
    }

    /// Lookup. `None` = not present here (check sstables);
    /// `Some(Tombstone)` = deleted, stop searching.
    pub fn get(&self, key: &[u8]) -> Option<&Entry> {
        self.map.get(key)
    }

    /// Number of entries (including tombstones).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Approximate memory footprint in bytes.
    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    /// Iterate entries in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&[u8], &Entry)> {
        self.map.iter().map(|(k, v)| (k.as_slice(), v))
    }

    /// Iterate entries whose key starts with `prefix`.
    pub fn scan_prefix<'a>(
        &'a self,
        prefix: &'a [u8],
    ) -> impl Iterator<Item = (&'a [u8], &'a Entry)> + 'a {
        self.map
            .range::<[u8], _>((Bound::Included(prefix), Bound::Unbounded))
            .take_while(move |(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_slice(), v))
    }

    /// Drain into a sorted vec (memtable flush).
    pub fn drain_sorted(&mut self) -> Vec<(Vec<u8>, Entry)> {
        self.approx_bytes = 0;
        std::mem::take(&mut self.map).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_overwrite() {
        let mut m = Memtable::new();
        m.put(b"k", b"v1".to_vec());
        m.put(b"k", b"v2".to_vec());
        assert_eq!(m.get(b"k"), Some(&Entry::Value(b"v2".to_vec())));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn tombstone_shadows() {
        let mut m = Memtable::new();
        m.put(b"k", b"v".to_vec());
        m.delete(b"k");
        assert_eq!(m.get(b"k"), Some(&Entry::Tombstone));
        assert_eq!(m.get(b"other"), None);
    }

    #[test]
    fn byte_accounting_tracks_overwrites() {
        let mut m = Memtable::new();
        m.put(b"key", vec![0u8; 100]);
        let b1 = m.approx_bytes();
        m.put(b"key", vec![0u8; 10]);
        let b2 = m.approx_bytes();
        assert!(b2 < b1);
        assert_eq!(b2, 3 + 10);
    }

    #[test]
    fn iter_is_sorted() {
        let mut m = Memtable::new();
        for k in ["delta", "alpha", "charlie", "bravo"] {
            m.put(k.as_bytes(), b"x".to_vec());
        }
        let keys: Vec<&[u8]> = m.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![&b"alpha"[..], b"bravo", b"charlie", b"delta"]);
    }

    #[test]
    fn scan_prefix_bounds() {
        let mut m = Memtable::new();
        for k in ["drone,lidar", "drone,thermal", "drone", "truck,gps"] {
            m.put(k.as_bytes(), b"x".to_vec());
        }
        let hits: Vec<&[u8]> = m.scan_prefix(b"drone").map(|(k, _)| k).collect();
        assert_eq!(hits.len(), 3);
        let hits: Vec<&[u8]> = m.scan_prefix(b"drone,l").map(|(k, _)| k).collect();
        assert_eq!(hits, vec![&b"drone,lidar"[..]]);
        assert_eq!(m.scan_prefix(b"zzz").count(), 0);
    }

    #[test]
    fn drain_empties_and_sorts() {
        let mut m = Memtable::new();
        m.put(b"b", b"2".to_vec());
        m.put(b"a", b"1".to_vec());
        m.delete(b"c");
        let drained = m.drain_sorted();
        assert_eq!(drained.len(), 3);
        assert_eq!(drained[0].0, b"a");
        assert_eq!(drained[2].1, Entry::Tombstone);
        assert!(m.is_empty());
        assert_eq!(m.approx_bytes(), 0);
    }
}
