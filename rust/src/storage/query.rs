//! The serving layer's query engine (paper §III: "the serving layer
//! capabilities are present within the pub/sub messaging system by
//! integrating a lightweight SQL engine"; §V-A2 Figs. 5–7).
//!
//! Queries come in the three forms the paper evaluates:
//! - **store**: insert a record under a simple profile;
//! - **exact query**: exact keywords, returns a single result;
//! - **wildcard query**: patterns, may return multiple results.

use super::dht::ReplicatedDht;
use crate::ar::profile::Profile;
use crate::error::{Error, Result};
use crate::metrics::Registry;

/// Thin query façade over the DHT, with metrics.
pub struct QueryEngine {
    dht: ReplicatedDht,
    metrics: Registry,
}

impl QueryEngine {
    pub fn new(dht: ReplicatedDht) -> Self {
        QueryEngine { dht, metrics: Registry::new() }
    }

    pub fn with_metrics(dht: ReplicatedDht, metrics: Registry) -> Self {
        QueryEngine { dht, metrics }
    }

    /// Store a record (paper workload: "stores N elements").
    pub fn store(&mut self, profile: &Profile, value: &[u8]) -> Result<()> {
        self.dht.put(profile, value)?;
        self.metrics.counter("query.stores").inc();
        Ok(())
    }

    /// Exact query: profile must be simple; returns at most one record.
    pub fn exact(&self, profile: &Profile) -> Result<Option<Vec<u8>>> {
        if !profile.is_simple() {
            return Err(Error::Profile(format!(
                "exact query requires exact keywords, got `{}`",
                profile.render()
            )));
        }
        self.metrics.counter("query.exact").inc();
        self.dht.get(profile)
    }

    /// Wildcard query: pattern profile; returns all matches.
    pub fn wildcard(&self, pattern: &Profile) -> Result<Vec<(String, Vec<u8>)>> {
        self.metrics.counter("query.wildcard").inc();
        self.dht.query(pattern)
    }

    /// Delete matching records.
    pub fn delete(&mut self, profile: &Profile) -> Result<()> {
        self.metrics.counter("query.deletes").inc();
        self.dht.delete(profile)
    }

    /// Access the underlying DHT (failure injection in tests).
    pub fn dht_mut(&mut self) -> &mut ReplicatedDht {
        &mut self.dht
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }
}

impl std::fmt::Debug for QueryEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "QueryEngine({:?})", self.dht)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::throttle::ThrottledDisk;
    use crate::overlay::node_id::NodeId;
    use crate::storage::lsm::LsmOptions;

    fn engine(name: &str) -> QueryEngine {
        let dir = std::env::temp_dir()
            .join("rpulsar-query-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let members: Vec<NodeId> =
            (0..8).map(|i| NodeId::from_name(&format!("q-{i}"))).collect();
        let opts = LsmOptions { dir, memtable_bytes: 1 << 20, bloom_bits_per_key: 10, max_tables: 4 };
        QueryEngine::new(
            ReplicatedDht::new(&members, opts, 2, &ThrottledDisk::native()).unwrap(),
        )
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    #[test]
    fn store_then_exact() {
        let mut e = engine("se");
        e.store(&p("drone,lidar"), b"img").unwrap();
        assert_eq!(e.exact(&p("drone,lidar")).unwrap(), Some(b"img".to_vec()));
        assert_eq!(e.exact(&p("drone,gps")).unwrap(), None);
    }

    #[test]
    fn exact_rejects_patterns() {
        let e = engine("rejects");
        assert!(e.exact(&p("drone,li*")).is_err());
    }

    #[test]
    fn wildcard_returns_multiple() {
        let mut e = engine("wc");
        e.store(&p("sensor1,temp"), b"20").unwrap();
        e.store(&p("sensor2,temp"), b"21").unwrap();
        e.store(&p("sensor3,humidity"), b"55").unwrap();
        let hits = e.wildcard(&p("sensor*,temp")).unwrap();
        assert_eq!(hits.len(), 2);
        let all = e.wildcard(&p("sensor*,*")).unwrap();
        assert_eq!(all.len(), 3);
    }

    #[test]
    fn delete_then_query_empty() {
        let mut e = engine("del");
        e.store(&p("a,b"), b"v").unwrap();
        e.delete(&p("a,b")).unwrap();
        assert_eq!(e.exact(&p("a,b")).unwrap(), None);
    }

    #[test]
    fn metrics_track_operations() {
        let mut e = engine("metrics");
        e.store(&p("a,b"), b"v").unwrap();
        e.exact(&p("a,b")).unwrap();
        e.wildcard(&p("a,*")).unwrap();
        assert_eq!(e.metrics().counter("query.stores").get(), 1);
        assert_eq!(e.metrics().counter("query.exact").get(), 1);
        assert_eq!(e.metrics().counter("query.wildcard").get(), 1);
    }
}
