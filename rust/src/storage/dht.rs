//! Region-scoped replicated DHT (paper §IV-C3).
//!
//! "We achieved a similar mechanism at the edge of the network by
//! implementing a DHT that uses the overlay P2P network to automatically
//! replicate the data and store using multiple RP located in same region.
//! It guarantees that in the event of a RP crashing, the data will remain
//! in the system."
//!
//! Placement: a record keyed by a profile is owned by the RP whose id is
//! XOR-closest to the profile's SFC-derived id; the next `replicas - 1`
//! closest RPs hold copies. The DHT here is the *placement + shard*
//! logic over per-node [`LsmStore`]s; the coordinator wires it to the
//! real transport, and the in-process cluster uses it directly.

use super::lsm::{LsmOptions, LsmStore};
use crate::ar::profile::Profile;
use crate::device::throttle::ThrottledDisk;
use crate::error::{Error, Result};
use crate::overlay::node_id::NodeId;
use crate::routing::router::ContentRouter;
use std::collections::BTreeMap;

/// Compute the DHT key id for a profile: its SFC point embedded in the
/// id space (simple profiles), or a hash for degenerate cases.
pub fn key_id(profile: &Profile) -> Result<NodeId> {
    if profile.is_simple() {
        let (curve, ks) = ContentRouter::curve_for(profile.dims())?;
        let coords: Vec<u64> = profile
            .terms()
            .iter()
            .map(|t| match t.to_dim_range(&ks) {
                crate::routing::keyspace::DimRange::Point(p) => p,
                other => other.bounds(ks.side()).0,
            })
            .collect();
        let idx = curve.encode(&coords)?;
        let mut id = ContentRouter::index_to_id(idx, &curve);
        // Fill the low 96 bits with a hash of the full rendering so
        // profiles that collide at SFC resolution still get distinct ids
        // (placement ties break deterministically).
        let h = NodeId::from_name(&profile.render());
        id.0[8..].copy_from_slice(&h.0[8..]);
        Ok(id)
    } else {
        Err(Error::Profile(format!(
            "DHT keys must be simple profiles, got `{}`",
            profile.render()
        )))
    }
}

/// Pick the `replicas` RPs responsible for a key among `members`
/// (XOR-closest first).
pub fn replica_set(key: &NodeId, members: &[NodeId], replicas: usize) -> Vec<NodeId> {
    let mut sorted: Vec<NodeId> = members.to_vec();
    sorted.sort_by_key(|m| m.distance(key));
    sorted.truncate(replicas.max(1));
    sorted
}

/// An in-process replicated DHT over one region's members. Each member
/// gets its own LSM shard; puts replicate to the replica set; gets read
/// from the closest live replica.
pub struct ReplicatedDht {
    shards: BTreeMap<NodeId, LsmStore>,
    /// Members currently alive (failed nodes keep their shard on disk —
    /// data is not lost — but are not consulted).
    alive: Vec<NodeId>,
    replicas: usize,
}

impl ReplicatedDht {
    /// Build shards for `members`, one LSM store per member under
    /// `base.dir/<node-id>`, all sharing the device profile `disk`.
    pub fn new(
        members: &[NodeId],
        base: LsmOptions,
        replicas: usize,
        disk: &ThrottledDisk,
    ) -> Result<Self> {
        let mut shards = BTreeMap::new();
        for m in members {
            let opts = LsmOptions {
                dir: base.dir.join(m.to_hex()),
                memtable_bytes: base.memtable_bytes,
                bloom_bits_per_key: base.bloom_bits_per_key,
                max_tables: base.max_tables,
            };
            shards.insert(*m, LsmStore::open(opts, disk.clone())?);
        }
        Ok(ReplicatedDht { shards, alive: members.to_vec(), replicas: replicas.max(1) })
    }

    /// Members currently alive.
    pub fn alive(&self) -> &[NodeId] {
        &self.alive
    }

    /// Mark a member failed (its shard stops serving).
    pub fn fail(&mut self, id: &NodeId) {
        self.alive.retain(|m| m != id);
    }

    /// Mark a member recovered.
    pub fn recover(&mut self, id: NodeId) {
        if self.shards.contains_key(&id) && !self.alive.contains(&id) {
            self.alive.push(id);
        }
    }

    /// Store a record under a simple profile, replicating it.
    pub fn put(&mut self, profile: &Profile, value: &[u8]) -> Result<Vec<NodeId>> {
        let key = key_id(profile)?;
        let targets = replica_set(&key, &self.alive, self.replicas);
        if targets.is_empty() {
            return Err(Error::Overlay("no live replicas".into()));
        }
        let storage_key = profile.render().into_bytes();
        for t in &targets {
            self.shards
                .get_mut(t)
                .expect("alive member must have a shard")
                .put(&storage_key, value)?;
        }
        Ok(targets)
    }

    /// Read a record (closest live replica first).
    pub fn get(&self, profile: &Profile) -> Result<Option<Vec<u8>>> {
        let key = key_id(profile)?;
        let storage_key = profile.render().into_bytes();
        for t in replica_set(&key, &self.alive, self.replicas) {
            if let Some(v) = self.shards[&t].get(&storage_key)? {
                return Ok(Some(v));
            }
        }
        Ok(None)
    }

    /// Delete a record from all live replicas.
    pub fn delete(&mut self, profile: &Profile) -> Result<()> {
        let key = key_id(profile)?;
        let storage_key = profile.render().into_bytes();
        for t in replica_set(&key, &self.alive, self.replicas) {
            self.shards.get_mut(&t).unwrap().delete(&storage_key)?;
        }
        Ok(())
    }

    /// Wildcard query: scan every live shard for keys matching the
    /// pattern profile, deduplicated (paper Fig. 7's query layer).
    pub fn query(&self, pattern: &Profile) -> Result<Vec<(String, Vec<u8>)>> {
        // Longest literal prefix of the pattern bounds the scan.
        let rendered = pattern.render();
        let literal: String = rendered.chars().take_while(|&c| c != '*').collect();
        let mut out: BTreeMap<String, Vec<u8>> = BTreeMap::new();
        for id in &self.alive {
            for (k, v) in self.shards[id].scan_prefix(literal.as_bytes())? {
                let key_str = String::from_utf8_lossy(&k).to_string();
                if let Ok(stored) = Profile::parse(&key_str) {
                    if crate::ar::matching::matches(pattern, &stored) {
                        out.insert(key_str, v);
                    }
                }
            }
        }
        Ok(out.into_iter().collect())
    }

    /// Number of live shards (tests).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

impl std::fmt::Debug for ReplicatedDht {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ReplicatedDht(shards={}, alive={}, replicas={})",
            self.shards.len(),
            self.alive.len(),
            self.replicas
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn members(n: usize) -> Vec<NodeId> {
        (0..n).map(|i| NodeId::from_name(&format!("dht-{i}"))).collect()
    }

    fn dht(name: &str, n: usize, replicas: usize) -> ReplicatedDht {
        let dir = std::env::temp_dir()
            .join("rpulsar-dht-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let opts = LsmOptions { dir, memtable_bytes: 1 << 20, bloom_bits_per_key: 10, max_tables: 4 };
        ReplicatedDht::new(&members(n), opts, replicas, &ThrottledDisk::native()).unwrap()
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    #[test]
    fn put_get_round_trip() {
        let mut d = dht("pg", 8, 2);
        let targets = d.put(&p("drone,lidar"), b"image-bytes").unwrap();
        assert_eq!(targets.len(), 2);
        assert_eq!(d.get(&p("drone,lidar")).unwrap(), Some(b"image-bytes".to_vec()));
        assert_eq!(d.get(&p("drone,thermal")).unwrap(), None);
    }

    #[test]
    fn replica_set_is_deterministic_and_distinct() {
        let ms = members(16);
        let key = NodeId::from_name("some-key");
        let a = replica_set(&key, &ms, 3);
        let b = replica_set(&key, &ms, 3);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert!(a[0] != a[1] && a[1] != a[2]);
        // First replica is the global XOR-minimum.
        let best = ms.iter().min_by_key(|m| m.distance(&key)).unwrap();
        assert_eq!(&a[0], best);
    }

    #[test]
    fn data_survives_primary_failure() {
        // The paper's replication guarantee.
        let mut d = dht("failover", 8, 3);
        let targets = d.put(&p("drone,lidar"), b"precious").unwrap();
        // Kill the primary replica.
        d.fail(&targets[0]);
        assert_eq!(d.get(&p("drone,lidar")).unwrap(), Some(b"precious".to_vec()));
        // Kill the second too — third still serves.
        d.fail(&targets[1]);
        assert_eq!(d.get(&p("drone,lidar")).unwrap(), Some(b"precious".to_vec()));
    }

    #[test]
    fn recovery_rejoins() {
        let mut d = dht("rejoin", 4, 2);
        let targets = d.put(&p("a,b"), b"v").unwrap();
        d.fail(&targets[0]);
        d.recover(targets[0]);
        assert!(d.alive().contains(&targets[0]));
        assert_eq!(d.get(&p("a,b")).unwrap(), Some(b"v".to_vec()));
    }

    #[test]
    fn complex_profile_rejected_as_key() {
        let mut d = dht("complexkey", 4, 2);
        assert!(d.put(&p("drone,li*"), b"x").is_err());
        assert!(key_id(&p("a*")).is_err());
    }

    #[test]
    fn wildcard_query_finds_matches() {
        let mut d = dht("wild", 8, 2);
        d.put(&p("drone,lidar"), b"1").unwrap();
        d.put(&p("drone,thermal"), b"2").unwrap();
        d.put(&p("truck,gps"), b"3").unwrap();
        let hits = d.query(&p("drone,*")).unwrap();
        assert_eq!(hits.len(), 2);
        let hits = d.query(&p("drone,li*")).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].1, b"1");
        let hits = d.query(&p("*,*")).unwrap();
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn delete_removes_from_replicas() {
        let mut d = dht("del", 8, 2);
        d.put(&p("a,b"), b"v").unwrap();
        d.delete(&p("a,b")).unwrap();
        assert_eq!(d.get(&p("a,b")).unwrap(), None);
    }

    #[test]
    fn different_profiles_spread_over_members() {
        // Placement should not pile everything on one node — provided the
        // keywords are actually diverse. (Prefix-similar keywords *do*
        // concentrate by design: SFC locality keeps them queryable as one
        // cluster.)
        let mut d = dht("spread", 16, 1);
        let mut owners = std::collections::BTreeSet::new();
        for i in 0..26u8 {
            let a = (b'a' + i) as char;
            let b = (b'a' + (25 - i)) as char;
            let profile = p(&format!("{a}sensor,{b}reading"));
            let t = d.put(&profile, b"v").unwrap();
            owners.insert(t[0]);
        }
        assert!(owners.len() >= 4, "placement too concentrated: {}", owners.len());
    }

    #[test]
    fn prefix_similar_profiles_cluster_on_same_owner() {
        // The SFC locality property at the placement level.
        let mut d = dht("cluster", 16, 1);
        let a = d.put(&p("sensor1,temp"), b"v").unwrap();
        let b = d.put(&p("sensor2,temp"), b"v").unwrap();
        assert_eq!(a[0], b[0], "similar keywords should co-locate");
    }
}
