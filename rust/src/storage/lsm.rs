//! The RocksDB-role store (paper §IV-C3): memory-first LSM.
//!
//! Writes land in the [`Memtable`] (RAM speed — the design point of the
//! paper's storage layer); when it exceeds `memtable_bytes` it flushes to
//! an [`SsTable`]. Reads check memtable → newest sstable → oldest,
//! short-circuiting through bloom filters. A simple full compaction
//! merges sstables when their count exceeds `max_tables`. All disk byte
//! movement is charged to the device throttle so edge-device behaviour
//! reproduces on server hardware.

use super::memtable::{Entry, Memtable};
use super::sstable::SsTable;
use crate::config::StorageConfig;
use crate::device::throttle::{Dir, Medium, Pattern, ThrottledDisk};
use crate::error::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Store tuning knobs.
#[derive(Debug, Clone)]
pub struct LsmOptions {
    pub dir: PathBuf,
    pub memtable_bytes: usize,
    pub bloom_bits_per_key: usize,
    /// Compact when sstable count exceeds this.
    pub max_tables: usize,
}

impl Default for LsmOptions {
    fn default() -> Self {
        LsmOptions {
            dir: std::env::temp_dir().join("rpulsar-lsm"),
            memtable_bytes: 4 << 20,
            bloom_bits_per_key: 10,
            max_tables: 6,
        }
    }
}

impl From<&StorageConfig> for LsmOptions {
    fn from(c: &StorageConfig) -> Self {
        LsmOptions {
            dir: c.dir.clone(),
            memtable_bytes: c.memtable_bytes,
            bloom_bits_per_key: c.bloom_bits_per_key,
            max_tables: 6,
        }
    }
}

/// The LSM store.
pub struct LsmStore {
    opts: LsmOptions,
    memtable: Memtable,
    /// Newest first.
    tables: Vec<SsTable>,
    next_table_id: u64,
    disk: ThrottledDisk,
}

impl LsmStore {
    /// Open (recovering existing sstables) or create a store.
    pub fn open(opts: LsmOptions, disk: ThrottledDisk) -> Result<Self> {
        std::fs::create_dir_all(&opts.dir)?;
        let mut ids: Vec<(u64, PathBuf)> = std::fs::read_dir(&opts.dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let id: u64 = name.strip_suffix(".sst")?.strip_prefix("table-")?.parse().ok()?;
                Some((id, e.path()))
            })
            .collect();
        ids.sort();
        let mut tables = Vec::new();
        let mut next_table_id = 0;
        for (id, path) in ids {
            tables.push(SsTable::open(&path)?);
            next_table_id = next_table_id.max(id + 1);
        }
        tables.reverse(); // newest (highest id) first
        Ok(LsmStore { opts, memtable: Memtable::new(), tables, next_table_id, disk })
    }

    /// Open with a native (unthrottled) device.
    pub fn open_native(opts: LsmOptions) -> Result<Self> {
        Self::open(opts, ThrottledDisk::native())
    }

    /// Insert or overwrite a record.
    pub fn put(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        // Fixed per-op CPU (matching/index maintenance on-device) + RAM
        // write (memtable) at RAM bandwidth.
        self.disk.charge_cpu_op();
        self.disk.charge(Medium::Ram, Pattern::Random, Dir::Write, key.len() + value.len());
        self.memtable.put(key, value.to_vec());
        self.maybe_flush()
    }

    /// Delete a record.
    pub fn delete(&mut self, key: &[u8]) -> Result<()> {
        self.disk.charge(Medium::Ram, Pattern::Random, Dir::Write, key.len() + 1);
        self.memtable.delete(key);
        self.maybe_flush()
    }

    /// Point lookup.
    pub fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.disk.charge_cpu_op();
        // Memtable: RAM random read.
        if let Some(entry) = self.memtable.get(key) {
            self.disk.charge(Medium::Ram, Pattern::Random, Dir::Read, key.len() + 8);
            return Ok(match entry {
                Entry::Value(v) => Some(v.clone()),
                Entry::Tombstone => None,
            });
        }
        // SsTables newest→oldest: bloom check is RAM; a hit reads disk.
        for t in &self.tables {
            if !t.may_contain(key) {
                continue;
            }
            if let Some((entry, size)) = t.get(key)? {
                self.disk.charge(Medium::Disk, Pattern::Random, Dir::Read, size.max(4096));
                return Ok(match entry {
                    Entry::Value(v) => Some(v),
                    Entry::Tombstone => None,
                });
            }
        }
        Ok(None)
    }

    /// All live records whose key starts with `prefix` (newest version
    /// wins; tombstones suppress).
    pub fn scan_prefix(&self, prefix: &[u8]) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let mut merged: BTreeMap<Vec<u8>, Entry> = BTreeMap::new();
        // Oldest first so newer layers overwrite.
        for t in self.tables.iter().rev() {
            let bytes: usize =
                t.scan_prefix(prefix)?.iter().map(|(k, _)| k.len() + 16).sum::<usize>();
            self.disk.charge(Medium::Disk, Pattern::Sequential, Dir::Read, bytes.max(4096));
            for (k, e) in t.scan_prefix(prefix)? {
                merged.insert(k, e);
            }
        }
        for (k, e) in self.memtable.scan_prefix(prefix) {
            merged.insert(k.to_vec(), e.clone());
        }
        // Per-query CPU (matching) plus RAM traffic for every returned
        // record.
        self.disk.charge_cpu_op();
        let hit_bytes: usize = merged.iter().map(|(k, e)| k.len() + entry_bytes(e)).sum();
        self.disk.charge(Medium::Ram, Pattern::Sequential, Dir::Read, hit_bytes.max(64));
        Ok(merged
            .into_iter()
            .filter_map(|(k, e)| match e {
                Entry::Value(v) => Some((k, v)),
                Entry::Tombstone => None,
            })
            .collect())
    }

    /// Approximate number of live records (full merge; tests/stats only).
    pub fn len(&self) -> Result<usize> {
        Ok(self.scan_prefix(b"")?.len())
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Force-flush the memtable to an sstable.
    pub fn flush(&mut self) -> Result<()> {
        if self.memtable.is_empty() {
            return Ok(());
        }
        let entries = self.memtable.drain_sorted();
        let bytes: usize =
            entries.iter().map(|(k, e)| k.len() + entry_bytes(e)).sum();
        let path = self.opts.dir.join(format!("table-{:010}.sst", self.next_table_id));
        self.next_table_id += 1;
        let table = SsTable::write(&path, &entries, self.opts.bloom_bits_per_key)?;
        // Flush = sequential disk write of the whole run.
        self.disk.charge(Medium::Disk, Pattern::Sequential, Dir::Write, bytes);
        self.tables.insert(0, table);
        if self.tables.len() > self.opts.max_tables {
            self.compact()?;
        }
        Ok(())
    }

    fn maybe_flush(&mut self) -> Result<()> {
        if self.memtable.approx_bytes() >= self.opts.memtable_bytes {
            self.flush()?;
        }
        Ok(())
    }

    /// Merge every sstable into one (full compaction).
    pub fn compact(&mut self) -> Result<()> {
        if self.tables.len() <= 1 {
            return Ok(());
        }
        let mut merged: BTreeMap<Vec<u8>, Entry> = BTreeMap::new();
        let mut read_bytes = 0usize;
        for t in self.tables.iter().rev() {
            read_bytes += t.data_bytes();
            for (k, e) in t.iter_all()? {
                merged.insert(k, e);
            }
        }
        // Drop tombstones entirely — nothing older remains.
        let entries: Vec<(Vec<u8>, Entry)> =
            merged.into_iter().filter(|(_, e)| !matches!(e, Entry::Tombstone)).collect();
        let write_bytes: usize = entries.iter().map(|(k, e)| k.len() + entry_bytes(e)).sum();
        self.disk.charge(Medium::Disk, Pattern::Sequential, Dir::Read, read_bytes);
        self.disk.charge(Medium::Disk, Pattern::Sequential, Dir::Write, write_bytes);

        let old_paths: Vec<PathBuf> = self.tables.iter().map(|t| t.path().to_path_buf()).collect();
        let path = self.opts.dir.join(format!("table-{:010}.sst", self.next_table_id));
        self.next_table_id += 1;
        let table = SsTable::write(&path, &entries, self.opts.bloom_bits_per_key)?;
        self.tables = vec![table];
        for p in old_paths {
            let _ = std::fs::remove_file(p);
        }
        Ok(())
    }

    /// Number of on-disk sstables (tests/stats).
    pub fn table_count(&self) -> usize {
        self.tables.len()
    }

    /// Memtable footprint (tests/stats).
    pub fn memtable_bytes(&self) -> usize {
        self.memtable.approx_bytes()
    }

    /// The device throttle (virtual-clock inspection in benches).
    pub fn disk(&self) -> &ThrottledDisk {
        &self.disk
    }
}

fn entry_bytes(e: &Entry) -> usize {
    match e {
        Entry::Value(v) => v.len(),
        Entry::Tombstone => 1,
    }
}

impl std::fmt::Debug for LsmStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LsmStore(memtable={}B, tables={})",
            self.memtable.approx_bytes(),
            self.tables.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(name: &str, memtable_bytes: usize) -> LsmOptions {
        let dir = std::env::temp_dir()
            .join("rpulsar-lsm-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        LsmOptions { dir, memtable_bytes, bloom_bits_per_key: 10, max_tables: 3 }
    }

    fn cleanup(o: &LsmOptions) {
        let _ = std::fs::remove_dir_all(&o.dir);
    }

    #[test]
    fn put_get_delete() {
        let o = opts("pgd", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        s.put(b"k1", b"v1").unwrap();
        assert_eq!(s.get(b"k1").unwrap(), Some(b"v1".to_vec()));
        s.delete(b"k1").unwrap();
        assert_eq!(s.get(b"k1").unwrap(), None);
        assert_eq!(s.get(b"never").unwrap(), None);
        cleanup(&o);
    }

    #[test]
    fn flush_and_read_from_sstable() {
        let o = opts("flush", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        for i in 0..100u32 {
            s.put(format!("key-{i:03}").as_bytes(), format!("val-{i}").as_bytes()).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.memtable_bytes(), 0);
        assert_eq!(s.table_count(), 1);
        assert_eq!(s.get(b"key-042").unwrap(), Some(b"val-42".to_vec()));
        cleanup(&o);
    }

    #[test]
    fn auto_flush_on_threshold() {
        let o = opts("auto", 1024);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        for i in 0..100u32 {
            s.put(format!("k{i}").as_bytes(), &[0u8; 64]).unwrap();
        }
        assert!(s.table_count() >= 1, "should have auto-flushed");
        // Everything still readable.
        assert_eq!(s.get(b"k0").unwrap(), Some(vec![0u8; 64]));
        assert_eq!(s.get(b"k99").unwrap(), Some(vec![0u8; 64]));
        cleanup(&o);
    }

    #[test]
    fn newest_version_wins_across_layers() {
        let o = opts("versions", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        s.put(b"k", b"old").unwrap();
        s.flush().unwrap();
        s.put(b"k", b"new").unwrap();
        assert_eq!(s.get(b"k").unwrap(), Some(b"new".to_vec()));
        s.flush().unwrap();
        assert_eq!(s.get(b"k").unwrap(), Some(b"new".to_vec()));
        cleanup(&o);
    }

    #[test]
    fn tombstone_shadows_sstable_value() {
        let o = opts("shadow", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        s.put(b"k", b"v").unwrap();
        s.flush().unwrap();
        s.delete(b"k").unwrap();
        assert_eq!(s.get(b"k").unwrap(), None);
        s.flush().unwrap();
        assert_eq!(s.get(b"k").unwrap(), None);
        cleanup(&o);
    }

    #[test]
    fn scan_prefix_merges_layers() {
        let o = opts("scanm", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        s.put(b"drone,lidar", b"1").unwrap();
        s.flush().unwrap();
        s.put(b"drone,thermal", b"2").unwrap();
        s.put(b"truck,gps", b"3").unwrap();
        let hits = s.scan_prefix(b"drone").unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].0, b"drone,lidar");
        cleanup(&o);
    }

    #[test]
    fn recovery_reopens_tables() {
        let o = opts("recover", 1 << 20);
        {
            let mut s = LsmStore::open_native(o.clone()).unwrap();
            s.put(b"persist", b"yes").unwrap();
            s.flush().unwrap();
        }
        let s = LsmStore::open_native(o.clone()).unwrap();
        assert_eq!(s.get(b"persist").unwrap(), Some(b"yes".to_vec()));
        cleanup(&o);
    }

    #[test]
    fn compaction_bounds_table_count() {
        let o = opts("compact", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        for round in 0..6u32 {
            for i in 0..10u32 {
                s.put(format!("r{round}-k{i}").as_bytes(), b"v").unwrap();
            }
            s.flush().unwrap();
        }
        assert!(s.table_count() <= 3 + 1, "tables={}", s.table_count());
        // All data survives compaction.
        for round in 0..6u32 {
            assert_eq!(s.get(format!("r{round}-k5").as_bytes()).unwrap(), Some(b"v".to_vec()));
        }
        cleanup(&o);
    }

    #[test]
    fn compaction_drops_tombstones() {
        let o = opts("droptomb", 1 << 20);
        let mut s = LsmStore::open_native(o.clone()).unwrap();
        s.put(b"a", b"1").unwrap();
        s.put(b"b", b"2").unwrap();
        s.flush().unwrap();
        s.delete(b"a").unwrap();
        s.flush().unwrap();
        s.compact().unwrap();
        assert_eq!(s.table_count(), 1);
        assert_eq!(s.get(b"a").unwrap(), None);
        assert_eq!(s.get(b"b").unwrap(), Some(b"2".to_vec()));
        cleanup(&o);
    }

    #[test]
    fn throttle_accounts_disk_flush() {
        use crate::device::profile::DeviceProfile;
        use crate::device::throttle::ClockMode;
        let o = opts("throttle", 1 << 20);
        let disk = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual);
        let mut s = LsmStore::open(o.clone(), disk).unwrap();
        for i in 0..100u32 {
            s.put(format!("k{i}").as_bytes(), &[0u8; 100]).unwrap();
        }
        let before_flush = s.disk().virtual_elapsed();
        s.flush().unwrap();
        let flush_cost = s.disk().virtual_elapsed() - before_flush;
        // ~10 KB at 7.12 MB/s ≈ 1.5 ms of sequential disk time.
        assert!(
            flush_cost.as_micros() > 1_000,
            "flush must hit the disk: {flush_cost:?}"
        );
        // Per-put cost is CPU+RAM only: ~110 µs on the Pi model.
        let per_put = before_flush / 100;
        assert!(per_put.as_micros() < 500, "puts must stay memory-speed: {per_put:?}");
        cleanup(&o);
    }
}
