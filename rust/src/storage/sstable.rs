//! Sorted string table: one immutable, sorted on-disk run produced by a
//! memtable flush.
//!
//! Layout: `[data block][index][bloom][footer]` — the data block is a
//! sequence of length-prefixed (key, entry) records in key order; the
//! index maps every key to its record offset; the footer locates index
//! and bloom. The whole table is small enough (memtable-sized) to keep
//! the index in memory after open. I/O is routed through the device
//! throttle by the owning [`super::lsm::LsmStore`].

use super::bloom::BloomFilter;
use super::memtable::Entry;
use crate::error::{Error, Result};
use crate::util::codec::{ByteReader, ByteWriter};
use crate::util::crc32;
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x5353_5442; // "SSTB"

/// An open sstable: index + bloom resident, data on disk.
#[derive(Debug)]
pub struct SsTable {
    path: PathBuf,
    /// Sorted (key → data-block offset).
    index: Vec<(Vec<u8>, u32)>,
    bloom: BloomFilter,
    /// Raw data block (kept mapped in memory — tables are memtable-sized;
    /// the *throttle accounting* treats reads as disk I/O).
    data: Vec<u8>,
}

impl SsTable {
    /// Write a new sstable from sorted entries. Returns the open table.
    pub fn write(
        path: &Path,
        entries: &[(Vec<u8>, Entry)],
        bits_per_key: usize,
    ) -> Result<SsTable> {
        if entries.windows(2).any(|w| w[0].0 >= w[1].0) {
            return Err(Error::Storage("sstable entries must be strictly sorted".into()));
        }
        let mut data = ByteWriter::with_capacity(4096);
        let mut index: Vec<(Vec<u8>, u32)> = Vec::with_capacity(entries.len());
        let mut bloom = BloomFilter::new(entries.len(), bits_per_key);
        for (key, entry) in entries {
            index.push((key.clone(), data.len() as u32));
            bloom.insert(key);
            data.put_bytes(key);
            match entry {
                Entry::Value(v) => {
                    data.put_u8(1);
                    data.put_bytes(v);
                }
                Entry::Tombstone => data.put_u8(0),
            }
        }
        let data = data.into_bytes();

        let mut file = ByteWriter::with_capacity(data.len() + 4096);
        file.put_raw(&data);
        let index_off = file.len() as u64;
        file.put_varint(index.len() as u64);
        for (key, off) in &index {
            file.put_bytes(key);
            file.put_u32(*off);
        }
        let bloom_off = file.len() as u64;
        let bloom_bytes = bloom.to_bytes();
        file.put_bytes(&bloom_bytes);
        // Footer: index_off, bloom_off, data_crc, magic.
        file.put_u64(index_off);
        file.put_u64(bloom_off);
        file.put_u32(crc32(&data));
        file.put_u32(MAGIC);

        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, file.as_slice())?;
        Ok(SsTable { path: path.to_path_buf(), index, bloom, data })
    }

    /// Open an existing sstable, verifying the footer and data CRC.
    pub fn open(path: &Path) -> Result<SsTable> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 24 {
            return Err(Error::Storage(format!("{path:?}: too small for an sstable")));
        }
        let footer = &bytes[bytes.len() - 24..];
        let mut fr = ByteReader::new(footer);
        let index_off = fr.get_u64()? as usize;
        let bloom_off = fr.get_u64()? as usize;
        let data_crc = fr.get_u32()?;
        let magic = fr.get_u32()?;
        if magic != MAGIC {
            return Err(Error::Storage(format!("{path:?}: bad magic")));
        }
        if index_off > bloom_off || bloom_off > bytes.len() - 24 {
            return Err(Error::Storage(format!("{path:?}: corrupt footer")));
        }
        let data = bytes[..index_off].to_vec();
        if crc32(&data) != data_crc {
            return Err(Error::Storage(format!("{path:?}: data crc mismatch")));
        }
        let mut ir = ByteReader::new(&bytes[index_off..bloom_off]);
        let n = ir.get_varint()? as usize;
        let mut index = Vec::with_capacity(n);
        for _ in 0..n {
            let key = ir.get_bytes()?.to_vec();
            let off = ir.get_u32()?;
            index.push((key, off));
        }
        let mut br = ByteReader::new(&bytes[bloom_off..bytes.len() - 24]);
        let bloom = BloomFilter::from_bytes(br.get_bytes()?)
            .ok_or_else(|| Error::Storage(format!("{path:?}: corrupt bloom")))?;
        Ok(SsTable { path: path.to_path_buf(), index, bloom, data })
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Total data-block size (throttle accounting).
    pub fn data_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bloom-filter check (no I/O).
    pub fn may_contain(&self, key: &[u8]) -> bool {
        self.bloom.may_contain(key)
    }

    fn read_at(&self, off: u32) -> Result<(Vec<u8>, Entry)> {
        let mut r = ByteReader::new(&self.data[off as usize..]);
        let key = r.get_bytes()?.to_vec();
        let entry = match r.get_u8()? {
            1 => Entry::Value(r.get_bytes()?.to_vec()),
            0 => Entry::Tombstone,
            other => return Err(Error::Storage(format!("bad entry tag {other}"))),
        };
        Ok((key, entry))
    }

    /// Point lookup. Returns the record size read (for I/O accounting)
    /// alongside the entry.
    pub fn get(&self, key: &[u8]) -> Result<Option<(Entry, usize)>> {
        if !self.bloom.may_contain(key) {
            return Ok(None);
        }
        match self.index.binary_search_by(|(k, _)| k.as_slice().cmp(key)) {
            Ok(i) => {
                let off = self.index[i].1;
                let (k, entry) = self.read_at(off)?;
                debug_assert_eq!(k.as_slice(), key);
                let size = k.len() + entry_size(&entry);
                Ok(Some((entry, size)))
            }
            Err(_) => Ok(None),
        }
    }

    /// Scan all entries whose key starts with `prefix`, in order.
    pub fn scan_prefix(&self, prefix: &[u8]) -> Result<Vec<(Vec<u8>, Entry)>> {
        let start = self.index.partition_point(|(k, _)| k.as_slice() < prefix);
        let mut out = Vec::new();
        for (key, off) in &self.index[start..] {
            if !key.starts_with(prefix) {
                break;
            }
            out.push(self.read_at(*off).map(|(k, e)| {
                debug_assert_eq!(&k, key);
                (k, e)
            })?);
        }
        Ok(out)
    }

    /// Iterate every entry (compaction / full scans).
    pub fn iter_all(&self) -> Result<Vec<(Vec<u8>, Entry)>> {
        self.index.iter().map(|(_, off)| self.read_at(*off)).collect()
    }
}

fn entry_size(e: &Entry) -> usize {
    match e {
        Entry::Value(v) => v.len(),
        Entry::Tombstone => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("rpulsar-sstable-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}.sst", std::process::id()))
    }

    fn entries(n: usize) -> Vec<(Vec<u8>, Entry)> {
        (0..n)
            .map(|i| {
                (
                    format!("key-{i:05}").into_bytes(),
                    Entry::Value(format!("value-{i}").into_bytes()),
                )
            })
            .collect()
    }

    #[test]
    fn write_open_get() {
        let path = tmp("wog");
        let es = entries(100);
        SsTable::write(&path, &es, 10).unwrap();
        let t = SsTable::open(&path).unwrap();
        assert_eq!(t.len(), 100);
        let (e, _) = t.get(b"key-00042").unwrap().unwrap();
        assert_eq!(e, Entry::Value(b"value-42".to_vec()));
        assert!(t.get(b"key-99999").unwrap().is_none());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unsorted_input_rejected() {
        let path = tmp("unsorted");
        let es = vec![
            (b"b".to_vec(), Entry::Value(vec![1])),
            (b"a".to_vec(), Entry::Value(vec![2])),
        ];
        assert!(SsTable::write(&path, &es, 10).is_err());
    }

    #[test]
    fn tombstones_round_trip() {
        let path = tmp("tomb");
        let es = vec![
            (b"alive".to_vec(), Entry::Value(b"v".to_vec())),
            (b"dead".to_vec(), Entry::Tombstone),
        ];
        SsTable::write(&path, &es, 10).unwrap();
        let t = SsTable::open(&path).unwrap();
        assert_eq!(t.get(b"dead").unwrap().unwrap().0, Entry::Tombstone);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prefix_scan_in_order() {
        let path = tmp("scan");
        let mut es = vec![
            (b"drone,lidar".to_vec(), Entry::Value(b"1".to_vec())),
            (b"drone,thermal".to_vec(), Entry::Value(b"2".to_vec())),
            (b"truck,gps".to_vec(), Entry::Value(b"3".to_vec())),
        ];
        es.sort_by(|a, b| a.0.cmp(&b.0));
        SsTable::write(&path, &es, 10).unwrap();
        let t = SsTable::open(&path).unwrap();
        let hits = t.scan_prefix(b"drone").unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].0, b"drone,lidar");
        assert!(t.scan_prefix(b"zzz").unwrap().is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("corrupt");
        SsTable::write(&path, &entries(10), 10).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[5] ^= 0xFF; // flip a data byte
        std::fs::write(&path, &bytes).unwrap();
        assert!(SsTable::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_file_rejected() {
        let path = tmp("trunc");
        std::fs::write(&path, b"short").unwrap();
        assert!(SsTable::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn iter_all_returns_everything() {
        let path = tmp("iterall");
        let es = entries(25);
        SsTable::write(&path, &es, 10).unwrap();
        let t = SsTable::open(&path).unwrap();
        assert_eq!(t.iter_all().unwrap(), es);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_table_roundtrips() {
        let path = tmp("empty");
        SsTable::write(&path, &[], 10).unwrap();
        let t = SsTable::open(&path).unwrap();
        assert!(t.is_empty());
        assert!(t.get(b"x").unwrap().is_none());
        std::fs::remove_file(&path).unwrap();
    }
}
