//! The memory-mapped data storage layer (paper §IV-C3).
//!
//! "The storage layer relies on RocksDB, an embedded database optimized
//! for fast and low latency storage. [...] The database will keep the
//! most recently used data in main memory, and it will store the least
//! recently used data to disk."
//!
//! No RocksDB offline — [`lsm`] implements the same contract natively:
//! an in-memory [`memtable`] absorbs writes, overflowing to sorted
//! on-disk runs ([`sstable`]) guarded by [`bloom`] filters; reads hit the
//! memtable first (recently-used data stays in RAM). [`dht`] replicates
//! records across the Rendezvous Points of a region so data survives RP
//! failures, and [`query`] evaluates the exact/wildcard/range queries of
//! the paper's serving layer (Figs. 5–7).

pub mod bloom;
pub mod dht;
pub mod lsm;
pub mod memtable;
pub mod query;
pub mod sstable;

pub use dht::ReplicatedDht;
pub use lsm::{LsmStore, LsmOptions};
pub use query::QueryEngine;
