//! Bloom filter for sstable lookups (double-hashing over FNV-1a).

use crate::util::fnv1a64;

/// A fixed-size Bloom filter.
#[derive(Debug, Clone)]
pub struct BloomFilter {
    bits: Vec<u64>,
    nbits: usize,
    k: u32,
}

impl BloomFilter {
    /// Build for an expected number of keys at `bits_per_key` (10 ≈ 1%
    /// false-positive rate).
    pub fn new(expected_keys: usize, bits_per_key: usize) -> Self {
        let nbits = (expected_keys.max(1) * bits_per_key.max(1)).max(64);
        let k = ((bits_per_key as f64 * 0.69) as u32).clamp(1, 30);
        BloomFilter { bits: vec![0u64; (nbits + 63) / 64], nbits, k }
    }

    fn hashes(&self, key: &[u8]) -> (u64, u64) {
        let h1 = fnv1a64(key);
        // Second independent hash: FNV over the first hash's bytes.
        let h2 = fnv1a64(&h1.to_le_bytes()) | 1; // odd so probes cover all bits
        (h1, h2)
    }

    /// Insert a key.
    pub fn insert(&mut self, key: &[u8]) {
        let (h1, h2) = self.hashes(key);
        for i in 0..self.k {
            let bit = (h1.wrapping_add(h2.wrapping_mul(i as u64)) % self.nbits as u64) as usize;
            self.bits[bit / 64] |= 1u64 << (bit % 64);
        }
    }

    /// Whether the key *may* be present (false positives possible,
    /// false negatives impossible).
    pub fn may_contain(&self, key: &[u8]) -> bool {
        let (h1, h2) = self.hashes(key);
        for i in 0..self.k {
            let bit = (h1.wrapping_add(h2.wrapping_mul(i as u64)) % self.nbits as u64) as usize;
            if self.bits[bit / 64] & (1u64 << (bit % 64)) == 0 {
                return false;
            }
        }
        true
    }

    /// Serialize (for embedding in sstable footers).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.bits.len() * 8);
        out.extend_from_slice(&(self.nbits as u64).to_le_bytes());
        out.extend_from_slice(&self.k.to_le_bytes());
        for w in &self.bits {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Deserialize.
    pub fn from_bytes(data: &[u8]) -> Option<Self> {
        if data.len() < 12 {
            return None;
        }
        let nbits = u64::from_le_bytes(data[0..8].try_into().ok()?) as usize;
        let k = u32::from_le_bytes(data[8..12].try_into().ok()?);
        let words = (nbits + 63) / 64;
        if data.len() != 12 + words * 8 || k == 0 || k > 30 {
            return None;
        }
        let bits = data[12..]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Some(BloomFilter { bits, nbits, k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_false_negatives() {
        let mut b = BloomFilter::new(1000, 10);
        for i in 0..1000u32 {
            b.insert(format!("key-{i}").as_bytes());
        }
        for i in 0..1000u32 {
            assert!(b.may_contain(format!("key-{i}").as_bytes()), "fn at {i}");
        }
    }

    #[test]
    fn false_positive_rate_reasonable() {
        let mut b = BloomFilter::new(1000, 10);
        for i in 0..1000u32 {
            b.insert(format!("key-{i}").as_bytes());
        }
        let fp = (0..10_000u32)
            .filter(|i| b.may_contain(format!("absent-{i}").as_bytes()))
            .count();
        // 10 bits/key ⇒ ~1% theoretical; allow up to 4%.
        assert!(fp < 400, "false positive rate too high: {fp}/10000");
    }

    #[test]
    fn empty_filter_rejects() {
        let b = BloomFilter::new(10, 10);
        assert!(!b.may_contain(b"anything"));
    }

    #[test]
    fn serialization_round_trip() {
        let mut b = BloomFilter::new(100, 10);
        for i in 0..100u32 {
            b.insert(&i.to_le_bytes());
        }
        let bytes = b.to_bytes();
        let b2 = BloomFilter::from_bytes(&bytes).unwrap();
        for i in 0..100u32 {
            assert!(b2.may_contain(&i.to_le_bytes()));
        }
        assert!(BloomFilter::from_bytes(&bytes[..5]).is_none());
        assert!(BloomFilter::from_bytes(&[]).is_none());
    }
}
