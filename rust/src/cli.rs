//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Grammar: `rpulsar <subcommand> [--flag] [--opt value|--opt=value] [positional...]`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing
                    args.positional.extend(it);
                    break;
                }
                if let Some(eq) = body.find('=') {
                    args.options.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = it.next().unwrap();
                    args.options.insert(body.to_string(), val);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// True if `--name` was given as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option value, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option with default.
    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    /// Integer option with default.
    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    /// u64 option with default.
    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    /// f64 option with default.
    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a number, got `{v}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["node", "start", "now"]);
        assert_eq!(a.command.as_deref(), Some("node"));
        assert_eq!(a.positional, vec!["start", "now"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["bench", "--size", "1024", "--device=pi"]);
        assert_eq!(a.opt("size"), Some("1024"));
        assert_eq!(a.opt("device"), Some("pi"));
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["run", "--verbose", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag_is_bare() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["x", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "42", "--r", "2.5"]);
        assert_eq!(a.opt_usize("n", 0).unwrap(), 42);
        assert_eq!(a.opt_f64("r", 0.0).unwrap(), 2.5);
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.opt_usize("n", 0).is_err());
    }
}
