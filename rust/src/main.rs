//! `rpulsar` — leader entrypoint and CLI.
//!
//! Subcommands:
//!
//! - `node --config <file> [--listen <addr>]` — run a single RP behind a
//!   TCP endpoint (multi-process deployment).
//! - `cluster --nodes N [--device pi|android|cloud|native]` — boot an
//!   in-process cluster, run a smoke workload, print metrics.
//! - `pipeline [--images N] [--device pi] [--artifacts DIR]` — run the
//!   end-to-end disaster-recovery workflow (paper §V-B) on a synthetic
//!   Hurricane-Sandy-shaped trace and print the Fig. 14 comparison.
//! - `post --profile "<p>" [--action store|...] [--data ...]` — one-shot
//!   AR post against an in-process cluster (demo/debug).
//! - `artifacts-check [--artifacts DIR]` — load + execute every AOT
//!   artifact once and print its outputs (runtime smoke test).

use rpulsar::ar::message::{Action, ArMessage};
use rpulsar::ar::profile::Profile;
use rpulsar::cli::Args;
use rpulsar::config::{DeviceKind, NodeConfig};
use rpulsar::coordinator::Cluster;
use rpulsar::device::profile::DeviceProfile;
use rpulsar::error::{Error, Result};
use rpulsar::pipeline::lidar::LidarTrace;
use rpulsar::pipeline::workflow::{BaselineKind, DisasterRecoveryPipeline};
use rpulsar::runtime::PreprocessRuntime;
use std::path::{Path, PathBuf};

fn main() {
    rpulsar::logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("node") => cmd_node(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("pipeline") => cmd_pipeline(&args),
        Some("post") => cmd_post(&args),
        Some("artifacts-check") => cmd_artifacts_check(&args),
        Some(other) => Err(Error::Config(format!("unknown subcommand `{other}`"))),
        None => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "rpulsar — Edge Based Data-Driven Pipelines (R-Pulsar reproduction)\n\n\
         usage: rpulsar <node|cluster|pipeline|post|artifacts-check> [options]\n\
         \n  node            run one RP (--config FILE, --listen ADDR)\
         \n  cluster         boot an in-process cluster (--nodes N, --device KIND)\
         \n  pipeline        end-to-end disaster-recovery run (--images N, --device KIND)\
         \n  post            one-shot AR post (--profile P, --action A, --data D)\
         \n  artifacts-check load + run every AOT artifact (--artifacts DIR)"
    );
}

fn device_of(args: &Args) -> Result<DeviceKind> {
    DeviceKind::parse(&args.opt_or("device", "native"))
}

fn cmd_node(args: &Args) -> Result<()> {
    let config = match args.opt("config") {
        Some(path) => NodeConfig::from_file(Path::new(path))?,
        None => NodeConfig::default(),
    };
    let listen = args.opt_or("listen", "127.0.0.1:0");
    let mut node = rpulsar::coordinator::Node::new(config)?;
    let endpoint = rpulsar::net::TcpEndpoint::bind(&listen)?;
    println!("node {} listening on {}", node.name(), endpoint.local_addr());
    // Event loop: serve AR messages until the process is killed.
    loop {
        match endpoint.recv_timeout(std::time::Duration::from_millis(500)) {
            Some(rpulsar::net::NetMessage::Ar { msg, .. }) => match node.handle_ar(&msg) {
                Ok(reactions) => log::info!("handled: {} reactions", reactions.len()),
                Err(e) => log::warn!("ar error: {e}"),
            },
            Some(rpulsar::net::NetMessage::Ping { from }) => {
                log::debug!("ping from {from}");
            }
            Some(other) => log::debug!("ignoring {other:?}"),
            None => {}
        }
    }
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let n = args.opt_usize("nodes", 8)?;
    let device = device_of(args)?;
    let mut cluster = Cluster::new("cli", n, device)?;
    println!(
        "cluster up: {} nodes, {} regions",
        cluster.len(),
        cluster.quadtree().regions().count()
    );
    // Smoke workload: store + query a few records.
    let origin = cluster.ids()[0];
    for i in 0..10 {
        let msg = ArMessage::builder()
            .set_header(Profile::parse(&format!("sensor{i},lidar")).unwrap())
            .set_sender("cli")
            .set_action(Action::Store)
            .set_data(vec![0u8; 256])
            .build()?;
        cluster.store_replicated(origin, &msg, 2)?;
    }
    let hits = cluster.query_wildcard(origin, &Profile::parse("sensor*,lidar")?)?;
    println!("stored 10, wildcard-query found {}", hits.len());
    println!(
        "network: {} msgs, {} bytes, {:?} simulated",
        cluster.network().messages(),
        cluster.network().bytes(),
        cluster.network().virtual_elapsed()
    );
    cluster.shutdown()
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let images = args.opt_usize("images", 100)?;
    let device = device_of(args)?;
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let pipeline = DisasterRecoveryPipeline::new(&artifacts, DeviceProfile::for_kind(device))?;
    let trace = LidarTrace::generate(42, images, 16.0);
    println!("trace: {} images, {} nominal bytes", trace.len(), trace.total_bytes());

    let rp = pipeline.run_rpulsar(&trace)?;
    let sq = pipeline.run_baseline(&trace, BaselineKind::KafkaEdgentSqlite)?;
    let nit = pipeline.run_baseline(&trace, BaselineKind::KafkaEdgentNitrite)?;
    for r in [&rp, &sq, &nit] {
        println!(
            "{:24} total={:?} per-image={:?} edge={} core={} dropped={}",
            r.system,
            r.total(),
            r.per_image(),
            r.stored_at_edge,
            r.forwarded_to_core,
            r.dropped
        );
    }
    let gain = 1.0 - rp.total().as_secs_f64() / sq.total().as_secs_f64();
    println!("response-time gain vs kafka+edgent+sqlite: {:.1}%", gain * 100.0);
    Ok(())
}

fn cmd_post(args: &Args) -> Result<()> {
    let profile = Profile::parse(&args.opt_or("profile", "drone,lidar"))?;
    let action = match args.opt_or("action", "store").as_str() {
        "store" => Action::Store,
        "statistics" => Action::Statistics,
        "store-function" => Action::StoreFunction,
        "start-function" => Action::StartFunction,
        "stop-function" => Action::StopFunction,
        "notify-interest" => Action::NotifyInterest,
        "notify-data" => Action::NotifyData,
        "delete" => Action::Delete,
        other => return Err(Error::Config(format!("unknown action `{other}`"))),
    };
    let mut builder = ArMessage::builder()
        .set_header(profile)
        .set_sender("cli")
        .set_action(action)
        .set_data(args.opt_or("data", "").into_bytes());
    if let Some(t) = args.opt("topology") {
        builder = builder.set_topology(t);
    }
    let msg = builder.build()?;
    let mut cluster = Cluster::new("post", args.opt_usize("nodes", 4)?, device_of(args)?)?;
    let origin = cluster.ids()[0];
    let results = cluster.post_from(origin, &msg)?;
    for (target, reactions) in results {
        println!("{target}: {reactions:?}");
    }
    cluster.shutdown()
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let runtime = PreprocessRuntime::load(&dir)?;
    println!("platform: {}", runtime.engine().platform());
    let tile = vec![0.5f32; 256 * 256];
    let out = runtime.preprocess(&tile)?;
    println!("preprocess: result={} quality={}", out.result, out.quality);
    let (_, change) = runtime.change_detect(&tile, &tile)?;
    println!("change_detect(identical): change={change}");
    let score = runtime.quality_score(&out.stats)?;
    println!("quality_score: {score}");
    println!("artifacts OK");
    Ok(())
}
