//! Minimal stderr logger implementing the `log` facade.
//!
//! Level comes from `RPULSAR_LOG` (error|warn|info|debug|trace, default
//! `info`). No external logger crate is available offline.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::io::Write;
use std::sync::Once;
use std::time::{SystemTime, UNIX_EPOCH};

struct StderrLogger {
    level: LevelFilter,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let ts = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0);
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{ts} {tag} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

static INIT: Once = Once::new();

/// Parse a level string (case-insensitive); unknown → Info.
pub fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the global logger once. Safe to call repeatedly.
pub fn init() {
    INIT.call_once(|| {
        let level = std::env::var("RPULSAR_LOG")
            .map(|v| parse_level(&v))
            .unwrap_or(LevelFilter::Info);
        let logger = Box::new(StderrLogger { level });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

/// Install with an explicit level (tests, benches). First call wins.
pub fn init_with_level(level: LevelFilter) {
    INIT.call_once(|| {
        let logger = Box::new(StderrLogger { level });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_variants() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("WARN"), LevelFilter::Warn);
        assert_eq!(parse_level("Debug"), LevelFilter::Debug);
        assert_eq!(parse_level("trace"), LevelFilter::Trace);
        assert_eq!(parse_level("off"), LevelFilter::Off);
        assert_eq!(parse_level("bogus"), LevelFilter::Info);
    }

    #[test]
    fn init_is_idempotent() {
        init_with_level(LevelFilter::Warn);
        init(); // second call is a no-op
        log::info!("not shown at warn level");
        log::warn!("logging smoke test");
    }
}
