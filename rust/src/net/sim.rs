//! Simulated transport for the in-process multi-node cluster
//! (DESIGN.md: substitutes the paper's Chameleon deployment).
//!
//! Delivery is synchronous (the caller routes the message itself); what
//! the simulation adds is *cost accounting*: every hop charges one-way
//! latency plus bandwidth-proportional transfer time to a shared virtual
//! clock, using the sending node's device profile. Benches read the
//! virtual clock to report device-accurate latencies while running at
//! host speed.

use crate::device::profile::DeviceProfile;
use crate::overlay::node_id::NodeId;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shared simulated network.
#[derive(Debug, Clone, Default)]
pub struct SimNetwork {
    inner: Arc<SimInner>,
}

#[derive(Debug, Default)]
struct SimInner {
    /// Per-node device profile (sender side pays the cost).
    profiles: Mutex<BTreeMap<NodeId, DeviceProfile>>,
    /// Virtual clock (ns) — accumulated network time.
    virtual_ns: AtomicU64,
    /// Message counter.
    messages: AtomicU64,
    /// Byte counter.
    bytes: AtomicU64,
    /// Partitioned (unreachable) nodes.
    down: Mutex<Vec<NodeId>>,
}

impl SimNetwork {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a node with its device profile.
    pub fn register(&self, id: NodeId, profile: DeviceProfile) {
        self.inner.profiles.lock().unwrap().insert(id, profile);
    }

    /// Whether a node is registered and reachable.
    pub fn is_reachable(&self, id: &NodeId) -> bool {
        self.inner.profiles.lock().unwrap().contains_key(id)
            && !self.inner.down.lock().unwrap().contains(id)
    }

    /// Partition a node (keep-alive failures, crash injection).
    pub fn take_down(&self, id: NodeId) {
        let mut down = self.inner.down.lock().unwrap();
        if !down.contains(&id) {
            down.push(id);
        }
    }

    /// Heal a partition.
    pub fn bring_up(&self, id: &NodeId) {
        self.inner.down.lock().unwrap().retain(|d| d != id);
    }

    /// Charge one hop from `from` to `to` carrying `bytes`. Returns the
    /// simulated duration, or `None` when either side is unreachable.
    pub fn charge_hop(&self, from: &NodeId, to: &NodeId, bytes: usize) -> Option<Duration> {
        if !self.is_reachable(from) || !self.is_reachable(to) {
            return None;
        }
        let profiles = self.inner.profiles.lock().unwrap();
        let p = profiles.get(from)?;
        // Canonicalized bandwidth: infinite/NaN/zero profiles charge a
        // large-but-finite link instead of a literal 0-cost hop, so the
        // virtual clock (and everything ranked on it) stays NaN-free
        // and deterministically ordered.
        let transfer = bytes as f64 / (p.effective_net_bandwidth() * 1e6);
        let d = Duration::from_nanos(((p.net_latency_us * 1e-6 + transfer) * 1e9) as u64);
        self.inner.virtual_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.inner.messages.fetch_add(1, Ordering::Relaxed);
        self.inner.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        Some(d)
    }

    /// Accumulated virtual network time.
    pub fn virtual_elapsed(&self) -> Duration {
        Duration::from_nanos(self.inner.virtual_ns.load(Ordering::Relaxed))
    }

    /// Total messages sent.
    pub fn messages(&self) -> u64 {
        self.inner.messages.load(Ordering::Relaxed)
    }

    /// Total bytes sent.
    pub fn bytes(&self) -> u64 {
        self.inner.bytes.load(Ordering::Relaxed)
    }

    /// Reset counters (bench iterations).
    pub fn reset(&self) {
        self.inner.virtual_ns.store(0, Ordering::Relaxed);
        self.inner.messages.store(0, Ordering::Relaxed);
        self.inner.bytes.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("s-{n}"))
    }

    fn net2() -> (SimNetwork, NodeId, NodeId) {
        let net = SimNetwork::new();
        let (a, b) = (id(1), id(2));
        net.register(a, DeviceProfile::raspberry_pi());
        net.register(b, DeviceProfile::raspberry_pi());
        (net, a, b)
    }

    #[test]
    fn hop_charges_latency_and_bandwidth() {
        let (net, a, b) = net2();
        let d = net.charge_hop(&a, &b, 1_000_000).unwrap();
        // 300 µs + 1 MB / 11 MB/s ≈ 91.2 ms.
        let expected = 300e-6 + 1.0 / 11e6 * 1e6;
        assert!((d.as_secs_f64() - expected).abs() < 1e-3, "{d:?}");
        assert_eq!(net.messages(), 1);
        assert_eq!(net.bytes(), 1_000_000);
        assert_eq!(net.virtual_elapsed(), d);
    }

    #[test]
    fn unknown_nodes_unreachable() {
        let net = SimNetwork::new();
        assert!(!net.is_reachable(&id(9)));
        assert!(net.charge_hop(&id(9), &id(10), 10).is_none());
    }

    #[test]
    fn partition_and_heal() {
        let (net, a, b) = net2();
        net.take_down(b);
        assert!(net.charge_hop(&a, &b, 10).is_none());
        assert!(!net.is_reachable(&b));
        net.bring_up(&b);
        assert!(net.charge_hop(&a, &b, 10).is_some());
    }

    #[test]
    fn reset_clears_counters() {
        let (net, a, b) = net2();
        net.charge_hop(&a, &b, 100).unwrap();
        net.reset();
        assert_eq!(net.messages(), 0);
        assert_eq!(net.virtual_elapsed(), Duration::ZERO);
    }

    #[test]
    fn infinite_bandwidth_charges_finite_nonzero_transfer() {
        // `native()` keeps Table-I-style infinity in the stored profile;
        // the hop charge canonicalizes it so virtual time stays ordered.
        let net = SimNetwork::new();
        let (a, b) = (id(1), id(2));
        net.register(a, DeviceProfile::native());
        net.register(b, DeviceProfile::native());
        let d = net.charge_hop(&a, &b, 1_000_000_000).unwrap();
        assert!(d > Duration::ZERO, "1 GB over a canonicalized link must cost time");
        assert!(d < Duration::from_secs(1), "native link is still near-free: {d:?}");
    }

    #[test]
    fn sender_profile_determines_cost() {
        let net = SimNetwork::new();
        let pi = id(1);
        let cloud = id(2);
        net.register(pi, DeviceProfile::raspberry_pi());
        net.register(cloud, DeviceProfile::cloud_small());
        let from_pi = net.charge_hop(&pi, &cloud, 1_000_000).unwrap();
        let from_cloud = net.charge_hop(&cloud, &pi, 1_000_000).unwrap();
        assert!(from_pi > from_cloud, "Pi uplink is slower than cloud NIC");
    }
}
