//! Minimal framed-TCP transport for running real multi-process nodes
//! (`rpulsar node` subcommand). Frames are `[len u32 le][body]` with
//! bodies encoded by [`super::wire::NetMessage`].
//!
//! Thread-based (no tokio offline): one acceptor thread, one reader
//! thread per connection, delivering into an mpsc inbox the node's event
//! loop drains.

use super::wire::NetMessage;
use crate::error::{Error, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

const MAX_FRAME: usize = 64 << 20;

/// Write one frame to a stream.
pub fn write_frame(stream: &mut TcpStream, msg: &NetMessage) -> Result<()> {
    write_frame_bytes(stream, &msg.encode())
}

/// Write an already-encoded frame body to a stream. The zero-copy hop
/// path encodes batches once into a pooled buffer and ships the bytes
/// directly; this is the transport half of that contract.
pub fn write_frame_bytes(stream: &mut TcpStream, body: &[u8]) -> Result<()> {
    if body.len() > MAX_FRAME {
        return Err(Error::Net(format!("frame of {} bytes too large", body.len())));
    }
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(body)?;
    Ok(())
}

/// Read one frame from a stream.
pub fn read_frame(stream: &mut TcpStream) -> Result<NetMessage> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(Error::Net(format!("frame of {len} bytes too large")));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    NetMessage::decode(&body)
}

/// A listening TCP endpoint delivering inbound messages to an inbox.
pub struct TcpEndpoint {
    local_addr: String,
    inbox: Receiver<NetMessage>,
    _accept_thread: JoinHandle<()>,
    shutdown: Arc<Mutex<bool>>,
}

impl TcpEndpoint {
    /// Bind and start accepting.
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?.to_string();
        let (tx, inbox) = channel::<NetMessage>();
        let shutdown = Arc::new(Mutex::new(false));
        let shutdown2 = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if *shutdown2.lock().unwrap() {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let tx = tx.clone();
                        std::thread::spawn(move || reader_loop(stream, tx));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(TcpEndpoint { local_addr, inbox, _accept_thread: accept_thread, shutdown })
    }

    /// The bound address (use `127.0.0.1:0` to get an ephemeral port).
    pub fn local_addr(&self) -> &str {
        &self.local_addr
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<NetMessage> {
        self.inbox.recv_timeout(timeout).ok()
    }

    /// Send one message to a peer address (connection per message — fine
    /// for control traffic; data uses `push` streams).
    pub fn send_to<A: ToSocketAddrs>(addr: A, msg: &NetMessage) -> Result<()> {
        let mut stream = TcpStream::connect(addr)?;
        write_frame(&mut stream, msg)
    }

    /// Stop accepting (existing reader threads drain and exit).
    pub fn shutdown(&self) {
        *self.shutdown.lock().unwrap() = true;
        // Poke the acceptor so it notices.
        let _ = TcpStream::connect(&self.local_addr);
    }
}

fn reader_loop(mut stream: TcpStream, tx: Sender<NetMessage>) {
    loop {
        match read_frame(&mut stream) {
            Ok(msg) => {
                if tx.send(msg).is_err() {
                    return;
                }
            }
            Err(_) => return, // EOF or bad frame
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overlay::node_id::NodeId;
    use std::time::Duration;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("t-{n}"))
    }

    #[test]
    fn send_and_receive_over_loopback() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().to_string();
        TcpEndpoint::send_to(&addr, &NetMessage::Ping { from: id(1) }).unwrap();
        let got = ep.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(got, NetMessage::Ping { from: id(1) });
        ep.shutdown();
    }

    #[test]
    fn multiple_senders_all_delivered() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().to_string();
        let handles: Vec<_> = (0..4)
            .map(|n| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    TcpEndpoint::send_to(&addr, &NetMessage::Ping { from: id(n) }).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = 0;
        while ep.recv_timeout(Duration::from_millis(500)).is_some() {
            got += 1;
            if got == 4 {
                break;
            }
        }
        assert_eq!(got, 4);
        ep.shutdown();
    }

    #[test]
    fn large_payload_round_trips() {
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().to_string();
        let msg = NetMessage::Push {
            from: id(9),
            topic: "drone,lidar".into(),
            payload: vec![0xAB; 1 << 20],
        };
        TcpEndpoint::send_to(&addr, &msg).unwrap();
        let got = ep.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, msg);
        ep.shutdown();
    }

    #[test]
    fn connect_to_dead_port_errors() {
        // Bind then shut down to get a (very likely) dead port.
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().to_string();
        ep.shutdown();
        std::thread::sleep(Duration::from_millis(50));
        drop(ep);
        std::thread::sleep(Duration::from_millis(50));
        let res = TcpEndpoint::send_to(&addr, &NetMessage::Ping { from: id(1) });
        // May race with OS port reuse, but usually errors.
        let _ = res;
    }
}
