//! Wire protocol: the messages RPs exchange, with a framed binary codec
//! (length-prefixed frames over TCP; raw structs over the simulated
//! transport).

use crate::ar::message::ArMessage;
use crate::error::{Error, Result};
use crate::overlay::node_id::{NodeId, ID_BYTES};
use crate::util::codec::{ByteReader, ByteWriter};

/// Overlay/application messages.
#[derive(Debug, Clone, PartialEq)]
pub enum NetMessage {
    /// Join-phase discovery broadcast.
    Discovery { from: NodeId },
    /// Answer to a discovery: the responder's id (routing-table seed).
    DiscoveryReply { from: NodeId },
    /// Keep-alive probe.
    Ping { from: NodeId },
    /// Keep-alive answer.
    Pong { from: NodeId },
    /// An AR message for the rendezvous layer.
    Ar { from: NodeId, msg: ArMessage },
    /// Stream data push (paper's `push` primitive payload).
    Push { from: NodeId, topic: String, payload: Vec<u8> },
}

impl NetMessage {
    fn tag(&self) -> u8 {
        match self {
            NetMessage::Discovery { .. } => 0,
            NetMessage::DiscoveryReply { .. } => 1,
            NetMessage::Ping { .. } => 2,
            NetMessage::Pong { .. } => 3,
            NetMessage::Ar { .. } => 4,
            NetMessage::Push { .. } => 5,
        }
    }

    /// Sender id.
    pub fn from(&self) -> NodeId {
        match self {
            NetMessage::Discovery { from }
            | NetMessage::DiscoveryReply { from }
            | NetMessage::Ping { from }
            | NetMessage::Pong { from }
            | NetMessage::Ar { from, .. }
            | NetMessage::Push { from, .. } => *from,
        }
    }

    /// Encode to a frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(self.tag());
        w.put_raw(&self.from().0);
        match self {
            NetMessage::Ar { msg, .. } => {
                w.put_bytes(&msg.encode());
            }
            NetMessage::Push { topic, payload, .. } => {
                w.put_str(topic);
                w.put_bytes(payload);
            }
            _ => {}
        }
        w.into_bytes()
    }

    /// Decode from a frame body.
    pub fn decode(bytes: &[u8]) -> Result<NetMessage> {
        let mut r = ByteReader::new(bytes);
        let tag = r.get_u8()?;
        let id_bytes: [u8; ID_BYTES] = r
            .get_raw(ID_BYTES)?
            .try_into()
            .map_err(|_| Error::Parse("short node id".into()))?;
        let from = NodeId(id_bytes);
        Ok(match tag {
            0 => NetMessage::Discovery { from },
            1 => NetMessage::DiscoveryReply { from },
            2 => NetMessage::Ping { from },
            3 => NetMessage::Pong { from },
            4 => NetMessage::Ar { from, msg: ArMessage::decode(r.get_bytes()?)? },
            5 => NetMessage::Push {
                from,
                topic: r.get_str()?.to_string(),
                payload: r.get_bytes()?.to_vec(),
            },
            other => return Err(Error::Parse(format!("unknown wire tag {other}"))),
        })
    }

    /// Approximate on-wire size (latency accounting).
    pub fn wire_size(&self) -> usize {
        self.encode().len() + 4 // + frame length prefix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::message::Action;
    use crate::ar::profile::Profile;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("w-{n}"))
    }

    #[test]
    fn control_messages_round_trip() {
        for msg in [
            NetMessage::Discovery { from: id(1) },
            NetMessage::DiscoveryReply { from: id(2) },
            NetMessage::Ping { from: id(3) },
            NetMessage::Pong { from: id(4) },
        ] {
            let bytes = msg.encode();
            assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        }
    }

    #[test]
    fn ar_message_round_trip() {
        let ar = ArMessage::builder()
            .set_header(Profile::parse("drone,lidar").unwrap())
            .set_sender("drone-1")
            .set_action(Action::Store)
            .set_data(vec![9, 8, 7])
            .build()
            .unwrap();
        let msg = NetMessage::Ar { from: id(5), msg: ar };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn push_round_trip() {
        let msg = NetMessage::Push {
            from: id(6),
            topic: "drone,lidar".into(),
            payload: vec![0u8; 100],
        };
        assert_eq!(NetMessage::decode(&msg.encode()).unwrap(), msg);
        assert!(msg.wire_size() > 100);
    }

    #[test]
    fn garbage_rejected() {
        assert!(NetMessage::decode(&[]).is_err());
        assert!(NetMessage::decode(&[99]).is_err());
        let mut bytes = NetMessage::Ping { from: id(1) }.encode();
        bytes[0] = 42; // unknown tag
        assert!(NetMessage::decode(&bytes).is_err());
    }
}
