//! Wire protocol: the messages RPs exchange, with a framed binary codec
//! (length-prefixed frames over TCP; raw structs over the simulated
//! transport).

use crate::ar::message::ArMessage;
use crate::error::{Error, Result};
use crate::overlay::node_id::{NodeId, ID_BYTES};
use crate::stream::operator::KeyState;
use crate::stream::tuple::Tuple;
use crate::util::codec::{ByteReader, ByteWriter};
use std::sync::Mutex;

/// Wire tag of a [`NetMessage::StreamBatch`] frame (the zero-copy
/// encoder writes frames without constructing the enum).
const STREAM_BATCH_TAG: u8 = 6;

/// Overlay/application messages.
#[derive(Debug, Clone, PartialEq)]
pub enum NetMessage {
    /// Join-phase discovery broadcast.
    Discovery { from: NodeId },
    /// Answer to a discovery: the responder's id (routing-table seed).
    DiscoveryReply { from: NodeId },
    /// Keep-alive probe.
    Ping { from: NodeId },
    /// Keep-alive answer.
    Pong { from: NodeId },
    /// An AR message for the rendezvous layer.
    Ar { from: NodeId, msg: ArMessage },
    /// Stream data push (paper's `push` primitive payload).
    Push { from: NodeId, topic: String, payload: Vec<u8> },
    /// A batch of stream tuples crossing a node boundary: the egress of
    /// one topology fragment feeding the ingress (router inbound) of
    /// the next fragment's first stage on another node.
    StreamBatch { from: NodeId, topology: String, stage: String, tuples: Vec<Tuple> },
    /// End-of-stream marker for a cross-node stage hop: everything the
    /// upstream fragment will ever emit has been shipped; the receiving
    /// fragment may drain and flush (zero-loss `finish` across nodes).
    StreamEos { from: NodeId, topology: String, stage: String },
    /// Federated subscription registration (libp2p rendezvous idiom:
    /// a node registers its consumers at every peer, with a TTL). The
    /// entry node forwards the registration to all peers; each applies
    /// it to its local matching plane and starts the TTL watermark
    /// (`ttl_ms == 0` = no expiry). Re-sending refreshes the watermark.
    Register { from: NodeId, consumer: String, profile: crate::ar::profile::Profile, ttl_ms: u64 },
    /// Withdraw a federated registration before its TTL lapses.
    Unregister { from: NodeId, consumer: String },
    /// Per-key operator state of one stage crossing a node boundary
    /// during a live fragment migration: the rescale handoff's exported
    /// `KeyState`s, shipped from the old host to the fresh fragment on
    /// the new host. One frame per stage holding state.
    MigrateState { from: NodeId, topology: String, stage: String, state: Vec<KeyState> },
    /// Checkpoint epoch barrier crossing a node boundary: everything
    /// the upstream fragment emitted for epochs ≤ `epoch` has been
    /// shipped ahead of this frame; the downstream fragment's snapshot
    /// belongs to the same epoch. One frame per inter-node hop per
    /// checkpoint.
    Barrier { from: NodeId, topology: String, epoch: u64 },
}

impl NetMessage {
    fn tag(&self) -> u8 {
        match self {
            NetMessage::Discovery { .. } => 0,
            NetMessage::DiscoveryReply { .. } => 1,
            NetMessage::Ping { .. } => 2,
            NetMessage::Pong { .. } => 3,
            NetMessage::Ar { .. } => 4,
            NetMessage::Push { .. } => 5,
            NetMessage::StreamBatch { .. } => 6,
            NetMessage::StreamEos { .. } => 7,
            NetMessage::Register { .. } => 8,
            NetMessage::Unregister { .. } => 9,
            NetMessage::MigrateState { .. } => 10,
            NetMessage::Barrier { .. } => 11,
        }
    }

    /// Sender id.
    pub fn from(&self) -> NodeId {
        match self {
            NetMessage::Discovery { from }
            | NetMessage::DiscoveryReply { from }
            | NetMessage::Ping { from }
            | NetMessage::Pong { from }
            | NetMessage::Ar { from, .. }
            | NetMessage::Push { from, .. }
            | NetMessage::StreamBatch { from, .. }
            | NetMessage::StreamEos { from, .. }
            | NetMessage::Register { from, .. }
            | NetMessage::Unregister { from, .. }
            | NetMessage::MigrateState { from, .. }
            | NetMessage::Barrier { from, .. } => *from,
        }
    }

    /// Encode to a frame body.
    pub fn encode(&self) -> Vec<u8> {
        if let NetMessage::StreamBatch { from, topology, stage, tuples } = self {
            // Delegate to the zero-copy encoder so the two paths are
            // byte-identical by construction.
            let mut w = ByteWriter::new();
            encode_stream_batch_into(&mut w, *from, topology, stage, tuples);
            return w.into_bytes();
        }
        let mut w = ByteWriter::new();
        w.put_u8(self.tag());
        w.put_raw(&self.from().0);
        match self {
            NetMessage::Ar { msg, .. } => {
                w.put_bytes(&msg.encode());
            }
            NetMessage::Push { topic, payload, .. } => {
                w.put_str(topic);
                w.put_bytes(payload);
            }
            NetMessage::StreamEos { topology, stage, .. } => {
                w.put_str(topology);
                w.put_str(stage);
            }
            NetMessage::Register { consumer, profile, ttl_ms, .. } => {
                w.put_str(consumer);
                profile.encode(&mut w);
                w.put_varint(*ttl_ms);
            }
            NetMessage::Unregister { consumer, .. } => {
                w.put_str(consumer);
            }
            NetMessage::MigrateState { topology, stage, state, .. } => {
                w.put_str(topology);
                w.put_str(stage);
                w.put_varint(state.len() as u64);
                for ks in state {
                    w.put_u64(ks.key_bits);
                    w.put_bytes(&ks.bytes);
                }
            }
            NetMessage::Barrier { topology, epoch, .. } => {
                w.put_str(topology);
                w.put_varint(*epoch);
            }
            _ => {}
        }
        w.into_bytes()
    }

    /// Decode from a frame body.
    pub fn decode(bytes: &[u8]) -> Result<NetMessage> {
        let mut r = ByteReader::new(bytes);
        let tag = r.get_u8()?;
        let id_bytes: [u8; ID_BYTES] = r
            .get_raw(ID_BYTES)?
            .try_into()
            .map_err(|_| Error::Parse("short node id".into()))?;
        let from = NodeId(id_bytes);
        Ok(match tag {
            0 => NetMessage::Discovery { from },
            1 => NetMessage::DiscoveryReply { from },
            2 => NetMessage::Ping { from },
            3 => NetMessage::Pong { from },
            4 => NetMessage::Ar { from, msg: ArMessage::decode(r.get_bytes()?)? },
            5 => NetMessage::Push {
                from,
                topic: r.get_str()?.to_string(),
                payload: r.get_bytes()?.to_vec(),
            },
            6 => {
                let topology = r.get_str()?.to_string();
                let stage = r.get_str()?.to_string();
                let n = r.get_varint()?;
                let mut tuples = Vec::new();
                for _ in 0..n {
                    tuples.push(Tuple::decode_from(&mut r)?);
                }
                NetMessage::StreamBatch { from, topology, stage, tuples }
            }
            7 => NetMessage::StreamEos {
                from,
                topology: r.get_str()?.to_string(),
                stage: r.get_str()?.to_string(),
            },
            8 => {
                let consumer = r.get_str()?.to_string();
                let profile = crate::ar::profile::Profile::decode(&mut r)?;
                let ttl_ms = r.get_varint()?;
                NetMessage::Register { from, consumer, profile, ttl_ms }
            }
            9 => NetMessage::Unregister { from, consumer: r.get_str()?.to_string() },
            10 => {
                let topology = r.get_str()?.to_string();
                let stage = r.get_str()?.to_string();
                let n = r.get_varint()?;
                let mut state = Vec::with_capacity(n.min(4096) as usize);
                for _ in 0..n {
                    let key_bits = r.get_u64()?;
                    let bytes = r.get_bytes()?.to_vec();
                    state.push(KeyState { key_bits, bytes });
                }
                NetMessage::MigrateState { from, topology, stage, state }
            }
            11 => NetMessage::Barrier {
                from,
                topology: r.get_str()?.to_string(),
                epoch: r.get_varint()?,
            },
            other => return Err(Error::Parse(format!("unknown wire tag {other}"))),
        })
    }

    /// Approximate on-wire size (latency accounting).
    pub fn wire_size(&self) -> usize {
        self.encode().len() + 4 // + frame length prefix
    }
}

/// Encode a `StreamBatch` frame body directly into `w`, without ever
/// constructing a [`NetMessage`]. This is the hot-path encoder for
/// cross-node hops: operator egress goes straight into a (pooled) wire
/// buffer. Byte-identical to `NetMessage::StreamBatch { .. }.encode()`
/// — that path delegates here.
pub fn encode_stream_batch_into(
    w: &mut ByteWriter,
    from: NodeId,
    topology: &str,
    stage: &str,
    tuples: &[Tuple],
) {
    w.put_u8(STREAM_BATCH_TAG);
    w.put_raw(&from.0);
    w.put_str(topology);
    w.put_str(stage);
    w.put_varint(tuples.len() as u64);
    for t in tuples {
        t.encode_into(w);
    }
}

/// Decode just the tuples of a `StreamBatch` frame body, skipping the
/// `String` allocations for topology/stage that `NetMessage::decode`
/// performs (the receiving route already knows both).
pub fn decode_stream_batch(bytes: &[u8]) -> Result<Vec<Tuple>> {
    let mut r = ByteReader::new(bytes);
    let tag = r.get_u8()?;
    if tag != STREAM_BATCH_TAG {
        return Err(Error::Parse(format!("expected stream batch frame, got tag {tag}")));
    }
    r.get_raw(ID_BYTES)?; // sender id — route context supplies it
    r.get_str()?; // topology
    r.get_str()?; // stage
    let n = r.get_varint()?;
    let mut tuples = Vec::with_capacity(n.min(4096) as usize);
    for _ in 0..n {
        tuples.push(Tuple::decode_from(&mut r)?);
    }
    Ok(tuples)
}

/// An encoded `StreamBatch` frame that optionally still owns its
/// decoded tuples. The cross-node data path stages these: a batch is
/// encoded exactly once at egress, shipped as raw bytes, and — when the
/// decoded form is kept — handed to the downstream ingress without a
/// decode round-trip. A backpressure rejection gives the tuples back
/// (see [`WireBatch::give_back`]) so neither the bytes nor the decoded
/// form are ever re-materialized.
#[derive(Debug)]
pub struct WireBatch {
    bytes: Vec<u8>,
    count: usize,
    decoded: Option<Vec<Tuple>>,
}

impl WireBatch {
    /// Encode `tuples` into `buf` (recycled: contents cleared, capacity
    /// kept) and keep the decoded form alongside the bytes.
    pub fn encode_with(
        buf: Vec<u8>,
        from: NodeId,
        topology: &str,
        stage: &str,
        tuples: Vec<Tuple>,
    ) -> WireBatch {
        let mut w = ByteWriter::from_vec(buf);
        encode_stream_batch_into(&mut w, from, topology, stage, &tuples);
        WireBatch { bytes: w.into_bytes(), count: tuples.len(), decoded: Some(tuples) }
    }

    /// Drop the decoded form, forcing the first [`WireBatch::take_tuples`]
    /// to decode from the wire bytes. The legacy synchronous pump uses
    /// this to keep PR-4 fidelity: the receiving side pays the decode,
    /// exactly as if the bytes had crossed a real link.
    pub fn forget_decoded(&mut self) {
        self.decoded = None;
    }

    /// Number of tuples in the frame.
    pub fn tuple_count(&self) -> usize {
        self.count
    }

    /// The encoded frame body.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// On-wire size (frame body + length prefix), matching
    /// [`NetMessage::wire_size`] accounting.
    pub fn wire_size(&self) -> usize {
        self.bytes.len() + 4
    }

    /// Take the tuples: the cached decoded form when present, otherwise
    /// one decode from the wire bytes.
    pub fn take_tuples(&mut self) -> Result<Vec<Tuple>> {
        match self.decoded.take() {
            Some(tuples) => Ok(tuples),
            None => decode_stream_batch(&self.bytes),
        }
    }

    /// Return tuples after an ingress rejection: the batch keeps both
    /// its encoded bytes and the decoded form, so a retry re-encodes
    /// and re-decodes nothing.
    pub fn give_back(&mut self, tuples: Vec<Tuple>) {
        self.decoded = Some(tuples);
    }

    /// Consume the batch, recovering the byte buffer for pooling.
    pub fn into_buffer(self) -> Vec<u8> {
        self.bytes
    }
}

/// Upper bound on buffers a [`BufferPool`] retains; beyond this,
/// returned buffers are simply dropped.
const POOL_CAP: usize = 64;

/// A small free-list of wire buffers. `get` hands out a recycled
/// buffer when one is available (capacity intact, so the encode does
/// not re-allocate); `put` returns a buffer after its frame is shipped
/// and admitted downstream.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<u8>>>,
}

impl BufferPool {
    /// New empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a buffer; `true` when it was recycled from the pool.
    pub fn get(&self) -> (Vec<u8>, bool) {
        match self.free.lock().unwrap().pop() {
            Some(buf) => (buf, true),
            None => (Vec::new(), false),
        }
    }

    /// Return a buffer for reuse.
    pub fn put(&self, buf: Vec<u8>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < POOL_CAP {
            free.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::message::Action;
    use crate::ar::profile::Profile;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("w-{n}"))
    }

    #[test]
    fn control_messages_round_trip() {
        for msg in [
            NetMessage::Discovery { from: id(1) },
            NetMessage::DiscoveryReply { from: id(2) },
            NetMessage::Ping { from: id(3) },
            NetMessage::Pong { from: id(4) },
        ] {
            let bytes = msg.encode();
            assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        }
    }

    #[test]
    fn ar_message_round_trip() {
        let ar = ArMessage::builder()
            .set_header(Profile::parse("drone,lidar").unwrap())
            .set_sender("drone-1")
            .set_action(Action::Store)
            .set_data(vec![9, 8, 7])
            .build()
            .unwrap();
        let msg = NetMessage::Ar { from: id(5), msg: ar };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn push_round_trip() {
        let msg = NetMessage::Push {
            from: id(6),
            topic: "drone,lidar".into(),
            payload: vec![0u8; 100],
        };
        assert_eq!(NetMessage::decode(&msg.encode()).unwrap(), msg);
        assert!(msg.wire_size() > 100);
    }

    #[test]
    fn stream_batch_round_trip() {
        let tuples = vec![
            Tuple::new(0, vec![1, 2, 3]).with("IMG", 4.0).with("V", -1.5),
            Tuple::new(1, vec![]).with("IMG", 4.0),
        ];
        let msg = NetMessage::StreamBatch {
            from: id(7),
            topology: "analytics".into(),
            stage: "stats".into(),
            tuples,
        };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        assert_eq!(msg.wire_size(), bytes.len() + 4);
        let eos = NetMessage::StreamEos {
            from: id(7),
            topology: "analytics".into(),
            stage: "stats".into(),
        };
        assert_eq!(NetMessage::decode(&eos.encode()).unwrap(), eos);
    }

    #[test]
    fn zero_copy_encode_is_byte_identical() {
        let tuples = vec![
            Tuple::new(0, vec![1, 2, 3]).with("IMG", 4.0).with("V", -1.5),
            Tuple::new(1, vec![0xCD; 64]).with("IMG", 2.0),
            Tuple::new(2, vec![]),
        ];
        let via_enum = NetMessage::StreamBatch {
            from: id(9),
            topology: "analytics".into(),
            stage: "stats".into(),
            tuples: tuples.clone(),
        }
        .encode();
        let batch = WireBatch::encode_with(Vec::new(), id(9), "analytics", "stats", tuples.clone());
        assert_eq!(batch.bytes(), &via_enum[..], "WireBatch frame must match NetMessage::encode");
        assert_eq!(batch.wire_size(), via_enum.len() + 4);
        assert_eq!(batch.tuple_count(), 3);
        assert_eq!(decode_stream_batch(batch.bytes()).unwrap(), tuples);
    }

    #[test]
    fn wire_batch_caches_decoded_form() {
        let tuples =
            vec![Tuple::new(4, vec![7; 16]).with("K", 1.0), Tuple::new(5, vec![]).with("K", 2.0)];
        let mut batch = WireBatch::encode_with(Vec::new(), id(3), "t", "s", tuples.clone());
        // Cached path: no decode happened, same tuples come back.
        let got = batch.take_tuples().unwrap();
        assert_eq!(got, tuples);
        // Give-back after a rejection restores the cache.
        batch.give_back(got);
        assert_eq!(batch.take_tuples().unwrap(), tuples);
        // Forgetting the decoded form forces a decode from wire bytes.
        batch.give_back(tuples.clone());
        batch.forget_decoded();
        assert_eq!(batch.take_tuples().unwrap(), tuples);
    }

    #[test]
    fn register_round_trip() {
        let msg = NetMessage::Register {
            from: id(11),
            consumer: "trigger:job".into(),
            profile: Profile::parse("drone,li*,lat:40..41").unwrap(),
            ttl_ms: 30_000,
        };
        assert_eq!(NetMessage::decode(&msg.encode()).unwrap(), msg);
        let never_expires = NetMessage::Register {
            from: id(11),
            consumer: "c".into(),
            profile: Profile::parse("a").unwrap(),
            ttl_ms: 0,
        };
        assert_eq!(NetMessage::decode(&never_expires.encode()).unwrap(), never_expires);
        let bye = NetMessage::Unregister { from: id(12), consumer: "trigger:job".into() };
        assert_eq!(NetMessage::decode(&bye.encode()).unwrap(), bye);
    }

    #[test]
    fn migrate_state_round_trip() {
        let msg = NetMessage::MigrateState {
            from: id(13),
            topology: "analytics#f1".into(),
            stage: "kwin".into(),
            state: vec![
                KeyState { key_bits: 3.0f64.to_bits(), bytes: vec![1, 2, 3, 4, 5, 6, 7, 8] },
                KeyState { key_bits: 7.5f64.to_bits(), bytes: vec![] },
            ],
        };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        assert_eq!(msg.wire_size(), bytes.len() + 4);
        // A stateless stage still frames cleanly (empty state vector).
        let empty = NetMessage::MigrateState {
            from: id(13),
            topology: "analytics#f1".into(),
            stage: "inc".into(),
            state: Vec::new(),
        };
        assert_eq!(NetMessage::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn barrier_round_trip() {
        let msg =
            NetMessage::Barrier { from: id(14), topology: "analytics".into(), epoch: 42 };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        assert_eq!(msg.wire_size(), bytes.len() + 4);
        // Epoch 0 (the pre-data initial checkpoint) frames cleanly.
        let first = NetMessage::Barrier { from: id(14), topology: "t".into(), epoch: 0 };
        assert_eq!(NetMessage::decode(&first.encode()).unwrap(), first);
    }

    #[test]
    fn decode_stream_batch_rejects_other_frames() {
        let ping = NetMessage::Ping { from: id(1) }.encode();
        assert!(decode_stream_batch(&ping).is_err());
        assert!(decode_stream_batch(&[]).is_err());
    }

    #[test]
    fn buffer_pool_recycles() {
        let pool = BufferPool::new();
        let (buf, reused) = pool.get();
        assert!(!reused, "empty pool cannot recycle");
        let batch = WireBatch::encode_with(buf, id(2), "t", "s", vec![Tuple::new(0, vec![1; 256])]);
        let cap = batch.bytes().len();
        pool.put(batch.into_buffer());
        let (buf, reused) = pool.get();
        assert!(reused, "returned buffer must be handed back out");
        assert!(buf.capacity() >= cap, "recycled buffer keeps its allocation");
    }

    #[test]
    fn garbage_rejected() {
        assert!(NetMessage::decode(&[]).is_err());
        assert!(NetMessage::decode(&[99]).is_err());
        let mut bytes = NetMessage::Ping { from: id(1) }.encode();
        bytes[0] = 42; // unknown tag
        assert!(NetMessage::decode(&bytes).is_err());
    }
}
