//! Wire protocol: the messages RPs exchange, with a framed binary codec
//! (length-prefixed frames over TCP; raw structs over the simulated
//! transport).

use crate::ar::message::ArMessage;
use crate::error::{Error, Result};
use crate::overlay::node_id::{NodeId, ID_BYTES};
use crate::stream::tuple::Tuple;
use crate::util::codec::{ByteReader, ByteWriter};

/// Overlay/application messages.
#[derive(Debug, Clone, PartialEq)]
pub enum NetMessage {
    /// Join-phase discovery broadcast.
    Discovery { from: NodeId },
    /// Answer to a discovery: the responder's id (routing-table seed).
    DiscoveryReply { from: NodeId },
    /// Keep-alive probe.
    Ping { from: NodeId },
    /// Keep-alive answer.
    Pong { from: NodeId },
    /// An AR message for the rendezvous layer.
    Ar { from: NodeId, msg: ArMessage },
    /// Stream data push (paper's `push` primitive payload).
    Push { from: NodeId, topic: String, payload: Vec<u8> },
    /// A batch of stream tuples crossing a node boundary: the egress of
    /// one topology fragment feeding the ingress (router inbound) of
    /// the next fragment's first stage on another node.
    StreamBatch { from: NodeId, topology: String, stage: String, tuples: Vec<Tuple> },
    /// End-of-stream marker for a cross-node stage hop: everything the
    /// upstream fragment will ever emit has been shipped; the receiving
    /// fragment may drain and flush (zero-loss `finish` across nodes).
    StreamEos { from: NodeId, topology: String, stage: String },
}

impl NetMessage {
    fn tag(&self) -> u8 {
        match self {
            NetMessage::Discovery { .. } => 0,
            NetMessage::DiscoveryReply { .. } => 1,
            NetMessage::Ping { .. } => 2,
            NetMessage::Pong { .. } => 3,
            NetMessage::Ar { .. } => 4,
            NetMessage::Push { .. } => 5,
            NetMessage::StreamBatch { .. } => 6,
            NetMessage::StreamEos { .. } => 7,
        }
    }

    /// Sender id.
    pub fn from(&self) -> NodeId {
        match self {
            NetMessage::Discovery { from }
            | NetMessage::DiscoveryReply { from }
            | NetMessage::Ping { from }
            | NetMessage::Pong { from }
            | NetMessage::Ar { from, .. }
            | NetMessage::Push { from, .. }
            | NetMessage::StreamBatch { from, .. }
            | NetMessage::StreamEos { from, .. } => *from,
        }
    }

    /// Encode to a frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(self.tag());
        w.put_raw(&self.from().0);
        match self {
            NetMessage::Ar { msg, .. } => {
                w.put_bytes(&msg.encode());
            }
            NetMessage::Push { topic, payload, .. } => {
                w.put_str(topic);
                w.put_bytes(payload);
            }
            NetMessage::StreamBatch { topology, stage, tuples, .. } => {
                w.put_str(topology);
                w.put_str(stage);
                w.put_varint(tuples.len() as u64);
                for t in tuples {
                    t.encode_into(&mut w);
                }
            }
            NetMessage::StreamEos { topology, stage, .. } => {
                w.put_str(topology);
                w.put_str(stage);
            }
            _ => {}
        }
        w.into_bytes()
    }

    /// Decode from a frame body.
    pub fn decode(bytes: &[u8]) -> Result<NetMessage> {
        let mut r = ByteReader::new(bytes);
        let tag = r.get_u8()?;
        let id_bytes: [u8; ID_BYTES] = r
            .get_raw(ID_BYTES)?
            .try_into()
            .map_err(|_| Error::Parse("short node id".into()))?;
        let from = NodeId(id_bytes);
        Ok(match tag {
            0 => NetMessage::Discovery { from },
            1 => NetMessage::DiscoveryReply { from },
            2 => NetMessage::Ping { from },
            3 => NetMessage::Pong { from },
            4 => NetMessage::Ar { from, msg: ArMessage::decode(r.get_bytes()?)? },
            5 => NetMessage::Push {
                from,
                topic: r.get_str()?.to_string(),
                payload: r.get_bytes()?.to_vec(),
            },
            6 => {
                let topology = r.get_str()?.to_string();
                let stage = r.get_str()?.to_string();
                let n = r.get_varint()?;
                let mut tuples = Vec::new();
                for _ in 0..n {
                    tuples.push(Tuple::decode_from(&mut r)?);
                }
                NetMessage::StreamBatch { from, topology, stage, tuples }
            }
            7 => NetMessage::StreamEos {
                from,
                topology: r.get_str()?.to_string(),
                stage: r.get_str()?.to_string(),
            },
            other => return Err(Error::Parse(format!("unknown wire tag {other}"))),
        })
    }

    /// Approximate on-wire size (latency accounting).
    pub fn wire_size(&self) -> usize {
        self.encode().len() + 4 // + frame length prefix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::message::Action;
    use crate::ar::profile::Profile;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("w-{n}"))
    }

    #[test]
    fn control_messages_round_trip() {
        for msg in [
            NetMessage::Discovery { from: id(1) },
            NetMessage::DiscoveryReply { from: id(2) },
            NetMessage::Ping { from: id(3) },
            NetMessage::Pong { from: id(4) },
        ] {
            let bytes = msg.encode();
            assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        }
    }

    #[test]
    fn ar_message_round_trip() {
        let ar = ArMessage::builder()
            .set_header(Profile::parse("drone,lidar").unwrap())
            .set_sender("drone-1")
            .set_action(Action::Store)
            .set_data(vec![9, 8, 7])
            .build()
            .unwrap();
        let msg = NetMessage::Ar { from: id(5), msg: ar };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn push_round_trip() {
        let msg = NetMessage::Push {
            from: id(6),
            topic: "drone,lidar".into(),
            payload: vec![0u8; 100],
        };
        assert_eq!(NetMessage::decode(&msg.encode()).unwrap(), msg);
        assert!(msg.wire_size() > 100);
    }

    #[test]
    fn stream_batch_round_trip() {
        let tuples = vec![
            Tuple::new(0, vec![1, 2, 3]).with("IMG", 4.0).with("V", -1.5),
            Tuple::new(1, vec![]).with("IMG", 4.0),
        ];
        let msg = NetMessage::StreamBatch {
            from: id(7),
            topology: "analytics".into(),
            stage: "stats".into(),
            tuples,
        };
        let bytes = msg.encode();
        assert_eq!(NetMessage::decode(&bytes).unwrap(), msg);
        assert_eq!(msg.wire_size(), bytes.len() + 4);
        let eos = NetMessage::StreamEos {
            from: id(7),
            topology: "analytics".into(),
            stage: "stats".into(),
        };
        assert_eq!(NetMessage::decode(&eos.encode()).unwrap(), eos);
    }

    #[test]
    fn garbage_rejected() {
        assert!(NetMessage::decode(&[]).is_err());
        assert!(NetMessage::decode(&[99]).is_err());
        let mut bytes = NetMessage::Ping { from: id(1) }.encode();
        bytes[0] = 42; // unknown tag
        assert!(NetMessage::decode(&bytes).is_err());
    }
}
