//! Transports: the wire protocol ([`wire`]), the cost-accounted
//! simulated network for in-process clusters ([`sim`]), and a framed-TCP
//! transport for real multi-process deployments ([`tcp`]).

pub mod sim;
pub mod tcp;
pub mod wire;

pub use sim::SimNetwork;
pub use tcp::TcpEndpoint;
pub use wire::NetMessage;
