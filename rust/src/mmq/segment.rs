//! One append-only, memory-mapped log segment.
//!
//! Layout:
//!
//! ```text
//! [0..8)   magic  "RPULSARQ"
//! [8..12)  version (u32 le)
//! [12..20) committed write offset (u64 le) — advanced after each append
//! [20..24) base sequence number low bits (u32 le, informational)
//! [24..64) reserved
//! [64..)   records: [len u32][crc32 u32][payload len bytes], 8-byte aligned
//! ```
//!
//! Recovery replays records while length/CRC are valid and consistent
//! with the committed offset; a torn final record is discarded.

use super::mmap::MmapRegion;
use crate::error::{Error, Result};
use crate::util::{align_up, crc32c};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RPULSARQ";
const VERSION: u32 = 1;
/// First byte of the record area.
pub const HEADER_SIZE: usize = 64;
/// Per-record framing overhead.
pub const RECORD_OVERHEAD: usize = 8;

/// An append-only mmap-backed segment.
pub struct Segment {
    region: MmapRegion,
    /// Next write position (bytes from start of file).
    write_pos: usize,
}

impl Segment {
    /// Create a fresh segment of `capacity` bytes at `path`.
    pub fn create(path: &Path, capacity: usize) -> Result<Self> {
        if capacity < HEADER_SIZE + RECORD_OVERHEAD {
            return Err(Error::Queue(format!("segment capacity {capacity} too small")));
        }
        let mut region = MmapRegion::create(path, capacity)?;
        let buf = region.as_mut_slice();
        buf[0..8].copy_from_slice(MAGIC);
        buf[8..12].copy_from_slice(&VERSION.to_le_bytes());
        buf[12..20].copy_from_slice(&(HEADER_SIZE as u64).to_le_bytes());
        Ok(Segment { region, write_pos: HEADER_SIZE })
    }

    /// Re-open an existing segment, replaying its records (recovery).
    pub fn open(path: &Path) -> Result<Self> {
        let region = MmapRegion::open(path)?;
        let buf = region.as_slice();
        if buf.len() < HEADER_SIZE || &buf[0..8] != MAGIC {
            return Err(Error::Queue(format!("{path:?}: not a segment file")));
        }
        let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(Error::Queue(format!("{path:?}: unsupported version {version}")));
        }
        let committed = u64::from_le_bytes(buf[12..20].try_into().unwrap()) as usize;
        // Walk records up to the committed offset, validating CRCs; stop
        // at the first invalid frame (torn write).
        let mut pos = HEADER_SIZE;
        while pos + RECORD_OVERHEAD <= committed.min(buf.len()) {
            let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
            if len == 0 || pos + RECORD_OVERHEAD + len > buf.len() {
                break;
            }
            let stored_crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
            let payload = &buf[pos + 8..pos + 8 + len];
            if crc32c(payload) != stored_crc {
                break;
            }
            pos += align_up(RECORD_OVERHEAD + len, 8);
        }
        Ok(Segment { region, write_pos: pos })
    }

    /// Capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.region.len()
    }

    /// Bytes remaining for appends.
    pub fn remaining(&self) -> usize {
        self.capacity().saturating_sub(self.write_pos)
    }

    /// Current write position (== recovery point).
    pub fn write_pos(&self) -> usize {
        self.write_pos
    }

    /// Whether a payload of `len` bytes fits.
    pub fn fits(&self, len: usize) -> bool {
        align_up(RECORD_OVERHEAD + len, 8) <= self.remaining()
    }

    /// Append one record; returns its byte offset within the segment.
    pub fn append(&mut self, payload: &[u8]) -> Result<usize> {
        if payload.is_empty() {
            return Err(Error::Queue("empty record".into()));
        }
        if payload.len() > u32::MAX as usize {
            return Err(Error::Queue("record too large".into()));
        }
        if !self.fits(payload.len()) {
            return Err(Error::Queue(format!(
                "segment full: need {}, have {}",
                align_up(RECORD_OVERHEAD + payload.len(), 8),
                self.remaining()
            )));
        }
        let pos = self.write_pos;
        let crc = crc32c(payload);
        let buf = self.region.as_mut_slice();
        buf[pos..pos + 4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        buf[pos + 4..pos + 8].copy_from_slice(&crc.to_le_bytes());
        buf[pos + 8..pos + 8 + payload.len()].copy_from_slice(payload);
        self.write_pos = pos + align_up(RECORD_OVERHEAD + payload.len(), 8);
        // Commit: publish the new offset in the header. A crash between
        // the payload write and this store just loses the last record.
        let committed = self.write_pos as u64;
        self.region.as_mut_slice()[12..20].copy_from_slice(&committed.to_le_bytes());
        Ok(pos)
    }

    /// Read the record at `offset` (as returned by [`Segment::append`]).
    pub fn read(&self, offset: usize) -> Result<&[u8]> {
        let buf = self.region.as_slice();
        if offset < HEADER_SIZE || offset + RECORD_OVERHEAD > buf.len() {
            return Err(Error::Queue(format!("bad record offset {offset}")));
        }
        let len = u32::from_le_bytes(buf[offset..offset + 4].try_into().unwrap()) as usize;
        if len == 0 || offset + RECORD_OVERHEAD + len > buf.len() {
            return Err(Error::Queue(format!("corrupt record at {offset}")));
        }
        let stored_crc = u32::from_le_bytes(buf[offset + 4..offset + 8].try_into().unwrap());
        let payload = &buf[offset + 8..offset + 8 + len];
        if crc32c(payload) != stored_crc {
            return Err(Error::Queue(format!("crc mismatch at {offset}")));
        }
        Ok(payload)
    }

    /// Offset of the record following the one at `offset`, or None past
    /// the write position.
    pub fn next_offset(&self, offset: usize) -> Option<usize> {
        let buf = self.region.as_slice();
        if offset + RECORD_OVERHEAD > buf.len() {
            return None;
        }
        let len = u32::from_le_bytes(buf[offset..offset + 4].try_into().unwrap()) as usize;
        let next = offset + align_up(RECORD_OVERHEAD + len, 8);
        if next >= self.write_pos {
            None
        } else {
            Some(next)
        }
    }

    /// Iterate all records from the start.
    pub fn iter(&self) -> SegmentIter<'_> {
        SegmentIter { segment: self, offset: HEADER_SIZE }
    }

    /// Flush dirty pages (`async` by default in the queue; `sync` used by
    /// tests and explicit checkpoints).
    pub fn flush(&self, sync: bool) -> Result<()> {
        self.region.flush(!sync)
    }
}

impl std::fmt::Debug for Segment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Segment(write_pos={}, cap={})", self.write_pos, self.capacity())
    }
}

/// Iterator over a segment's records.
pub struct SegmentIter<'a> {
    segment: &'a Segment,
    offset: usize,
}

impl<'a> Iterator for SegmentIter<'a> {
    type Item = &'a [u8];

    fn next(&mut self) -> Option<Self::Item> {
        if self.offset >= self.segment.write_pos {
            return None;
        }
        let payload = self.segment.read(self.offset).ok()?;
        self.offset = self.offset
            + align_up(RECORD_OVERHEAD + payload.len(), 8);
        Some(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("rpulsar-segment-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}.seg", std::process::id()))
    }

    #[test]
    fn append_then_read() {
        let path = tmp("ar");
        let mut s = Segment::create(&path, 4096).unwrap();
        let o1 = s.append(b"first").unwrap();
        let o2 = s.append(b"second message").unwrap();
        assert_eq!(s.read(o1).unwrap(), b"first");
        assert_eq!(s.read(o2).unwrap(), b"second message");
        assert!(o2 > o1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn iteration_in_order() {
        let path = tmp("iter");
        let mut s = Segment::create(&path, 4096).unwrap();
        for i in 0..10 {
            s.append(format!("msg-{i}").as_bytes()).unwrap();
        }
        let all: Vec<Vec<u8>> = s.iter().map(|r| r.to_vec()).collect();
        assert_eq!(all.len(), 10);
        assert_eq!(all[0], b"msg-0");
        assert_eq!(all[9], b"msg-9");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn recovery_after_reopen() {
        let path = tmp("recover");
        {
            let mut s = Segment::create(&path, 4096).unwrap();
            s.append(b"alpha").unwrap();
            s.append(b"beta").unwrap();
            s.flush(true).unwrap();
        }
        let s = Segment::open(&path).unwrap();
        let all: Vec<Vec<u8>> = s.iter().map(|r| r.to_vec()).collect();
        assert_eq!(all, vec![b"alpha".to_vec(), b"beta".to_vec()]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn recovery_discards_torn_record() {
        let path = tmp("torn");
        {
            let mut s = Segment::create(&path, 4096).unwrap();
            s.append(b"good").unwrap();
            let bad = s.append(b"will-be-corrupted").unwrap();
            // Corrupt the payload after the fact (simulated torn write).
            s.region.as_mut_slice()[bad + 8] ^= 0xFF;
            s.flush(true).unwrap();
        }
        let s = Segment::open(&path).unwrap();
        let all: Vec<Vec<u8>> = s.iter().map(|r| r.to_vec()).collect();
        assert_eq!(all, vec![b"good".to_vec()]);
        // New appends go after the last good record.
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn append_after_recovery_continues() {
        let path = tmp("cont");
        {
            let mut s = Segment::create(&path, 4096).unwrap();
            s.append(b"one").unwrap();
            s.flush(true).unwrap();
        }
        {
            let mut s = Segment::open(&path).unwrap();
            s.append(b"two").unwrap();
            s.flush(true).unwrap();
        }
        let s = Segment::open(&path).unwrap();
        let all: Vec<Vec<u8>> = s.iter().map(|r| r.to_vec()).collect();
        assert_eq!(all, vec![b"one".to_vec(), b"two".to_vec()]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn full_segment_rejects_append() {
        let path = tmp("full");
        let mut s = Segment::create(&path, HEADER_SIZE + 32).unwrap();
        s.append(&[7u8; 16]).unwrap();
        assert!(!s.fits(16));
        assert!(s.append(&[7u8; 16]).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_and_oversized_records_rejected() {
        let path = tmp("sizes");
        let mut s = Segment::create(&path, 4096).unwrap();
        assert!(s.append(b"").is_err());
        assert!(s.append(&vec![0u8; 8192]).is_err()); // exceeds capacity
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn read_rejects_bad_offsets() {
        let path = tmp("badoff");
        let mut s = Segment::create(&path, 4096).unwrap();
        s.append(b"x").unwrap();
        assert!(s.read(0).is_err()); // inside header
        assert!(s.read(5000).is_err()); // out of bounds
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_rejects_non_segment() {
        let path = tmp("notseg");
        std::fs::write(&path, vec![0u8; 128]).unwrap();
        assert!(Segment::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn next_offset_walks_records() {
        let path = tmp("walk");
        let mut s = Segment::create(&path, 4096).unwrap();
        let o1 = s.append(b"aaa").unwrap();
        let o2 = s.append(b"bbbbb").unwrap();
        assert_eq!(s.next_offset(o1), Some(o2));
        assert_eq!(s.next_offset(o2), None);
        std::fs::remove_file(&path).unwrap();
    }
}
