//! Profile-keyed pub/sub broker over memory-mapped queues (paper §IV-C1).
//!
//! Topics are keyed by the canonical rendering of a *simple* profile
//! (pattern-profiles subscribe to many topics via associative matching).
//! The broker offers the paper's claim: "the same guarantees as Mosquitto
//! or Kafka (persistence, durability, and delivery guarantees)" — every
//! message is framed+CRC'd in an mmap segment before acknowledgement, and
//! consumers resume from their last acknowledged offset.

use super::queue::{MemoryMappedQueue, QueueOptions};
use crate::ar::matching;
use crate::ar::profile::Profile;
use crate::error::{Error, Result};
use crate::metrics::Registry;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// A consumer's registered interest.
#[derive(Debug, Clone)]
pub struct SubscriptionState {
    pub consumer: String,
    pub profile: Profile,
    /// Per-topic resume cursor.
    cursors: BTreeMap<String, u64>,
}

/// The broker: one mmap queue per topic, plus subscription state.
pub struct Broker {
    base: QueueOptions,
    topics: BTreeMap<String, (Profile, MemoryMappedQueue)>,
    subscriptions: BTreeMap<String, SubscriptionState>,
    metrics: Registry,
}

impl Broker {
    /// Create a broker rooted at `base.dir` (one subdirectory per topic).
    pub fn new(base: QueueOptions) -> Self {
        Broker { base, topics: BTreeMap::new(), subscriptions: BTreeMap::new(), metrics: Registry::new() }
    }

    /// Broker with shared metrics registry.
    pub fn with_metrics(base: QueueOptions, metrics: Registry) -> Self {
        Broker { base, topics: BTreeMap::new(), subscriptions: BTreeMap::new(), metrics }
    }

    fn topic_key(profile: &Profile) -> Result<String> {
        if !profile.is_simple() {
            return Err(Error::Profile(format!(
                "publish requires a simple profile, got `{}`",
                profile.render()
            )));
        }
        Ok(profile.render())
    }

    fn topic_dir(&self, key: &str) -> PathBuf {
        // Sanitise the profile rendering into a directory name.
        let safe: String = key
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
            .collect();
        self.base.dir.join(safe)
    }

    fn open_topic(&mut self, profile: &Profile) -> Result<&mut (Profile, MemoryMappedQueue)> {
        let key = Self::topic_key(profile)?;
        if !self.topics.contains_key(&key) {
            let opts = QueueOptions {
                dir: self.topic_dir(&key),
                segment_bytes: self.base.segment_bytes,
                max_segments: self.base.max_segments,
                sync_every: self.base.sync_every,
            };
            let queue = MemoryMappedQueue::open(opts)?;
            self.topics.insert(key.clone(), (profile.clone(), queue));
        }
        Ok(self.topics.get_mut(&key).unwrap())
    }

    /// Publish a message under a simple (concrete) profile. Returns the
    /// assigned sequence number within the topic.
    pub fn publish(&mut self, profile: &Profile, payload: &[u8]) -> Result<u64> {
        let (_, queue) = self.open_topic(profile)?;
        let seq = queue.append(payload)?;
        self.metrics.counter("broker.published").inc();
        self.metrics.counter("broker.published_bytes").add(payload.len() as u64);
        Ok(seq)
    }

    /// Register (or replace) a subscription; the profile may be complex —
    /// it is matched associatively against topic profiles.
    pub fn subscribe(&mut self, consumer: &str, profile: Profile) {
        self.subscriptions.insert(
            consumer.to_string(),
            SubscriptionState { consumer: consumer.to_string(), profile, cursors: BTreeMap::new() },
        );
    }

    /// Remove a subscription.
    pub fn unsubscribe(&mut self, consumer: &str) {
        self.subscriptions.remove(consumer);
    }

    /// Fetch up to `max` pending messages for a consumer across all
    /// matching topics, advancing its cursors (at-least-once delivery:
    /// cursors only advance past what this call returns).
    pub fn fetch(&mut self, consumer: &str, max: usize) -> Result<Vec<(String, Vec<u8>)>> {
        let sub = self
            .subscriptions
            .get_mut(consumer)
            .ok_or_else(|| Error::NotFound(format!("no subscription for `{consumer}`")))?;
        let mut out = Vec::new();
        for (key, (topic_profile, queue)) in self.topics.iter() {
            if out.len() >= max {
                break;
            }
            if !matching::matches(&sub.profile, topic_profile) {
                continue;
            }
            let cursor = sub.cursors.get(key).copied().unwrap_or(0);
            let (next, msgs) = queue.poll(cursor, max - out.len());
            for m in msgs {
                out.push((key.clone(), m));
            }
            sub.cursors.insert(key.clone(), next);
        }
        self.metrics.counter("broker.delivered").add(out.len() as u64);
        Ok(out)
    }

    /// Current lag (pending message count) for a consumer.
    pub fn lag(&self, consumer: &str) -> Result<u64> {
        let sub = self
            .subscriptions
            .get(consumer)
            .ok_or_else(|| Error::NotFound(format!("no subscription for `{consumer}`")))?;
        let mut lag = 0u64;
        for (key, (topic_profile, queue)) in self.topics.iter() {
            if matching::matches(&sub.profile, topic_profile) {
                let cursor = sub.cursors.get(key).copied().unwrap_or(0).max(queue.tail_seq());
                lag += queue.head_seq() - cursor;
            }
        }
        Ok(lag)
    }

    /// Topic count (tests/stats).
    pub fn topic_count(&self) -> usize {
        self.topics.len()
    }

    /// Flush all topic queues.
    pub fn flush(&self, sync: bool) -> Result<()> {
        for (_, queue) in self.topics.values() {
            queue.flush(sync)?;
        }
        Ok(())
    }

    /// Metrics registry handle.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }
}

impl std::fmt::Debug for Broker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Broker(topics={}, subs={})", self.topics.len(), self.subscriptions.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn broker(name: &str) -> Broker {
        let dir = std::env::temp_dir()
            .join("rpulsar-broker-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Broker::new(QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 })
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    #[test]
    fn publish_subscribe_fetch() {
        let mut b = broker("psf");
        b.subscribe("app", p("drone,li*"));
        b.publish(&p("drone,lidar"), b"img-1").unwrap();
        b.publish(&p("drone,lidar"), b"img-2").unwrap();
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].1, b"img-1");
        // Cursor advanced: nothing pending.
        assert!(b.fetch("app", 10).unwrap().is_empty());
    }

    #[test]
    fn pattern_subscription_spans_topics() {
        let mut b = broker("span");
        b.publish(&p("drone,lidar"), b"a").unwrap();
        b.publish(&p("drone,thermal"), b"b").unwrap();
        b.publish(&p("truck,gps"), b"c").unwrap();
        b.subscribe("app", p("drone,*"));
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 2, "only the two drone topics match");
        assert_eq!(b.topic_count(), 3);
    }

    #[test]
    fn complex_profile_cannot_publish() {
        let mut b = broker("complexpub");
        assert!(b.publish(&p("drone,li*"), b"x").is_err());
    }

    #[test]
    fn lag_tracks_pending() {
        let mut b = broker("lag");
        b.subscribe("app", p("drone,lidar"));
        assert_eq!(b.lag("app").unwrap(), 0);
        b.publish(&p("drone,lidar"), b"1").unwrap();
        b.publish(&p("drone,lidar"), b"2").unwrap();
        assert_eq!(b.lag("app").unwrap(), 2);
        b.fetch("app", 1).unwrap();
        assert_eq!(b.lag("app").unwrap(), 1);
    }

    #[test]
    fn unsubscribed_fetch_errors() {
        let mut b = broker("nosub");
        assert!(b.fetch("ghost", 1).is_err());
        assert!(b.lag("ghost").is_err());
    }

    #[test]
    fn unsubscribe_removes() {
        let mut b = broker("unsub");
        b.subscribe("app", p("a"));
        b.unsubscribe("app");
        assert!(b.fetch("app", 1).is_err());
    }

    #[test]
    fn delivery_survives_new_publications_between_fetches() {
        let mut b = broker("interleave");
        b.subscribe("app", p("s,t"));
        b.publish(&p("s,t"), b"1").unwrap();
        let first = b.fetch("app", 10).unwrap();
        assert_eq!(first.len(), 1);
        b.publish(&p("s,t"), b"2").unwrap();
        b.publish(&p("s,t"), b"3").unwrap();
        let second = b.fetch("app", 10).unwrap();
        assert_eq!(second.len(), 2);
        assert_eq!(second[0].1, b"2");
    }

    #[test]
    fn metrics_count_published_and_delivered() {
        let mut b = broker("metrics");
        b.subscribe("app", p("x"));
        b.publish(&p("x"), b"abc").unwrap();
        b.fetch("app", 10).unwrap();
        assert_eq!(b.metrics().counter("broker.published").get(), 1);
        assert_eq!(b.metrics().counter("broker.published_bytes").get(), 3);
        assert_eq!(b.metrics().counter("broker.delivered").get(), 1);
    }
}
