//! Profile-keyed pub/sub broker over memory-mapped queues (paper §IV-C1).
//!
//! Topics are keyed by the canonical rendering of a *simple* profile
//! (pattern-profiles subscribe to many topics via associative matching).
//! The broker offers the paper's claim: "the same guarantees as Mosquitto
//! or Kafka (persistence, durability, and delivery guarantees)" — every
//! message is framed+CRC'd in an mmap segment before acknowledgement, and
//! consumers resume from their last acknowledged offset.
//!
//! **Match cache.** Subscription↔topic matching is resolved *once*, at
//! the edges where the relation can change — [`Broker::subscribe`] runs
//! one forward index query over the topic profiles, and opening a new
//! topic runs one reverse index query over the subscription profiles to
//! extend the affected caches (see [`crate::ar::index`]). `fetch` and
//! [`Broker::lag`] walk the cached topic list and never re-run
//! [`matching::matches`]; `broker.match_calls` counts the broker's
//! matcher invocations so tests and `fig4_messaging` can prove it.
//!
//! **Fairness.** `fetch` drains the cached topics round-robin — the
//! start topic rotates per call — so a small `max` no longer starves
//! every topic after the lexicographically first one.
//!
//! **Topic retirement.** Edge brokers live long and topics churn
//! (short-lived sensors, per-mission streams). [`Broker::retire_topic`]
//! drops a topic's queue and on-disk segments, tombstones its entry in
//! the topic index, and purges it from every subscription's match cache
//! together with the now-stale cursors; the index re-packs once
//! tombstones dominate, bounding broker memory to O(live topics).
//!
//! Payloads are delivered as shared `Arc<[u8]>` slices (one copy out of
//! the mmap, pointer clones beyond that).

use super::queue::{MemoryMappedQueue, QueueOptions};
use crate::ar::index::ProfileIndex;
use crate::ar::matching;
use crate::ar::profile::Profile;
use crate::error::{Error, Result};
use crate::metrics::Registry;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Age/idle-driven topic retirement: the *policy* deciding when to
/// invoke the [`Broker::retire_topic`] mechanism. The broker keeps
/// per-topic watermarks (creation, last publish, last fetch);
/// [`Broker::retire_idle`] sweeps them through the pure
/// [`RetirePolicy::decide`] and retires every topic it condemns —
/// edge brokers live long and topics churn (short-lived sensors,
/// per-mission streams), so without this the topic set only grows.
#[derive(Debug, Clone)]
pub struct RetirePolicy {
    /// Retire only topics with no publish for at least this long.
    pub max_publish_idle: Duration,
    /// ...and no fetch touching them for at least this long (both idle
    /// conditions must hold — a drained-but-actively-polled topic is
    /// kept, as is a quiet topic a consumer still reads).
    pub max_fetch_idle: Duration,
    /// Grace period: never retire a topic younger than this, however
    /// idle (a freshly created topic has had no chance to be used).
    pub min_age: Duration,
}

impl Default for RetirePolicy {
    fn default() -> Self {
        RetirePolicy {
            max_publish_idle: Duration::from_secs(600),
            max_fetch_idle: Duration::from_secs(600),
            min_age: Duration::from_secs(60),
        }
    }
}

impl RetirePolicy {
    /// The pure retirement decision for one topic given its watermark
    /// distances: `true` condemns the topic.
    pub fn decide(&self, age: Duration, publish_idle: Duration, fetch_idle: Duration) -> bool {
        age >= self.min_age
            && publish_idle >= self.max_publish_idle
            && fetch_idle >= self.max_fetch_idle
    }
}

/// Per-topic activity watermarks feeding [`RetirePolicy::decide`].
#[derive(Debug, Clone, Copy)]
struct TopicWatermarks {
    created: Instant,
    last_publish: Instant,
    last_fetch: Instant,
}

impl TopicWatermarks {
    fn new(now: Instant) -> Self {
        TopicWatermarks { created: now, last_publish: now, last_fetch: now }
    }
}

/// A consumer's registered interest.
#[derive(Debug, Clone)]
pub struct SubscriptionState {
    pub consumer: String,
    pub profile: Profile,
    /// Per-topic resume cursor.
    cursors: BTreeMap<String, u64>,
    /// Cached keys of matching topics (sorted; incrementally maintained).
    matched: Vec<String>,
    /// Round-robin rotation: index into `matched` where the next fetch
    /// starts draining.
    rr: usize,
    /// This subscription's pid in the broker's subscription index.
    pid: u32,
}

impl SubscriptionState {
    /// Cached matching topic keys (sorted). Test/stats surface.
    pub fn matched_topics(&self) -> &[String] {
        &self.matched
    }
}

/// The broker: one mmap queue per topic, plus subscription state and the
/// incremental subscription↔topic match cache.
pub struct Broker {
    base: QueueOptions,
    topics: BTreeMap<String, (Profile, MemoryMappedQueue)>,
    /// Topic pid → topic key, aligned with `topic_index` (`None` =
    /// retired pid; compacted once tombstones dominate).
    topic_keys: Vec<Option<String>>,
    topic_index: ProfileIndex,
    subscriptions: BTreeMap<String, SubscriptionState>,
    /// Subscription pid → consumer name (`None` = retired pid).
    sub_pids: Vec<Option<String>>,
    sub_index: ProfileIndex,
    /// Topic key → activity watermarks (retirement policy input).
    watermarks: BTreeMap<String, TopicWatermarks>,
    metrics: Registry,
}

impl Broker {
    /// Create a broker rooted at `base.dir` (one subdirectory per topic).
    pub fn new(base: QueueOptions) -> Self {
        Self::with_metrics(base, Registry::new())
    }

    /// Broker with shared metrics registry.
    pub fn with_metrics(base: QueueOptions, metrics: Registry) -> Self {
        Broker {
            base,
            topics: BTreeMap::new(),
            topic_keys: Vec::new(),
            topic_index: ProfileIndex::new(),
            subscriptions: BTreeMap::new(),
            sub_pids: Vec::new(),
            sub_index: ProfileIndex::new(),
            watermarks: BTreeMap::new(),
            metrics,
        }
    }

    fn topic_key(profile: &Profile) -> Result<String> {
        if !profile.is_simple() {
            return Err(Error::Profile(format!(
                "publish requires a simple profile, got `{}`",
                profile.render()
            )));
        }
        Ok(profile.render())
    }

    fn topic_dir(&self, key: &str) -> PathBuf {
        // Sanitise the profile rendering into a directory name.
        let safe: String = key
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
            .collect();
        self.base.dir.join(safe)
    }

    /// Matcher invocation, counted so the fetch path can be proven
    /// rematch-free (`broker.match_calls` + global [`matching::match_calls`]).
    fn matches_counted(&self, query: &Profile, stored: &Profile) -> bool {
        self.metrics.counter("broker.match_calls").inc();
        matching::matches(query, stored)
    }

    /// Open (creating if needed) the topic under its precomputed key —
    /// `key` must be `Self::topic_key(profile)` (the caller already
    /// rendered it; rendering is the publish hot path's allocation).
    fn open_topic(
        &mut self,
        key: &str,
        profile: &Profile,
    ) -> Result<&mut (Profile, MemoryMappedQueue)> {
        if !self.topics.contains_key(key) {
            let key = key.to_string();
            let opts = QueueOptions {
                dir: self.topic_dir(&key),
                segment_bytes: self.base.segment_bytes,
                max_segments: self.base.max_segments,
                sync_every: self.base.sync_every,
            };
            let queue = MemoryMappedQueue::open(opts)?;
            self.topics.insert(key.clone(), (profile.clone(), queue));
            self.watermarks.insert(key.clone(), TopicWatermarks::new(Instant::now()));
            // Index the new topic and incrementally extend the match
            // cache of every subscription the new topic matches: one
            // reverse query, not a scan over all subscriptions.
            let pid = self.topic_keys.len() as u32;
            self.topic_keys.push(Some(key.clone()));
            self.topic_index.insert(pid, profile);
            let counter = self.metrics.counter("broker.match_calls");
            for spid in self.sub_index.reverse_candidates(profile) {
                let Some(name) = &self.sub_pids[spid as usize] else { continue };
                let sub = self.subscriptions.get_mut(name).expect("pid map in sync");
                counter.inc();
                if matching::matches(&sub.profile, profile) {
                    if let Err(pos) = sub.matched.binary_search(&key) {
                        sub.matched.insert(pos, key.clone());
                    }
                }
            }
        }
        Ok(self.topics.get_mut(key).unwrap())
    }

    /// Publish a message under a simple (concrete) profile. Returns the
    /// assigned sequence number within the topic.
    pub fn publish(&mut self, profile: &Profile, payload: &[u8]) -> Result<u64> {
        let key = Self::topic_key(profile)?;
        let (_, queue) = self.open_topic(&key, profile)?;
        let seq = queue.append(payload)?;
        if let Some(w) = self.watermarks.get_mut(&key) {
            w.last_publish = Instant::now();
        }
        self.metrics.counter("broker.published").inc();
        self.metrics.counter("broker.published_bytes").add(payload.len() as u64);
        Ok(seq)
    }

    /// Register (or replace) a subscription; the profile may be complex —
    /// it is matched associatively against topic profiles (one index
    /// query here; `fetch`/`lag` then use the cached result).
    ///
    /// Replacing an existing subscription preserves the cursors of every
    /// topic the new profile still matches — re-subscribing with the same
    /// or a widened profile does not rewind delivery. Cursors of topics
    /// the new profile no longer matches are dropped (re-matching such a
    /// topic later redelivers from the start of retention).
    pub fn subscribe(&mut self, consumer: &str, profile: Profile) {
        let mut matched: Vec<String> = self
            .topic_index
            .forward_candidates(&profile)
            .into_iter()
            .filter_map(|pid| self.topic_keys[pid as usize].as_deref())
            .filter(|key| {
                let (topic_profile, _) = &self.topics[*key];
                self.matches_counted(&profile, topic_profile)
            })
            .map(str::to_string)
            .collect();
        matched.sort();

        let mut cursors = BTreeMap::new();
        if let Some(old) = self.subscriptions.get(consumer) {
            cursors = old
                .cursors
                .iter()
                .filter(|(key, _)| matched.binary_search(key).is_ok())
                .map(|(key, &cur)| (key.clone(), cur))
                .collect();
            // Retire the old subscription's index entry.
            self.sub_index.remove(old.pid);
            self.sub_pids[old.pid as usize] = None;
        }

        let pid = self.sub_pids.len() as u32;
        self.sub_pids.push(Some(consumer.to_string()));
        self.sub_index.insert(pid, &profile);
        self.subscriptions.insert(
            consumer.to_string(),
            SubscriptionState {
                consumer: consumer.to_string(),
                profile,
                cursors,
                matched,
                rr: 0,
                pid,
            },
        );
        self.maybe_compact_sub_index();
    }

    /// Remove a subscription.
    pub fn unsubscribe(&mut self, consumer: &str) {
        if let Some(sub) = self.subscriptions.remove(consumer) {
            self.sub_index.remove(sub.pid);
            self.sub_pids[sub.pid as usize] = None;
        }
    }

    /// Retire a topic: drop its queue and on-disk segments, tombstone
    /// its entry in the topic index, and purge it from every
    /// subscription's match cache (stale cursors are dropped with it —
    /// a later topic under the same profile is a fresh topic and
    /// redelivers from the start of retention). Runs zero matcher
    /// calls. Returns `false` when no such topic exists; errors only
    /// on a non-simple profile (topics are keyed by simple profiles).
    pub fn retire_topic(&mut self, profile: &Profile) -> Result<bool> {
        let key = Self::topic_key(profile)?;
        if self.topics.remove(&key).is_none() {
            return Ok(false);
        }
        self.watermarks.remove(&key);
        // Tombstone the index entry; the postings go stale and are
        // filtered at query time until the next compaction. (The pid
        // scan is a Vec walk, bounded at O(2·live) by compaction.)
        if let Some(pid) =
            self.topic_keys.iter().position(|k| k.as_deref() == Some(key.as_str()))
        {
            self.topic_index.remove(pid as u32);
            self.topic_keys[pid] = None;
        }
        for sub in self.subscriptions.values_mut() {
            if let Ok(pos) = sub.matched.binary_search(&key) {
                sub.matched.remove(pos);
            }
            sub.cursors.remove(&key);
        }
        // The queue handle dropped with the map entry; reclaim disk.
        let _ = std::fs::remove_dir_all(self.topic_dir(&key));
        self.metrics.counter("broker.topics_retired").inc();
        self.maybe_compact_topic_index();
        Ok(true)
    }

    /// Sweep every topic through `policy` and retire the condemned
    /// ones via [`Broker::retire_topic`] (queue + segments dropped,
    /// caches purged — see there). Returns the retired topic keys.
    /// Call periodically (an edge node's housekeeping tick); runs zero
    /// matcher calls.
    pub fn retire_idle(&mut self, policy: &RetirePolicy) -> Result<Vec<String>> {
        let now = Instant::now();
        let doomed: Vec<(String, Profile)> = self
            .topics
            .iter()
            .filter(|(key, _)| {
                self.watermarks.get(*key).is_some_and(|w| {
                    policy.decide(
                        now.duration_since(w.created),
                        now.duration_since(w.last_publish),
                        now.duration_since(w.last_fetch),
                    )
                })
            })
            .map(|(key, (profile, _))| (key.clone(), profile.clone()))
            .collect();
        for (_, profile) in &doomed {
            self.retire_topic(profile)?;
        }
        Ok(doomed.into_iter().map(|(key, _)| key).collect())
    }

    /// Re-pack the topic index once retired pids dominate (topic
    /// churn), bounding index memory to O(live topics).
    fn maybe_compact_topic_index(&mut self) {
        if self.topic_keys.len() < 32 || self.topic_keys.len() < self.topics.len() * 2 {
            return;
        }
        self.topic_keys.clear();
        self.topic_index = ProfileIndex::new();
        for (key, (profile, _)) in self.topics.iter() {
            let pid = self.topic_keys.len() as u32;
            self.topic_keys.push(Some(key.clone()));
            self.topic_index.insert(pid, profile);
        }
    }

    /// Re-pack the subscription index once retired pids dominate
    /// (subscribe replaces retire one pid each), bounding it to O(live).
    fn maybe_compact_sub_index(&mut self) {
        if self.sub_pids.len() < 32 || self.sub_pids.len() < self.subscriptions.len() * 2 {
            return;
        }
        self.sub_pids.clear();
        self.sub_index = ProfileIndex::new();
        for (name, sub) in self.subscriptions.iter_mut() {
            let pid = self.sub_pids.len() as u32;
            self.sub_pids.push(Some(name.clone()));
            self.sub_index.insert(pid, &sub.profile);
            sub.pid = pid;
        }
    }

    /// Fetch up to `max` pending messages for a consumer across all
    /// matching topics, advancing its cursors (at-least-once delivery:
    /// cursors only advance past what this call returns).
    ///
    /// Topics come from the subscription's match cache — no profile
    /// matching runs here — and are drained round-robin: the start topic
    /// rotates every call, so a small `max` cannot permanently starve
    /// the topics after the first.
    pub fn fetch(&mut self, consumer: &str, max: usize) -> Result<Vec<(String, Arc<[u8]>)>> {
        let sub = self
            .subscriptions
            .get_mut(consumer)
            .ok_or_else(|| Error::NotFound(format!("no subscription for `{consumer}`")))?;
        // Disjoint field borrows: topic keys stay borrowed while the
        // cursors advance, so idle topics cost no allocation.
        let SubscriptionState { matched, cursors, rr, .. } = &mut *sub;
        let mut out = Vec::new();
        let topics = matched.len();
        if topics == 0 {
            return Ok(out);
        }
        let start = *rr % topics;
        *rr = (*rr + 1) % topics;
        let now = Instant::now();
        for i in 0..topics {
            if out.len() >= max {
                break;
            }
            let key = &matched[(start + i) % topics];
            let (_, queue) = &self.topics[key];
            if let Some(w) = self.watermarks.get_mut(key) {
                w.last_fetch = now;
            }
            let cursor = cursors.get(key).copied().unwrap_or(0);
            let (next, msgs) = queue.poll_shared(cursor, max - out.len());
            for m in msgs {
                out.push((key.clone(), m));
            }
            if let Some(c) = cursors.get_mut(key) {
                *c = next;
            } else if next > 0 {
                // A zero cursor is the `unwrap_or(0)` default: no entry
                // needed until the topic actually advances.
                cursors.insert(key.clone(), next);
            }
        }
        self.metrics.counter("broker.delivered").add(out.len() as u64);
        Ok(out)
    }

    /// Current lag (pending message count) for a consumer. Walks the
    /// cached matching topics; no profile matching runs here.
    pub fn lag(&self, consumer: &str) -> Result<u64> {
        let sub = self
            .subscriptions
            .get(consumer)
            .ok_or_else(|| Error::NotFound(format!("no subscription for `{consumer}`")))?;
        let mut lag = 0u64;
        for key in &sub.matched {
            let (_, queue) = &self.topics[key];
            let cursor = sub.cursors.get(key).copied().unwrap_or(0).max(queue.tail_seq());
            lag += queue.head_seq() - cursor;
        }
        Ok(lag)
    }

    /// Topic count (tests/stats).
    pub fn topic_count(&self) -> usize {
        self.topics.len()
    }

    /// Subscription state for a consumer (tests/stats).
    pub fn subscription(&self, consumer: &str) -> Option<&SubscriptionState> {
        self.subscriptions.get(consumer)
    }

    /// How many times this broker invoked the profile matcher.
    pub fn match_calls(&self) -> u64 {
        self.metrics.counter("broker.match_calls").get()
    }

    /// Flush all topic queues.
    pub fn flush(&self, sync: bool) -> Result<()> {
        for (_, queue) in self.topics.values() {
            queue.flush(sync)?;
        }
        Ok(())
    }

    /// Metrics registry handle.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }
}

impl std::fmt::Debug for Broker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Broker(topics={}, subs={})", self.topics.len(), self.subscriptions.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn broker(name: &str) -> Broker {
        let dir = std::env::temp_dir()
            .join("rpulsar-broker-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Broker::new(QueueOptions { dir, segment_bytes: 1 << 16, max_segments: 4, sync_every: 0 })
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    #[test]
    fn publish_subscribe_fetch() {
        let mut b = broker("psf");
        b.subscribe("app", p("drone,li*"));
        b.publish(&p("drone,lidar"), b"img-1").unwrap();
        b.publish(&p("drone,lidar"), b"img-2").unwrap();
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 2);
        assert_eq!(&msgs[0].1[..], b"img-1");
        // Cursor advanced: nothing pending.
        assert!(b.fetch("app", 10).unwrap().is_empty());
    }

    #[test]
    fn pattern_subscription_spans_topics() {
        let mut b = broker("span");
        b.publish(&p("drone,lidar"), b"a").unwrap();
        b.publish(&p("drone,thermal"), b"b").unwrap();
        b.publish(&p("truck,gps"), b"c").unwrap();
        b.subscribe("app", p("drone,*"));
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 2, "only the two drone topics match");
        assert_eq!(b.topic_count(), 3);
    }

    #[test]
    fn complex_profile_cannot_publish() {
        let mut b = broker("complexpub");
        assert!(b.publish(&p("drone,li*"), b"x").is_err());
    }

    #[test]
    fn lag_tracks_pending() {
        let mut b = broker("lag");
        b.subscribe("app", p("drone,lidar"));
        assert_eq!(b.lag("app").unwrap(), 0);
        b.publish(&p("drone,lidar"), b"1").unwrap();
        b.publish(&p("drone,lidar"), b"2").unwrap();
        assert_eq!(b.lag("app").unwrap(), 2);
        b.fetch("app", 1).unwrap();
        assert_eq!(b.lag("app").unwrap(), 1);
    }

    #[test]
    fn unsubscribed_fetch_errors() {
        let mut b = broker("nosub");
        assert!(b.fetch("ghost", 1).is_err());
        assert!(b.lag("ghost").is_err());
    }

    #[test]
    fn unsubscribe_removes() {
        let mut b = broker("unsub");
        b.subscribe("app", p("a"));
        b.unsubscribe("app");
        assert!(b.fetch("app", 1).is_err());
    }

    #[test]
    fn delivery_survives_new_publications_between_fetches() {
        let mut b = broker("interleave");
        b.subscribe("app", p("s,t"));
        b.publish(&p("s,t"), b"1").unwrap();
        let first = b.fetch("app", 10).unwrap();
        assert_eq!(first.len(), 1);
        b.publish(&p("s,t"), b"2").unwrap();
        b.publish(&p("s,t"), b"3").unwrap();
        let second = b.fetch("app", 10).unwrap();
        assert_eq!(second.len(), 2);
        assert_eq!(&second[0].1[..], b"2");
    }

    #[test]
    fn metrics_count_published_and_delivered() {
        let mut b = broker("metrics");
        b.subscribe("app", p("x"));
        b.publish(&p("x"), b"abc").unwrap();
        b.fetch("app", 10).unwrap();
        assert_eq!(b.metrics().counter("broker.published").get(), 1);
        assert_eq!(b.metrics().counter("broker.published_bytes").get(), 3);
        assert_eq!(b.metrics().counter("broker.delivered").get(), 1);
    }

    #[test]
    fn fetch_and_lag_never_rematch() {
        let mut b = broker("nomatch");
        for i in 0..8 {
            b.publish(&p(&format!("topic{i},x")), b"m").unwrap();
        }
        b.subscribe("app", p("topic*,x"));
        let after_subscribe = b.match_calls();
        for _ in 0..50 {
            b.fetch("app", 3).unwrap();
            b.lag("app").unwrap();
        }
        assert_eq!(
            b.match_calls(),
            after_subscribe,
            "fetch/lag must use the match cache, not re-run matching"
        );
    }

    #[test]
    fn new_topic_extends_existing_subscription_caches() {
        let mut b = broker("extend");
        b.subscribe("app", p("drone,*"));
        assert!(b.subscription("app").unwrap().matched_topics().is_empty());
        b.publish(&p("drone,lidar"), b"1").unwrap();
        assert_eq!(b.subscription("app").unwrap().matched_topics(), ["drone,lidar"]);
        b.publish(&p("truck,gps"), b"2").unwrap();
        assert_eq!(b.subscription("app").unwrap().matched_topics().len(), 1);
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 1);
        assert_eq!(&msgs[0].1[..], b"1");
    }

    #[test]
    fn fetch_round_robins_start_topic() {
        // Two matching topics, max=1 per fetch: the old fixed-order drain
        // starved the lexicographically later topic forever.
        let mut b = broker("rr");
        b.publish(&p("a,x"), b"from-a-1").unwrap();
        b.publish(&p("a,x"), b"from-a-2").unwrap();
        b.publish(&p("b,x"), b"from-b-1").unwrap();
        b.publish(&p("b,x"), b"from-b-2").unwrap();
        b.subscribe("app", p("*,x"));
        let first = b.fetch("app", 1).unwrap();
        let second = b.fetch("app", 1).unwrap();
        assert_eq!(first.len(), 1);
        assert_eq!(second.len(), 1);
        assert_ne!(first[0].0, second[0].0, "start topic must rotate between fetches");
        // Four more single-message fetches drain everything.
        let mut total = first.len() + second.len();
        for _ in 0..4 {
            total += b.fetch("app", 1).unwrap().len();
        }
        assert_eq!(total, 4);
        assert!(b.fetch("app", 1).unwrap().is_empty());
    }

    #[test]
    fn resubscribe_same_profile_preserves_cursors() {
        let mut b = broker("resub-keep");
        b.subscribe("app", p("s,t"));
        b.publish(&p("s,t"), b"1").unwrap();
        assert_eq!(b.fetch("app", 10).unwrap().len(), 1);
        // Replacing with a still-matching profile keeps the cursor: no
        // redelivery of message "1".
        b.subscribe("app", p("s,*"));
        assert!(b.fetch("app", 10).unwrap().is_empty());
        b.publish(&p("s,t"), b"2").unwrap();
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 1);
        assert_eq!(&msgs[0].1[..], b"2");
    }

    #[test]
    fn resubscribe_away_and_back_redelivers() {
        // Documented replace semantics: a cursor is dropped when the new
        // profile stops matching its topic, so matching again later
        // redelivers from the start of retention (at-least-once).
        let mut b = broker("resub-drop");
        b.subscribe("app", p("s,t"));
        b.publish(&p("s,t"), b"1").unwrap();
        assert_eq!(b.fetch("app", 10).unwrap().len(), 1);
        b.subscribe("app", p("other"));
        assert!(b.fetch("app", 10).unwrap().is_empty());
        b.subscribe("app", p("s,t"));
        let msgs = b.fetch("app", 10).unwrap();
        assert_eq!(msgs.len(), 1, "cursor was dropped → message 1 redelivered");
        assert_eq!(&msgs[0].1[..], b"1");
    }

    #[test]
    fn retire_topic_purges_caches_cursors_and_disk() {
        let mut b = broker("retire");
        b.publish(&p("a,x"), b"a1").unwrap();
        b.publish(&p("a,x"), b"a2").unwrap();
        b.publish(&p("b,x"), b"b1").unwrap();
        b.subscribe("app", p("*,x"));
        // Consume a1 so a cursor exists for the doomed topic.
        while b
            .fetch("app", 1)
            .unwrap()
            .first()
            .map(|(topic, _)| topic != "a,x")
            .unwrap_or(true)
        {}
        let calls_before = b.match_calls();
        let dir = b.topic_dir("a,x");
        assert!(dir.exists(), "topic segments should be on disk");
        assert!(b.retire_topic(&p("a,x")).unwrap());
        assert_eq!(b.match_calls(), calls_before, "retirement must not re-run matching");
        assert!(!dir.exists(), "retirement must reclaim the segments");
        assert_eq!(b.topic_count(), 1);
        assert_eq!(b.subscription("app").unwrap().matched_topics(), ["b,x"]);
        // Only b's backlog remains; the retired topic is gone from fetch.
        let rest = b.fetch("app", 10).unwrap();
        assert!(rest.iter().all(|(topic, _)| topic == "b,x"), "{rest:?}");
        // Double retirement reports "no such topic"; complex profiles error.
        assert!(!b.retire_topic(&p("a,x")).unwrap());
        assert!(b.retire_topic(&p("a,*")).is_err());
        // Re-publishing under the same profile creates a *fresh* topic:
        // the old cursor was dropped, so delivery restarts at seq 0.
        b.publish(&p("a,x"), b"a3").unwrap();
        assert_eq!(b.subscription("app").unwrap().matched_topics(), ["a,x", "b,x"]);
        let again = b.fetch("app", 10).unwrap();
        assert_eq!(again.len(), 1);
        assert_eq!(&again[0].1[..], b"a3");
    }

    #[test]
    fn retire_policy_decisions_are_age_and_idle_driven() {
        let s = Duration::from_secs;
        let p = RetirePolicy { max_publish_idle: s(10), max_fetch_idle: s(20), min_age: s(5) };
        // Old enough and idle on both watermarks → retire.
        assert!(p.decide(s(60), s(10), s(20)), "thresholds are inclusive");
        assert!(p.decide(s(60), s(300), s(300)));
        // Any live signal keeps the topic.
        assert!(!p.decide(s(60), s(9), s(300)), "recent publish keeps it");
        assert!(!p.decide(s(60), s(300), s(19)), "recent fetch keeps it");
        // Grace period: young topics are never retired.
        assert!(!p.decide(s(4), s(300), s(300)));
        // Zero thresholds condemn everything immediately.
        let zero = RetirePolicy {
            max_publish_idle: Duration::ZERO,
            max_fetch_idle: Duration::ZERO,
            min_age: Duration::ZERO,
        };
        assert!(zero.decide(Duration::ZERO, Duration::ZERO, Duration::ZERO));
    }

    #[test]
    fn retire_idle_sweeps_through_the_mechanism() {
        let mut b = broker("retire-idle");
        b.publish(&p("a,x"), b"1").unwrap();
        b.publish(&p("b,x"), b"2").unwrap();
        b.subscribe("app", p("*,x"));
        // Generous thresholds: nothing condemned.
        let lazy = RetirePolicy::default();
        assert!(b.retire_idle(&lazy).unwrap().is_empty());
        assert_eq!(b.topic_count(), 2);
        // Zero thresholds: every topic is idle by definition; the
        // mechanism runs (caches purged, disk reclaimed, counted).
        let eager = RetirePolicy {
            max_publish_idle: Duration::ZERO,
            max_fetch_idle: Duration::ZERO,
            min_age: Duration::ZERO,
        };
        let retired = b.retire_idle(&eager).unwrap();
        assert_eq!(retired, ["a,x", "b,x"]);
        assert_eq!(b.topic_count(), 0);
        assert!(b.subscription("app").unwrap().matched_topics().is_empty());
        assert_eq!(b.metrics().counter("broker.topics_retired").get(), 2);
        assert!(b.fetch("app", 10).unwrap().is_empty());
        // The broker keeps working: a re-published topic is fresh.
        b.publish(&p("a,x"), b"again").unwrap();
        assert_eq!(b.fetch("app", 10).unwrap().len(), 1);
    }

    #[test]
    fn publish_and_fetch_refresh_watermarks() {
        let mut b = broker("watermark");
        b.publish(&p("s,t"), b"1").unwrap();
        let w0 = b.watermarks["s,t"];
        std::thread::sleep(Duration::from_millis(2));
        b.publish(&p("s,t"), b"2").unwrap();
        let w1 = b.watermarks["s,t"];
        assert!(w1.last_publish > w0.last_publish, "publish must advance the watermark");
        assert_eq!(w1.created, w0.created, "creation time is immutable");
        b.subscribe("app", p("s,*"));
        std::thread::sleep(Duration::from_millis(2));
        b.fetch("app", 10).unwrap();
        let w2 = b.watermarks["s,t"];
        assert!(w2.last_fetch > w1.last_fetch, "fetch must advance the watermark");
    }

    #[test]
    fn topic_index_compacts_under_churn() {
        let mut b = broker("topic-churn");
        b.subscribe("app", p("keep,*"));
        b.publish(&p("keep,alive"), b"k").unwrap();
        for i in 0..200 {
            let profile = p(&format!("burst{i},x"));
            b.publish(&profile, b"m").unwrap();
            assert!(b.retire_topic(&profile).unwrap());
        }
        assert!(
            b.topic_keys.len() <= 33,
            "retired pids must be compacted: {}",
            b.topic_keys.len()
        );
        // The surviving topic still matches and delivers.
        assert_eq!(b.subscription("app").unwrap().matched_topics(), ["keep,alive"]);
        assert_eq!(b.fetch("app", 10).unwrap().len(), 1);
        b.publish(&p("keep,alive"), b"k2").unwrap();
        assert_eq!(b.lag("app").unwrap(), 1);
    }

    #[test]
    fn retire_fixes_round_robin_rotation() {
        // Retiring a topic shrinks `matched`; the rotating fetch start
        // must stay in bounds and keep draining the survivors.
        let mut b = broker("retire-rr");
        for t in ["a,x", "b,x", "c,x"] {
            b.publish(&p(t), b"1").unwrap();
            b.publish(&p(t), b"2").unwrap();
        }
        b.subscribe("app", p("*,x"));
        b.fetch("app", 1).unwrap(); // advance rr past 0
        assert!(b.retire_topic(&p("c,x")).unwrap());
        let mut got = 0;
        for _ in 0..10 {
            got += b.fetch("app", 1).unwrap().len();
        }
        // 6 published, 2 retired with their topic, 1 consumed before.
        assert_eq!(got, 3);
    }

    #[test]
    fn subscription_index_compacts_under_churn() {
        let mut b = broker("churn");
        b.publish(&p("s,t"), b"1").unwrap();
        for _ in 0..100 {
            b.subscribe("app", p("s,*"));
        }
        b.subscribe("other", p("s,t"));
        assert!(b.sub_pids.len() <= 33, "retired pids must be compacted: {}", b.sub_pids.len());
        assert_eq!(b.fetch("app", 10).unwrap().len(), 1);
        assert_eq!(b.fetch("other", 10).unwrap().len(), 1);
    }
}
