//! The memory-mapped data collection layer (paper §IV-C1).
//!
//! "To tackle these issues we designed and implemented a custom messaging
//! hub specially designed for edge devices using a memory-mapped queue."
//!
//! A [`mmap::MmapRegion`] wraps `libc::mmap` over a backing file: writes
//! go to page cache at memory speed and the operating system persists
//! them even if the process crashes. Records are framed with a CRC
//! ([`segment`]); the multi-segment [`queue`] adds rotation, consumer
//! offsets and crash recovery; [`pubsub`] layers profile-keyed topics
//! with the same persistence/durability/delivery guarantees as Kafka or
//! Mosquitto — minus their per-message disk I/O.

pub mod mmap;
pub mod pubsub;
pub mod queue;
pub mod segment;

pub use pubsub::Broker;
pub use queue::{MemoryMappedQueue, QueueOptions};
pub use segment::Segment;
