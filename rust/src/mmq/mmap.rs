//! Safe wrapper over `libc::mmap` for file-backed shared mappings.
//!
//! (No `memmap2` crate offline; this is the minimal safe surface the
//! queue needs: create/open, grow-to-size, slice access, `msync`.)

use crate::error::{Error, Result};
use std::fs::{File, OpenOptions};
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::ptr::NonNull;

/// A file-backed, read-write memory mapping.
pub struct MmapRegion {
    ptr: NonNull<u8>,
    len: usize,
    _file: File,
}

// The mapping is owned and the backing file is kept alive for the
// region's lifetime; aliasing is controlled by &/&mut access.
unsafe impl Send for MmapRegion {}

impl MmapRegion {
    /// Create (or open) `path`, ensure it is exactly `len` bytes, and map
    /// it read-write shared.
    pub fn create(path: &Path, len: usize) -> Result<Self> {
        if len == 0 {
            return Err(Error::Queue("mmap: zero-length mapping".into()));
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        file.set_len(len as u64)?;
        Self::map(file, len)
    }

    /// Open an existing file and map its current size.
    pub fn open(path: &Path) -> Result<Self> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            return Err(Error::Queue(format!("mmap: {path:?} is empty")));
        }
        Self::map(file, len)
    }

    fn map(file: File, len: usize) -> Result<Self> {
        // SAFETY: fd is valid and owned; length checked non-zero.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        Ok(MmapRegion {
            ptr: NonNull::new(ptr as *mut u8)
                .ok_or_else(|| Error::Queue("mmap returned null".into()))?,
            len,
            _file: file,
        })
    }

    /// Mapping length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Immutable view of the mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: ptr/len valid for the mapping's lifetime.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }

    /// Mutable view of the mapped bytes.
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        // SAFETY: exclusive borrow of self guarantees unique access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }

    /// Flush dirty pages to the backing file. `async_flush` uses
    /// `MS_ASYNC` (schedule, don't wait) — the queue's default because
    /// the OS already guarantees write-back on crash of the *process*.
    pub fn flush(&self, async_flush: bool) -> Result<()> {
        let flags = if async_flush { libc::MS_ASYNC } else { libc::MS_SYNC };
        // SAFETY: ptr/len describe a live mapping.
        let rc = unsafe { libc::msync(self.ptr.as_ptr() as *mut libc::c_void, self.len, flags) };
        if rc != 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        Ok(())
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        // SAFETY: ptr/len describe a live mapping created by mmap.
        unsafe {
            libc::munmap(self.ptr.as_ptr() as *mut libc::c_void, self.len);
        }
    }
}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MmapRegion(len={})", self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("rpulsar-mmap-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn create_write_read() {
        let path = tmp("cwr");
        let mut m = MmapRegion::create(&path, 4096).unwrap();
        m.as_mut_slice()[0..5].copy_from_slice(b"hello");
        assert_eq!(&m.as_slice()[0..5], b"hello");
        assert_eq!(m.len(), 4096);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn data_survives_remap() {
        // The core persistence claim: bytes written through the mapping
        // are visible after unmapping and re-opening ("the operating
        // system takes care of reading and writing to disk in the event
        // of the program crashing").
        let path = tmp("remap");
        {
            let mut m = MmapRegion::create(&path, 8192).unwrap();
            m.as_mut_slice()[100..107].copy_from_slice(b"durable");
            m.flush(false).unwrap();
        } // munmap
        let m = MmapRegion::open(&path).unwrap();
        assert_eq!(&m.as_slice()[100..107], b"durable");
        assert_eq!(m.len(), 8192);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn zero_length_rejected() {
        assert!(MmapRegion::create(&tmp("zero"), 0).is_err());
    }

    #[test]
    fn open_missing_file_errors() {
        assert!(MmapRegion::open(Path::new("/nonexistent/rpulsar-xyz")).is_err());
    }

    #[test]
    fn flush_modes_succeed() {
        let path = tmp("flush");
        let mut m = MmapRegion::create(&path, 4096).unwrap();
        m.as_mut_slice()[0] = 42;
        m.flush(true).unwrap();
        m.flush(false).unwrap();
        std::fs::remove_file(&path).unwrap();
    }
}
