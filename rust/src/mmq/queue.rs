//! Multi-segment memory-mapped queue with consumer offsets, rotation and
//! crash recovery (paper §IV-C1).
//!
//! Messages get monotonically increasing sequence numbers. Segments
//! rotate when full; when `max_segments` is exceeded the oldest segment
//! is retired (message retention, like Kafka's log retention). Consumers
//! track their own positions; [`MemoryMappedQueue::poll`] returns the
//! next batch after a given sequence number.

use super::segment::Segment;
use crate::config::QueueConfig;
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};

/// Queue tuning knobs (subset of [`QueueConfig`] used directly).
#[derive(Debug, Clone)]
pub struct QueueOptions {
    pub dir: PathBuf,
    pub segment_bytes: usize,
    pub max_segments: usize,
    /// msync (async) every N appends; 0 = rely on OS write-back only.
    pub sync_every: usize,
}

impl From<&QueueConfig> for QueueOptions {
    fn from(c: &QueueConfig) -> Self {
        QueueOptions {
            dir: c.dir.clone(),
            segment_bytes: c.segment_bytes,
            max_segments: c.max_segments,
            sync_every: c.sync_every,
        }
    }
}

struct LiveSegment {
    segment: Segment,
    /// Sequence number of the first record in this segment.
    base_seq: u64,
    /// Byte offsets of records, indexed by (seq - base_seq).
    offsets: Vec<usize>,
    path: PathBuf,
}

/// The memory-mapped queue.
pub struct MemoryMappedQueue {
    opts: QueueOptions,
    segments: VecDeque<LiveSegment>,
    next_seq: u64,
    appends_since_sync: usize,
    next_segment_id: u64,
}

impl MemoryMappedQueue {
    /// Open (recovering any existing segments) or create a queue in
    /// `opts.dir`.
    pub fn open(opts: QueueOptions) -> Result<Self> {
        std::fs::create_dir_all(&opts.dir)?;
        let mut seg_paths: Vec<(u64, PathBuf)> = std::fs::read_dir(&opts.dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let id: u64 = name.strip_suffix(".seg")?.strip_prefix("segment-")?.parse().ok()?;
                Some((id, e.path()))
            })
            .collect();
        seg_paths.sort();

        let mut queue = MemoryMappedQueue {
            opts,
            segments: VecDeque::new(),
            next_seq: 0,
            appends_since_sync: 0,
            next_segment_id: 0,
        };

        for (id, path) in seg_paths {
            let segment = Segment::open(&path)?;
            let mut offsets = Vec::new();
            let mut off = super::segment::HEADER_SIZE;
            while off < segment.write_pos() {
                offsets.push(off);
                match segment.next_offset(off) {
                    Some(n) => off = n,
                    None => break,
                }
            }
            let base_seq = queue.next_seq;
            queue.next_seq += offsets.len() as u64;
            queue.next_segment_id = queue.next_segment_id.max(id + 1);
            queue.segments.push_back(LiveSegment { segment, base_seq, offsets, path });
        }
        if queue.segments.is_empty() {
            queue.rotate()?;
        }
        Ok(queue)
    }

    /// Open with default options rooted at `dir` (convenience).
    pub fn open_dir(dir: &Path) -> Result<Self> {
        Self::open(QueueOptions {
            dir: dir.to_path_buf(),
            segment_bytes: 8 << 20,
            max_segments: 8,
            sync_every: 0,
        })
    }

    fn rotate(&mut self) -> Result<()> {
        let id = self.next_segment_id;
        self.next_segment_id += 1;
        let path = self.opts.dir.join(format!("segment-{id:010}.seg"));
        let segment = Segment::create(&path, self.opts.segment_bytes)?;
        self.segments.push_back(LiveSegment {
            segment,
            base_seq: self.next_seq,
            offsets: Vec::new(),
            path,
        });
        // Retention: drop the oldest segment beyond the cap.
        while self.segments.len() > self.opts.max_segments {
            if let Some(old) = self.segments.pop_front() {
                drop(old.segment);
                let _ = std::fs::remove_file(&old.path);
            }
        }
        Ok(())
    }

    /// Append a message; returns its sequence number.
    pub fn append(&mut self, payload: &[u8]) -> Result<u64> {
        if payload.len() + super::segment::RECORD_OVERHEAD + super::segment::HEADER_SIZE
            > self.opts.segment_bytes
        {
            return Err(Error::Queue(format!(
                "message of {} bytes exceeds segment size {}",
                payload.len(),
                self.opts.segment_bytes
            )));
        }
        let needs_rotation =
            !self.segments.back().map(|s| s.segment.fits(payload.len())).unwrap_or(false);
        if needs_rotation {
            self.rotate()?;
        }
        let live = self.segments.back_mut().expect("rotate guarantees a live segment");
        let off = live.segment.append(payload)?;
        live.offsets.push(off);
        let seq = self.next_seq;
        self.next_seq += 1;
        if self.opts.sync_every > 0 {
            self.appends_since_sync += 1;
            if self.appends_since_sync >= self.opts.sync_every {
                live.segment.flush(false)?;
                self.appends_since_sync = 0;
            }
        }
        Ok(seq)
    }

    /// Sequence number of the next message to be appended.
    pub fn head_seq(&self) -> u64 {
        self.next_seq
    }

    /// Oldest sequence number still retained.
    pub fn tail_seq(&self) -> u64 {
        self.segments.front().map(|s| s.base_seq).unwrap_or(self.next_seq)
    }

    /// Number of retained messages.
    pub fn len(&self) -> u64 {
        self.next_seq - self.tail_seq()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read one message by sequence number.
    pub fn get(&self, seq: u64) -> Result<&[u8]> {
        let live = self
            .segments
            .iter()
            .find(|s| seq >= s.base_seq && seq < s.base_seq + s.offsets.len() as u64)
            .ok_or_else(|| Error::NotFound(format!("seq {seq} not retained")))?;
        live.segment.read(live.offsets[(seq - live.base_seq) as usize])
    }

    /// Poll up to `max` messages with sequence numbers ≥ `from`.
    /// Returns (next_cursor, messages).
    pub fn poll(&self, from: u64, max: usize) -> (u64, Vec<Vec<u8>>) {
        let start = from.max(self.tail_seq());
        let end = (start + max as u64).min(self.next_seq);
        let mut out = Vec::with_capacity((end - start) as usize);
        for seq in start..end {
            match self.get(seq) {
                Ok(bytes) => out.push(bytes.to_vec()),
                Err(_) => break,
            }
        }
        (start + out.len() as u64, out)
    }

    /// [`Self::poll`], but messages are copied out of the mmap once into
    /// shared `Arc<[u8]>` slices — fan-out to multiple consumers or
    /// reactions then clones pointers, not payload bytes.
    pub fn poll_shared(&self, from: u64, max: usize) -> (u64, Vec<std::sync::Arc<[u8]>>) {
        let start = from.max(self.tail_seq());
        let end = (start + max as u64).min(self.next_seq);
        let mut out = Vec::with_capacity((end - start) as usize);
        for seq in start..end {
            match self.get(seq) {
                Ok(bytes) => out.push(std::sync::Arc::from(bytes)),
                Err(_) => break,
            }
        }
        (start + out.len() as u64, out)
    }

    /// Flush all segments (used at shutdown/checkpoints).
    pub fn flush(&self, sync: bool) -> Result<()> {
        for s in &self.segments {
            s.segment.flush(sync)?;
        }
        Ok(())
    }

    /// Number of live segments (tests/metrics).
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }
}

impl std::fmt::Debug for MemoryMappedQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MemoryMappedQueue(len={}, segments={}, head={})",
            self.len(),
            self.segments.len(),
            self.next_seq
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(name: &str, segment_bytes: usize, max_segments: usize) -> QueueOptions {
        let dir = std::env::temp_dir()
            .join("rpulsar-queue-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        QueueOptions { dir, segment_bytes, max_segments, sync_every: 0 }
    }

    fn cleanup(o: &QueueOptions) {
        let _ = std::fs::remove_dir_all(&o.dir);
    }

    #[test]
    fn fifo_order_preserved() {
        let o = opts("fifo", 1 << 16, 4);
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        for i in 0..100u32 {
            let seq = q.append(format!("m{i}").as_bytes()).unwrap();
            assert_eq!(seq, i as u64);
        }
        let (cursor, msgs) = q.poll(0, 1000);
        assert_eq!(cursor, 100);
        assert_eq!(msgs.len(), 100);
        assert_eq!(msgs[0], b"m0");
        assert_eq!(msgs[99], b"m99");
        cleanup(&o);
    }

    #[test]
    fn poll_batches_and_cursors() {
        let o = opts("batch", 1 << 16, 4);
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        for i in 0..10u32 {
            q.append(format!("m{i}").as_bytes()).unwrap();
        }
        let (c1, b1) = q.poll(0, 4);
        assert_eq!((c1, b1.len()), (4, 4));
        let (c2, b2) = q.poll(c1, 4);
        assert_eq!((c2, b2.len()), (8, 4));
        let (c3, b3) = q.poll(c2, 4);
        assert_eq!((c3, b3.len()), (10, 2));
        let (c4, b4) = q.poll(c3, 4);
        assert_eq!((c4, b4.len()), (10, 0));
        cleanup(&o);
    }

    #[test]
    fn rotation_on_full_segment() {
        let o = opts("rotate", 4096, 10);
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        let payload = vec![42u8; 1000];
        for _ in 0..10 {
            q.append(&payload).unwrap();
        }
        assert!(q.segment_count() > 1, "should have rotated");
        // All messages still readable.
        let (_, msgs) = q.poll(0, 100);
        assert_eq!(msgs.len(), 10);
        cleanup(&o);
    }

    #[test]
    fn retention_drops_oldest() {
        let o = opts("retention", 4096, 2);
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        let payload = vec![7u8; 1000];
        for _ in 0..20 {
            q.append(&payload).unwrap();
        }
        assert!(q.segment_count() <= 2);
        assert!(q.tail_seq() > 0, "oldest messages retired");
        // Polling from 0 silently starts at the tail.
        let (cursor, msgs) = q.poll(0, 100);
        assert_eq!(cursor, q.head_seq());
        assert_eq!(msgs.len() as u64, q.len());
        cleanup(&o);
    }

    #[test]
    fn recovery_across_reopen() {
        let o = opts("reopen", 1 << 14, 4);
        {
            let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
            for i in 0..50u32 {
                q.append(format!("msg-{i}").as_bytes()).unwrap();
            }
            q.flush(true).unwrap();
        }
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        assert_eq!(q.head_seq(), 50);
        let (_, msgs) = q.poll(0, 100);
        assert_eq!(msgs.len(), 50);
        assert_eq!(msgs[49], b"msg-49");
        // Appending after recovery continues the sequence.
        assert_eq!(q.append(b"post-recovery").unwrap(), 50);
        cleanup(&o);
    }

    #[test]
    fn oversized_message_rejected() {
        let o = opts("oversize", 4096, 2);
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        assert!(q.append(&vec![0u8; 8192]).is_err());
        cleanup(&o);
    }

    #[test]
    fn get_missing_seq_errors() {
        let o = opts("missing", 4096, 2);
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        q.append(b"only").unwrap();
        assert!(q.get(0).is_ok());
        assert!(q.get(1).is_err());
        cleanup(&o);
    }

    #[test]
    fn sync_every_triggers_flush() {
        let mut o = opts("synce", 1 << 14, 2);
        o.sync_every = 3;
        let mut q = MemoryMappedQueue::open(o.clone()).unwrap();
        for i in 0..10u32 {
            q.append(format!("{i}").as_bytes()).unwrap();
        }
        cleanup(&o);
    }
}
