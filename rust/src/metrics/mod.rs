//! Lightweight metrics: counters, gauges, log-bucketed latency histograms,
//! throughput meters, and a registry used by the coordinator and benches.

pub mod histogram;

pub use histogram::Histogram;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge (set/add/sub).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Throughput meter: counts events/bytes against a wall-clock window.
#[derive(Debug)]
pub struct Meter {
    start: Instant,
    events: AtomicU64,
    bytes: AtomicU64,
}

impl Meter {
    pub fn new() -> Self {
        Meter { start: Instant::now(), events: AtomicU64::new(0), bytes: AtomicU64::new(0) }
    }

    #[inline]
    pub fn record(&self, bytes: u64) {
        self.events.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn events(&self) -> u64 {
        self.events.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Events per second since creation.
    pub fn events_per_sec(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.events() as f64 / secs
    }

    /// Bytes per second since creation.
    pub fn bytes_per_sec(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64().max(1e-9);
        self.bytes() as f64 / secs
    }
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

/// Named-metric registry; cheap to clone and share between threads.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter by name.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.inner.counters.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())).clone()
    }

    /// Get or create a gauge by name.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.inner.gauges.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())).clone()
    }

    /// Get or create a histogram by name (microsecond latencies by convention).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.inner.histograms.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())).clone()
    }

    /// Snapshot every gauge whose name starts with `prefix`, sorted by
    /// name (`""` snapshots all). The cluster policy plane samples
    /// queue-depth gauges across whole topologies through this without
    /// knowing the stage names up front.
    pub fn gauges_with_prefix(&self, prefix: &str) -> Vec<(String, i64)> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .range(prefix.to_string()..)
            .take_while(|(name, _)| name.starts_with(prefix))
            .map(|(name, g)| (name.clone(), g.get()))
            .collect()
    }

    /// Render a sorted text snapshot (one metric per line).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, h) in self.inner.histograms.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push_str(&format!(
                "histogram {name} count={} p50={} p99={} max={}\n",
                s.count, s.p50, s.p99, s.max
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_set_add() {
        let g = Gauge::new();
        g.set(10);
        g.add(-3);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn meter_counts_bytes_and_events() {
        let m = Meter::new();
        m.record(100);
        m.record(50);
        assert_eq!(m.events(), 2);
        assert_eq!(m.bytes(), 150);
        assert!(m.events_per_sec() > 0.0);
    }

    #[test]
    fn registry_deduplicates_by_name() {
        let r = Registry::new();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
    }

    #[test]
    fn registry_render_mentions_all() {
        let r = Registry::new();
        r.counter("msgs").add(3);
        r.gauge("depth").set(7);
        r.histogram("lat").record(100);
        let text = r.render();
        assert!(text.contains("counter msgs 3"));
        assert!(text.contains("gauge depth 7"));
        assert!(text.contains("histogram lat"));
    }

    #[test]
    fn gauges_with_prefix_snapshots_matching_sorted() {
        let r = Registry::new();
        r.gauge("stream.a.s1.in.depth").set(4);
        r.gauge("stream.a.s2.r0.depth").set(9);
        r.gauge("stream.b.s1.in.depth").set(1);
        r.gauge("net.in_flight").set(2);
        assert_eq!(
            r.gauges_with_prefix("stream.a."),
            vec![
                ("stream.a.s1.in.depth".to_string(), 4),
                ("stream.a.s2.r0.depth".to_string(), 9),
            ]
        );
        assert_eq!(r.gauges_with_prefix("stream.b.").len(), 1);
        assert_eq!(r.gauges_with_prefix("").len(), 4);
        assert!(r.gauges_with_prefix("missing.").is_empty());
    }

    #[test]
    fn registry_shared_across_threads() {
        let r = Registry::new();
        let c = r.counter("x");
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("x").get(), 4000);
    }
}
