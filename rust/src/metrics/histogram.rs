//! Log-bucketed histogram for latency recording (HdrHistogram-lite).
//!
//! Values are bucketed as (exponent, 16 linear sub-buckets), giving a
//! relative error bound of ~6% per bucket — plenty for bench reporting.
//! Lock-free recording via atomics; snapshots are consistent-enough reads.

use std::sync::atomic::{AtomicU64, Ordering};

const SUB_BUCKETS: usize = 16;
const EXPONENTS: usize = 64;
const NUM_BUCKETS: usize = EXPONENTS * SUB_BUCKETS;

/// Concurrent log-bucketed histogram of u64 values (typically µs or ns).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
}

/// Point-in-time summary of a [`Histogram`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Snapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p90: u64,
    pub p95: u64,
    pub p99: u64,
    pub p999: u64,
}

impl Histogram {
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(NUM_BUCKETS);
        buckets.resize_with(NUM_BUCKETS, || AtomicU64::new(0));
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    fn bucket_index(v: u64) -> usize {
        if v < SUB_BUCKETS as u64 {
            return v as usize;
        }
        let exp = 63 - v.leading_zeros() as usize; // exp >= 4
        let sub = ((v >> (exp - 4)) & 0xF) as usize; // top 4 bits below the MSB
        ((exp - 3) * SUB_BUCKETS + sub).min(NUM_BUCKETS - 1)
    }

    /// Representative (lower-bound) value for a bucket index.
    fn bucket_value(idx: usize) -> u64 {
        if idx < SUB_BUCKETS {
            return idx as u64;
        }
        let exp = idx / SUB_BUCKETS + 3;
        let sub = (idx % SUB_BUCKETS) as u64;
        (1u64 << exp) | (sub << (exp - 4))
    }

    /// Record one value.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
    }

    /// Record a duration in microseconds.
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Total number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Compute a summary snapshot.
    pub fn snapshot(&self) -> Snapshot {
        let count = self.count.load(Ordering::Relaxed);
        let sum = self.sum.load(Ordering::Relaxed);
        if count == 0 {
            return Snapshot {
                count: 0,
                sum: 0,
                min: 0,
                max: 0,
                mean: 0.0,
                p50: 0,
                p90: 0,
                p95: 0,
                p99: 0,
                p999: 0,
            };
        }
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        let pct = |q: f64| -> u64 {
            let target = (q * total as f64).ceil() as u64;
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                cum += c;
                if cum >= target {
                    return Self::bucket_value(i);
                }
            }
            Self::bucket_value(NUM_BUCKETS - 1)
        };
        Snapshot {
            count,
            sum,
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            mean: sum as f64 / count as f64,
            p50: pct(0.50),
            p90: pct(0.90),
            p95: pct(0.95),
            p99: pct(0.99),
            p999: pct(0.999),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "Histogram(count={}, p50={}, p99={}, max={})", s.count, s.p50, s.p99, s.max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0);
    }

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.snapshot().min, 0);
        assert_eq!(h.snapshot().max, 15);
        assert_eq!(h.snapshot().count, 16);
    }

    #[test]
    fn bucket_round_trip_error_bounded() {
        for v in [1u64, 16, 100, 1_000, 123_456, 9_999_999, u32::MAX as u64] {
            let idx = Histogram::bucket_index(v);
            let rep = Histogram::bucket_value(idx);
            let err = (v as f64 - rep as f64).abs() / v as f64;
            assert!(err <= 0.07, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn percentiles_are_ordered() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i);
        }
        let s = h.snapshot();
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.p999);
        // p50 of uniform 1..=10k should be around 5000 (±7%).
        assert!((s.p50 as f64 - 5_000.0).abs() / 5_000.0 < 0.1, "p50={}", s.p50);
    }

    #[test]
    fn mean_matches_sum() {
        let h = Histogram::new();
        h.record(10);
        h.record(20);
        h.record(30);
        let s = h.snapshot();
        assert_eq!(s.sum, 60);
        assert!((s.mean - 20.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(i + t);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }
}
