//! Configuration system: a minimal TOML-subset parser ([`toml`]) and the
//! typed node/cluster configuration schema ([`schema`]).
//!
//! No `serde`/`toml` crates are available offline; the parser supports the
//! subset used by R-Pulsar configs: `[section]` and `[section.sub]` tables,
//! string / integer / float / boolean scalars, and flat arrays of scalars.

pub mod schema;
pub mod toml;

pub use schema::{
    ClusterConfig, DeviceKind, NodeConfig, QueueConfig, RuntimeConfig, StorageConfig,
};
pub use toml::{TomlDoc, TomlValue};
