//! Typed configuration schema for nodes and clusters, loaded from the
//! TOML-subset documents parsed by [`super::toml`].

use super::toml::TomlDoc;
use crate::error::{Error, Result};
use std::path::PathBuf;

/// Which device-emulation profile a node runs under (paper §V test beds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// Raspberry Pi 3 (paper's primary edge device).
    RaspberryPi,
    /// Motorola Moto G5 Plus (paper's Android device).
    Android,
    /// Chameleon cloud m1.small-class VM.
    CloudSmall,
    /// No throttling — raw host performance.
    Native,
}

impl DeviceKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pi" | "raspberry-pi" | "raspberrypi" => Ok(DeviceKind::RaspberryPi),
            "android" | "phone" => Ok(DeviceKind::Android),
            "cloud" | "cloud-small" | "vm" => Ok(DeviceKind::CloudSmall),
            "native" | "none" => Ok(DeviceKind::Native),
            other => Err(Error::Config(format!("unknown device kind `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::RaspberryPi => "raspberry-pi",
            DeviceKind::Android => "android",
            DeviceKind::CloudSmall => "cloud-small",
            DeviceKind::Native => "native",
        }
    }
}

/// Memory-mapped queue configuration.
#[derive(Debug, Clone)]
pub struct QueueConfig {
    /// Directory for queue segment files.
    pub dir: PathBuf,
    /// Size of each mmap segment in bytes.
    pub segment_bytes: usize,
    /// Maximum retained segments before oldest is recycled.
    pub max_segments: usize,
    /// msync to disk every N appends (0 = only on rotation/close).
    pub sync_every: usize,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            dir: PathBuf::from("/tmp/rpulsar/queue"),
            segment_bytes: 8 << 20,
            max_segments: 8,
            sync_every: 0,
        }
    }
}

/// LSM storage configuration.
#[derive(Debug, Clone)]
pub struct StorageConfig {
    /// Directory for sstable files.
    pub dir: PathBuf,
    /// Memtable flush threshold in bytes.
    pub memtable_bytes: usize,
    /// Number of DHT replicas per record within a region.
    pub replicas: usize,
    /// Bloom filter bits per key.
    pub bloom_bits_per_key: usize,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig {
            dir: PathBuf::from("/tmp/rpulsar/store"),
            memtable_bytes: 4 << 20,
            replicas: 2,
            bloom_bits_per_key: 10,
        }
    }
}

/// PJRT runtime configuration (artifact locations).
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Directory holding `*.hlo.txt` artifacts produced by `make artifacts`.
    pub artifacts_dir: PathBuf,
    /// Load and compile artifacts eagerly at node start.
    pub preload: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { artifacts_dir: PathBuf::from("artifacts"), preload: false }
    }
}

/// Per-node configuration (paper: one Rendezvous Point).
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// Human-readable node name; also seeds the 160-bit node id.
    pub name: String,
    /// Latitude/longitude of the RP (drives quadtree placement).
    pub latitude: f64,
    pub longitude: f64,
    /// Device emulation profile.
    pub device: DeviceKind,
    /// Minimum RPs per quadtree region before a split is allowed
    /// (the paper's replication invariant, §IV-A).
    pub region_min_rps: usize,
    /// Kademlia-style bucket size for the XOR ring.
    pub bucket_size: usize,
    /// Keep-alive period in milliseconds.
    pub keepalive_ms: u64,
    /// Keep-alive misses before a peer is declared failed.
    pub keepalive_misses: u32,
    pub queue: QueueConfig,
    pub storage: StorageConfig,
    pub runtime: RuntimeConfig,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig {
            name: "rp-0".into(),
            latitude: 40.5,
            longitude: -74.45,
            device: DeviceKind::Native,
            region_min_rps: 2,
            bucket_size: 8,
            keepalive_ms: 500,
            keepalive_misses: 3,
            queue: QueueConfig::default(),
            storage: StorageConfig::default(),
            runtime: RuntimeConfig::default(),
        }
    }
}

impl NodeConfig {
    /// Build from a parsed TOML document; missing keys use defaults.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = NodeConfig::default();
        let device = match doc.get("node.device") {
            Some(v) => DeviceKind::parse(v.as_str().unwrap_or("native"))?,
            None => d.device,
        };
        Ok(NodeConfig {
            name: doc.str_or("node.name", &d.name),
            latitude: doc.float_or("node.latitude", d.latitude),
            longitude: doc.float_or("node.longitude", d.longitude),
            device,
            region_min_rps: doc.int_or("overlay.region_min_rps", d.region_min_rps as i64) as usize,
            bucket_size: doc.int_or("overlay.bucket_size", d.bucket_size as i64) as usize,
            keepalive_ms: doc.int_or("overlay.keepalive_ms", d.keepalive_ms as i64) as u64,
            keepalive_misses: doc.int_or("overlay.keepalive_misses", d.keepalive_misses as i64)
                as u32,
            queue: QueueConfig {
                dir: PathBuf::from(doc.str_or("queue.dir", d.queue.dir.to_str().unwrap())),
                segment_bytes: doc.int_or("queue.segment_bytes", d.queue.segment_bytes as i64)
                    as usize,
                max_segments: doc.int_or("queue.max_segments", d.queue.max_segments as i64)
                    as usize,
                sync_every: doc.int_or("queue.sync_every", d.queue.sync_every as i64) as usize,
            },
            storage: StorageConfig {
                dir: PathBuf::from(doc.str_or("storage.dir", d.storage.dir.to_str().unwrap())),
                memtable_bytes: doc.int_or("storage.memtable_bytes", d.storage.memtable_bytes as i64)
                    as usize,
                replicas: doc.int_or("storage.replicas", d.storage.replicas as i64) as usize,
                bloom_bits_per_key: doc
                    .int_or("storage.bloom_bits_per_key", d.storage.bloom_bits_per_key as i64)
                    as usize,
            },
            runtime: RuntimeConfig {
                artifacts_dir: PathBuf::from(
                    doc.str_or("runtime.artifacts_dir", d.runtime.artifacts_dir.to_str().unwrap()),
                ),
                preload: doc.bool_or("runtime.preload", d.runtime.preload),
            },
        })
    }

    /// Load from a config file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_doc(&TomlDoc::parse_file(path)?)
    }

    /// Validate invariants (used at node start and by property tests).
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(Error::Config("node name must be non-empty".into()));
        }
        if !(-90.0..=90.0).contains(&self.latitude) {
            return Err(Error::Config(format!("latitude {} out of range", self.latitude)));
        }
        if !(-180.0..=180.0).contains(&self.longitude) {
            return Err(Error::Config(format!("longitude {} out of range", self.longitude)));
        }
        if self.region_min_rps == 0 {
            return Err(Error::Config("region_min_rps must be >= 1".into()));
        }
        if self.bucket_size == 0 {
            return Err(Error::Config("bucket_size must be >= 1".into()));
        }
        if self.queue.segment_bytes < 4096 {
            return Err(Error::Config("queue.segment_bytes must be >= 4096".into()));
        }
        if self.storage.replicas == 0 {
            return Err(Error::Config("storage.replicas must be >= 1".into()));
        }
        Ok(())
    }
}

/// Cluster-level configuration for the in-process multi-node harness.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of nodes to launch.
    pub nodes: usize,
    /// Device profile applied to every node.
    pub device: DeviceKind,
    /// Simulated one-way network latency between nodes, microseconds.
    pub link_latency_us: u64,
    /// Simulated link bandwidth, bytes/second (0 = unlimited).
    pub link_bandwidth: u64,
    /// PRNG seed for placement and workloads.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 4,
            device: DeviceKind::Native,
            link_latency_us: 200,
            link_bandwidth: 0,
            seed: 42,
        }
    }
}

impl ClusterConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = ClusterConfig::default();
        let device = match doc.get("cluster.device") {
            Some(v) => DeviceKind::parse(v.as_str().unwrap_or("native"))?,
            None => d.device,
        };
        Ok(ClusterConfig {
            nodes: doc.int_or("cluster.nodes", d.nodes as i64) as usize,
            device,
            link_latency_us: doc.int_or("cluster.link_latency_us", d.link_latency_us as i64) as u64,
            link_bandwidth: doc.int_or("cluster.link_bandwidth", d.link_bandwidth as i64) as u64,
            seed: doc.int_or("cluster.seed", d.seed as i64) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        NodeConfig::default().validate().unwrap();
    }

    #[test]
    fn from_doc_overrides_and_defaults() {
        let doc = TomlDoc::parse(
            r#"
[node]
name = "edge-7"
latitude = 40.0583
longitude = -74.4056
device = "pi"

[overlay]
region_min_rps = 3

[queue]
segment_bytes = 65536
"#,
        )
        .unwrap();
        let cfg = NodeConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.name, "edge-7");
        assert_eq!(cfg.device, DeviceKind::RaspberryPi);
        assert_eq!(cfg.region_min_rps, 3);
        assert_eq!(cfg.queue.segment_bytes, 65536);
        // untouched default
        assert_eq!(cfg.bucket_size, 8);
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = NodeConfig::default();
        cfg.latitude = 123.0;
        assert!(cfg.validate().is_err());
        let mut cfg = NodeConfig::default();
        cfg.queue.segment_bytes = 16;
        assert!(cfg.validate().is_err());
        let mut cfg = NodeConfig::default();
        cfg.storage.replicas = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn device_kind_parsing() {
        assert_eq!(DeviceKind::parse("pi").unwrap(), DeviceKind::RaspberryPi);
        assert_eq!(DeviceKind::parse("Android").unwrap(), DeviceKind::Android);
        assert_eq!(DeviceKind::parse("cloud").unwrap(), DeviceKind::CloudSmall);
        assert_eq!(DeviceKind::parse("native").unwrap(), DeviceKind::Native);
        assert!(DeviceKind::parse("gpu").is_err());
    }

    #[test]
    fn cluster_config_from_doc() {
        let doc = TomlDoc::parse("[cluster]\nnodes = 16\nlink_latency_us = 500").unwrap();
        let cfg = ClusterConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.nodes, 16);
        assert_eq!(cfg.link_latency_us, 500);
        assert_eq!(cfg.seed, 42);
    }
}
