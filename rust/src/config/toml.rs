//! Minimal TOML-subset parser.
//!
//! Supported: comments (`#`), `[table]` / `[table.sub]` headers, and
//! `key = value` with string (`"..."`), integer, float, boolean and flat
//! array (`[v, v, ...]`) values. Keys are flattened to dotted paths
//! (`table.sub.key`). This covers every config file the repo ships.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path → value.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated table header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty table name"));
                }
                prefix = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            let value_text = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let full_key = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            let value = parse_value(value_text).map_err(|m| err(lineno, &m))?;
            entries.insert(full_key, value);
        }
        Ok(TomlDoc { entries })
    }

    /// Parse a file.
    pub fn parse_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Look up a value by dotted path.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    /// Typed getters with defaults.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// All keys under a dotted prefix (e.g. every `peers.*`).
    pub fn keys_with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.entries
            .keys()
            .filter(move |k| k.starts_with(prefix))
            .map(|k| k.as_str())
    }

    /// Number of entries (for tests).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Parse(format!("toml line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_array(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match t {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = t.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("unrecognised value `{t}`"))
}

/// Split a flat array body on commas outside strings.
fn split_array(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&body[start..]);
    parts
}

fn unescape(s: &str) -> std::result::Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape `\\{other:?}`")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# node config
name = "edge-1"        # inline comment
port = 7100
ratio = 0.5
debug = true

[overlay]
region_capacity = 4
bootstrap = ["10.0.0.1:7100", "10.0.0.2:7100"]

[overlay.quadtree]
max_depth = 8
"#;

    #[test]
    fn parses_scalars() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.str_or("name", ""), "edge-1");
        assert_eq!(doc.int_or("port", 0), 7100);
        assert!((doc.float_or("ratio", 0.0) - 0.5).abs() < 1e-12);
        assert!(doc.bool_or("debug", false));
    }

    #[test]
    fn parses_tables_and_nested() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.int_or("overlay.region_capacity", 0), 4);
        assert_eq!(doc.int_or("overlay.quadtree.max_depth", 0), 8);
    }

    #[test]
    fn parses_arrays() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        let arr = doc.get("overlay.bootstrap").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_str().unwrap(), "10.0.0.1:7100");
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.int_or("missing", 9), 9);
        assert!(doc.is_empty());
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.str_or("tag", ""), "a#b");
    }

    #[test]
    fn escapes_in_strings() {
        let doc = TomlDoc::parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(doc.str_or("s", ""), "a\nb\"c");
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.int_or("big", 0), 1_000_000);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("x = ").unwrap_err();
        assert!(format!("{e}").contains("line 1"));
        let e = TomlDoc::parse("ok = 1\n[broken").unwrap_err();
        assert!(format!("{e}").contains("line 2"));
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0").unwrap();
        assert!(matches!(doc.get("a").unwrap(), TomlValue::Int(3)));
        assert!(matches!(doc.get("b").unwrap(), TomlValue::Float(_)));
        // as_float accepts both
        assert_eq!(doc.get("a").unwrap().as_float(), Some(3.0));
    }
}
