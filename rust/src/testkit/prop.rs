//! Property runner with bounded shrinking.

use crate::util::prng::Prng;

/// A value generator: a function from PRNG to value. Implemented for all
/// `Fn(&mut Prng) -> T`, so closures compose naturally.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Prng) -> T;
}

impl<T, F: Fn(&mut Prng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Prng) -> T {
        self(rng)
    }
}

/// How shrink candidates for a failing input are produced.
pub trait Shrink: Sized {
    /// Candidate "smaller" values, in decreasing preference order.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let v = *self;
        if v == 0 {
            return vec![];
        }
        // Geometric approach toward zero, then -1: lets the runner bisect
        // to a boundary counterexample in O(log v) rounds.
        let mut out = vec![0u64];
        let mut delta = v / 2;
        while delta > 0 {
            out.push(v - delta);
            delta /= 2;
        }
        out.dedup();
        out.retain(|&c| c != v);
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|v| v as usize).collect()
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        vec![0, self / 2, self - self.signum()]
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        vec![0.0, self / 2.0]
    }
}

impl Shrink for String {
    fn shrink(&self) -> Vec<Self> {
        if self.is_empty() {
            return vec![];
        }
        let half: String = self.chars().take(self.chars().count() / 2).collect();
        let minus_one: String = self.chars().take(self.chars().count() - 1).collect();
        vec![String::new(), half, minus_one]
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(Vec::new());
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // remove each single element (bounded)
        for i in 0..self.len().min(16) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        // shrink each element in place once (bounded)
        for i in 0..self.len().min(16) {
            if let Some(shrunk) = self[i].shrink().into_iter().next() {
                let mut v = self.clone();
                v[i] = shrunk;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

/// Wrapper for generated values that are not worth shrinking (composite
/// fixtures, geometry objects). `forall` accepts it wherever a `Shrink`
/// bound is required; counterexamples are reported unshrunk.
#[derive(Clone, Debug)]
pub struct NoShrink<T>(pub T);

impl<T: Clone> Shrink for NoShrink<T> {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Outcome of a property run (useful when asserting on failure text).
#[derive(Debug)]
pub enum PropResult<T> {
    Ok,
    Failed { input: T, cases_run: usize },
}

const DEFAULT_CASES: usize = 256;
const MAX_SHRINK_STEPS: usize = 512;

/// Run `prop` on `cases` generated inputs; on failure, shrink and panic
/// with the minimal counterexample. Seed is fixed for reproducibility.
pub fn forall<T, G, P>(gen: G, prop: P)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: Gen<T>,
    P: Fn(&T) -> bool,
{
    forall_seeded(0xDEC0DE, DEFAULT_CASES, gen, prop)
}

/// [`forall`] with explicit seed and case count.
pub fn forall_seeded<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: Gen<T>,
    P: Fn(&T) -> bool,
{
    if let PropResult::Failed { input, cases_run } = check(seed, cases, &gen, &prop) {
        panic!(
            "property failed after {cases_run} cases; minimal counterexample: {input:?} (seed={seed})"
        );
    }
}

/// Non-panicking property check; returns the shrunk counterexample.
pub fn check<T, G, P>(seed: u64, cases: usize, gen: &G, prop: &P) -> PropResult<T>
where
    T: Clone + std::fmt::Debug + Shrink,
    G: Gen<T>,
    P: Fn(&T) -> bool,
{
    let mut rng = Prng::seeded(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_to_minimal(input, prop);
            return PropResult::Failed { input: minimal, cases_run: case + 1 };
        }
    }
    PropResult::Ok
}

fn shrink_to_minimal<T, P>(mut failing: T, prop: &P) -> T
where
    T: Clone + Shrink,
    P: Fn(&T) -> bool,
{
    let mut steps = 0;
    'outer: while steps < MAX_SHRINK_STEPS {
        for candidate in failing.shrink() {
            steps += 1;
            if !prop(&candidate) {
                failing = candidate;
                continue 'outer;
            }
            if steps >= MAX_SHRINK_STEPS {
                break;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{u64_in, vec_of};

    #[test]
    fn passing_property_passes() {
        forall(u64_in(0, 1000), |&v| v <= 1000);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        // Property "v < 500" fails for v >= 500; minimal counterexample
        // reachable by our shrinker should be <= any originally found value.
        let result = check(1, 512, &u64_in(0, 1000), &|&v: &u64| v < 500);
        match result {
            PropResult::Failed { input, .. } => {
                assert!(input >= 500);
                assert!(input <= 510, "shrinking should approach 500, got {input}");
            }
            PropResult::Ok => panic!("property should have failed"),
        }
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        // "no vec contains a value > 90" — minimal failing vec should be short.
        let result = check(2, 512, &vec_of(u64_in(0, 100), 20), &|v: &Vec<u64>| {
            v.iter().all(|&x| x <= 90)
        });
        match result {
            PropResult::Failed { input, .. } => {
                assert!(input.iter().any(|&x| x > 90));
                assert!(input.len() <= 4, "expected short counterexample, got {input:?}");
            }
            PropResult::Ok => panic!("property should have failed"),
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_panics_with_counterexample() {
        forall(u64_in(0, 10), |&v| v < 10);
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4u64, 6u64);
        let shrunk = t.shrink();
        assert!(shrunk.iter().any(|&(a, _)| a < 4));
        assert!(shrunk.iter().any(|&(_, b)| b < 6));
    }
}
