//! Mini property-based testing framework (no `proptest` offline).
//!
//! Provides [`Gen`]-style value generators over the crate PRNG, a
//! [`forall`] runner with bounded shrinking for failures, and common
//! generators (ints, vecs, strings, keyword profiles). Used by unit tests
//! across coordinator modules and by `rust/tests/properties.rs`.

pub mod prop;

pub use prop::{forall, forall_seeded, Gen};

use crate::util::prng::Prng;

/// Generator for uniform `u64` in `[lo, hi]` (full range supported).
pub fn u64_in(lo: u64, hi: u64) -> impl Fn(&mut Prng) -> u64 {
    move |rng| {
        debug_assert!(lo <= hi);
        match hi.checked_sub(lo).and_then(|span| span.checked_add(1)) {
            Some(bound) => lo + rng.gen_range_u64(bound),
            None => rng.next_u64(), // whole u64 range
        }
    }
}

/// Generator for uniform `usize` in `[lo, hi)`.
pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Prng) -> usize {
    move |rng| rng.gen_range(lo, hi)
}

/// Generator for f64 in `[lo, hi)`.
pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Prng) -> f64 {
    move |rng| lo + rng.gen_f64() * (hi - lo)
}

/// Generator for a vec whose length is in `[0, max_len)` and whose items
/// come from `item`.
pub fn vec_of<T>(
    item: impl Fn(&mut Prng) -> T,
    max_len: usize,
) -> impl Fn(&mut Prng) -> Vec<T> {
    move |rng| {
        let len = rng.gen_range(0, max_len.max(1));
        (0..len).map(|_| item(rng)).collect()
    }
}

/// Generator for lowercase ASCII strings of length `[1, max_len]`.
pub fn keyword(max_len: usize) -> impl Fn(&mut Prng) -> String {
    move |rng| {
        let len = rng.gen_range(1, max_len.max(2));
        rng.ascii_lower(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Prng::seeded(1);
        let g = u64_in(5, 10);
        for _ in 0..1000 {
            let v = g(&mut rng);
            assert!((5..=10).contains(&v));
        }
        let g = f64_in(-1.0, 1.0);
        for _ in 0..1000 {
            let v = g(&mut rng);
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn vec_and_keyword_generators() {
        let mut rng = Prng::seeded(2);
        let g = vec_of(u64_in(0, 9), 8);
        for _ in 0..100 {
            let v = g(&mut rng);
            assert!(v.len() < 8);
            assert!(v.iter().all(|&x| x <= 9));
        }
        let k = keyword(6);
        for _ in 0..100 {
            let s = k(&mut rng);
            assert!(!s.is_empty() && s.len() <= 6);
            assert!(s.bytes().all(|b| b.is_ascii_lowercase()));
        }
    }
}
