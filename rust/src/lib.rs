//! # R-Pulsar — Edge Based Data-Driven Pipelines
//!
//! A reproduction of *"Edge Based Data-Driven Pipelines (Technical Report)"*
//! (Renart, Balouek-Thomert, Parashar — Rutgers, 2018): a lightweight,
//! memory-mapped, full-stack platform for real-time data analytics across
//! the cloud and the edge in a uniform manner.
//!
//! The system is organised as the paper's four layers:
//!
//! 1. **Location-aware self-organising overlay** ([`overlay`]) — a point
//!    quadtree of geographic regions, each region an XOR-metric P2P ring
//!    with 160-bit identifiers, master election and replication.
//! 2. **Content-based routing** ([`routing`]) — Hilbert space-filling-curve
//!    mapping from keyword *profiles* to overlay identifiers, supporting
//!    exact keywords, partial keywords, wildcards and ranges.
//! 3. **Memory-mapped data processing** ([`mmq`], [`storage`], [`stream`]) —
//!    an mmap-backed pub/sub queue for data collection, a stream-processing
//!    engine with on-demand topologies, and a DHT-backed memory-first store.
//! 4. **Programming abstraction** ([`ar`], [`rules`]) — the Associative
//!    Rendezvous (AR) model (post/push/pull, reactive actions) and an
//!    IF-THEN rule engine for data-driven pipelines.
//!
//! The compute hot-spot of the paper's disaster-recovery use case (LiDAR
//! image pre-processing and change detection) is authored as JAX + Pallas
//! kernels in `python/compile/`, AOT-lowered to HLO text, and executed on
//! the request path by the [`runtime`] module via the PJRT CPU client —
//! Python never runs at runtime.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every table/figure of the paper to a bench target.

pub mod ar;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod logging;
pub mod metrics;
pub mod mmq;
pub mod net;
pub mod overlay;
pub mod pipeline;
pub mod routing;
pub mod rules;
pub mod runtime;
pub mod storage;
pub mod stream;
pub mod testkit;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
