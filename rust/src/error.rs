//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for all R-Pulsar subsystems.
#[derive(Error, Debug)]
pub enum Error {
    /// I/O failure (mmap, segment files, sstables, sockets).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed or unparsable input (config, profiles, rules, wire frames).
    #[error("parse error: {0}")]
    Parse(String),

    /// Profile/keyspace violation (too many dimensions, empty profile, ...).
    #[error("profile error: {0}")]
    Profile(String),

    /// Overlay-level failure (no route, region not found, join failure).
    #[error("overlay error: {0}")]
    Overlay(String),

    /// Queue-level failure (segment full, corrupt record, bad offset).
    #[error("queue error: {0}")]
    Queue(String),

    /// Storage-level failure (corrupt sstable, missing key where required).
    #[error("storage error: {0}")]
    Storage(String),

    /// Stream-engine failure (unknown operator, topology cycle, shutdown).
    #[error("stream error: {0}")]
    Stream(String),

    /// Rule-engine failure (bad condition expression, unknown variable).
    #[error("rule error: {0}")]
    Rule(String),

    /// PJRT runtime failure (artifact missing, compile/execute error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Network/transport failure (peer unreachable, frame too large).
    #[error("net error: {0}")]
    Net(String),

    /// Configuration / CLI error.
    #[error("config error: {0}")]
    Config(String),

    /// The requested entity does not exist.
    #[error("not found: {0}")]
    NotFound(String),

    /// A topology/function that was expected to be running is not
    /// (never started, or already stopped).
    #[error("not running: {0}")]
    NotRunning(String),

    /// Operation timed out.
    #[error("timeout: {0}")]
    Timeout(String),

    /// Activation refused by trigger-plane admission control (the
    /// in-flight cap is reached). Structured, not a hang: the refused
    /// binding's broker cursor has not advanced, so retrying after
    /// capacity frees loses nothing.
    #[error("admission refused: {0}")]
    Admission(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Short machine-readable kind tag, used by metrics and wire errors.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::Io(_) => "io",
            Error::Parse(_) => "parse",
            Error::Profile(_) => "profile",
            Error::Overlay(_) => "overlay",
            Error::Queue(_) => "queue",
            Error::Storage(_) => "storage",
            Error::Stream(_) => "stream",
            Error::Rule(_) => "rule",
            Error::Runtime(_) => "runtime",
            Error::Net(_) => "net",
            Error::Config(_) => "config",
            Error::NotFound(_) => "not_found",
            Error::NotRunning(_) => "not_running",
            Error::Timeout(_) => "timeout",
            Error::Admission(_) => "admission",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tags_are_stable() {
        assert_eq!(Error::Parse("x".into()).kind(), "parse");
        assert_eq!(Error::NotFound("y".into()).kind(), "not_found");
        assert_eq!(Error::NotRunning("z".into()).kind(), "not_running");
        assert_eq!(Error::Admission("full".into()).kind(), "admission");
        let io = Error::from(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        assert_eq!(io.kind(), "io");
    }

    #[test]
    fn display_includes_message() {
        let e = Error::Queue("segment full".into());
        assert!(format!("{e}").contains("segment full"));
    }
}
