//! Minimal binary codec used for the wire format, queue records and
//! sstable blocks. Little-endian fixed-width integers, LEB128 varints,
//! and length-prefixed byte strings.

use crate::error::{Error, Result};

/// Append-only byte buffer writer.
#[derive(Debug, Default, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// New writer with reserved capacity.
    pub fn with_capacity(cap: usize) -> Self {
        ByteWriter { buf: Vec::with_capacity(cap) }
    }

    /// Recycle an existing buffer: its contents are cleared but its
    /// capacity is kept, so pooled wire buffers encode without
    /// re-allocating (`net::wire::BufferPool`).
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        ByteWriter { buf }
    }

    /// Finish and take the underlying buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Current length in bytes.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Borrow written bytes.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint.
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                break;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Raw bytes without a length prefix.
    pub fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Varint-length-prefixed byte string.
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_varint(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    /// Varint-length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }
}

/// Cursor-based reader over a byte slice.
#[derive(Debug, Clone)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// New reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current cursor position.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// True when the cursor has consumed every byte.
    pub fn is_exhausted(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Parse(format!(
                "codec: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let slice = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// LEB128 unsigned varint.
    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.get_u8()?;
            if shift >= 64 {
                return Err(Error::Parse("codec: varint overflow".into()));
            }
            v |= ((byte & 0x7F) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Raw bytes of a known length.
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Varint-length-prefixed byte string.
    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let len = self.get_varint()? as usize;
        self.take(len)
    }

    /// Varint-length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<&'a str> {
        std::str::from_utf8(self.get_bytes()?)
            .map_err(|e| Error::Parse(format!("codec: bad utf8: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_width_round_trip() {
        let mut w = ByteWriter::new();
        w.put_u8(0xAB);
        w.put_u16(0xBEEF);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_i64(-42);
        w.put_f32(1.5);
        w.put_f64(-2.25);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 0xAB);
        assert_eq!(r.get_u16().unwrap(), 0xBEEF);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert!(r.is_exhausted());
    }

    #[test]
    fn varint_round_trip_boundaries() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut w = ByteWriter::new();
            w.put_varint(v);
            let bytes = w.into_bytes();
            let mut r = ByteReader::new(&bytes);
            assert_eq!(r.get_varint().unwrap(), v, "v={v}");
            assert!(r.is_exhausted());
        }
    }

    #[test]
    fn varint_compactness() {
        let mut w = ByteWriter::new();
        w.put_varint(5);
        assert_eq!(w.len(), 1);
        let mut w = ByteWriter::new();
        w.put_varint(300);
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn strings_and_bytes() {
        let mut w = ByteWriter::new();
        w.put_str("héllo");
        w.put_bytes(&[1, 2, 3]);
        w.put_str("");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_str().unwrap(), "héllo");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.get_str().unwrap(), "");
    }

    #[test]
    fn from_vec_reuses_capacity() {
        let mut w = ByteWriter::with_capacity(256);
        w.put_u64(7);
        let buf = w.into_bytes();
        let cap = buf.capacity();
        let w = ByteWriter::from_vec(buf);
        assert!(w.is_empty(), "recycled writer must start empty");
        let buf = w.into_bytes();
        assert_eq!(buf.capacity(), cap, "recycling must keep the allocation");
    }

    #[test]
    fn truncated_input_errors() {
        let mut w = ByteWriter::new();
        w.put_u64(7);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn bad_utf8_errors() {
        let mut w = ByteWriter::new();
        w.put_bytes(&[0xFF, 0xFE]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_str().is_err());
    }
}
