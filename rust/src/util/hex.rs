//! Hex encode/decode for 160-bit ids and debug output.

/// Encode bytes to lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0xF) as usize] as char);
    }
    out
}

/// Decode a hex string (case-insensitive, even length) to bytes.
pub fn decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    fn nibble(c: u8) -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    }
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let data = vec![0x00, 0x01, 0xAB, 0xFF, 0x7E];
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn known_encoding() {
        assert_eq!(encode(&[0xDE, 0xAD, 0xBE, 0xEF]), "deadbeef");
    }

    #[test]
    fn decode_rejects_bad_input() {
        assert!(decode("abc").is_none()); // odd length
        assert!(decode("zz").is_none()); // non-hex
        assert_eq!(decode("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn decode_is_case_insensitive() {
        assert_eq!(decode("DEADbeef").unwrap(), vec![0xDE, 0xAD, 0xBE, 0xEF]);
    }
}
