//! Small shared utilities: deterministic PRNG, hex, binary codec, timing.

pub mod codec;
pub mod hex;
pub mod prng;
pub mod timeutil;

pub use codec::{ByteReader, ByteWriter};
pub use prng::Prng;
pub use timeutil::Stopwatch;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `n` up to the next multiple of `align` (power of two not required).
#[inline]
pub fn align_up(n: usize, align: usize) -> usize {
    ceil_div(n, align) * align
}

/// CRC32 (IEEE) over a byte slice — used for queue-record and sstable
/// integrity checks.
///
/// Slicing-by-8 (8 table lookups per 8 input bytes, no loop-carried
/// byte dependency): ~7× faster than the classic byte-at-a-time loop on
/// this host (see EXPERIMENTS.md §Perf), which matters because the mmq
/// hot path CRCs every record.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLES: once_cell::sync::Lazy<[[u32; 256]; 8]> = once_cell::sync::Lazy::new(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256usize {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i] = c;
        }
        for i in 0..256usize {
            let mut c = t[0][i];
            for k in 1..8 {
                c = t[0][(c & 0xFF) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    });
    let t = &*TABLES;
    let mut crc = 0xFFFF_FFFFu32;
    let mut chunks = data.chunks_exact(8);
    for chunk in &mut chunks {
        let lo = u32::from_le_bytes(chunk[0..4].try_into().unwrap()) ^ crc;
        let hi = u32::from_le_bytes(chunk[4..8].try_into().unwrap());
        crc = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][((lo >> 24) & 0xFF) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][((hi >> 24) & 0xFF) as usize];
    }
    for &b in chunks.remainder() {
        crc = t[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// CRC32-C (Castagnoli) — the polynomial with xmm hardware support; used
/// on the queue/sstable hot paths. Falls back to slicing-by-8 software
/// when SSE4.2 is absent. (IEEE [`crc32`] is kept for wire compatibility
/// checks and known-vector tests.)
pub fn crc32c(data: &[u8]) -> u32 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("sse4.2") {
            // SAFETY: guarded by the sse4.2 runtime check.
            return unsafe { crc32c_hw(data) };
        }
    }
    crc32c_sw(data)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.2")]
unsafe fn crc32c_hw(data: &[u8]) -> u32 {
    use std::arch::x86_64::{_mm_crc32_u64, _mm_crc32_u8};
    let mut crc = 0xFFFF_FFFFu64;
    let mut chunks = data.chunks_exact(8);
    for chunk in &mut chunks {
        let v = u64::from_le_bytes(chunk.try_into().unwrap());
        crc = _mm_crc32_u64(crc, v);
    }
    let mut crc = crc as u32;
    for &b in chunks.remainder() {
        crc = _mm_crc32_u8(crc, b);
    }
    crc ^ 0xFFFF_FFFF
}

fn crc32c_sw(data: &[u8]) -> u32 {
    static TABLE: once_cell::sync::Lazy<[u32; 256]> = once_cell::sync::Lazy::new(|| {
        let mut table = [0u32; 256];
        for (i, entry) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0x82F6_3B78 ^ (c >> 1) } else { c >> 1 };
            }
            *entry = c;
        }
        table
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// FNV-1a 64-bit hash — cheap stable hash for keyword→dimension mapping.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 8), 16);
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC32 test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_flip() {
        let a = crc32(b"hello world");
        let b = crc32(b"hello worle");
        assert_ne!(a, b);
    }

    #[test]
    fn fnv_is_stable_and_spreads() {
        assert_eq!(fnv1a64(b"a"), fnv1a64(b"a"));
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }
}

#[cfg(test)]
mod crc32c_tests {
    use super::*;

    #[test]
    fn crc32c_known_vector() {
        // RFC 3720 test vector: crc32c("123456789") = 0xE3069283.
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(b""), 0);
    }

    #[test]
    fn hw_and_sw_agree() {
        let mut data = Vec::new();
        for i in 0..1000u32 {
            data.push((i % 251) as u8);
            assert_eq!(crc32c(&data), crc32c_sw(&data), "len={}", data.len());
        }
    }

    #[test]
    fn crc32c_detects_corruption() {
        let a = crc32c(b"the quick brown fox");
        let b = crc32c(b"the quick brown fix");
        assert_ne!(a, b);
    }
}
