//! Deterministic, seedable PRNG (xoshiro256**), used by workload
//! generators, the testkit property framework and simulated transports.
//!
//! No external `rand` crate is available offline; this is a self-contained
//! implementation of the public-domain xoshiro256** algorithm with a
//! SplitMix64 seeder.

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a PRNG from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    /// Uses Lemire-style multiply-shift with rejection for unbiasedness.
    pub fn gen_range_u64(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`; `lo < hi`.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.gen_range_u64((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Approximately-normal sample via the Irwin–Hall sum of 12 uniforms.
    /// Good enough for workload jitter; not for cryptography or statistics.
    pub fn gen_normal(&mut self, mean: f64, stddev: f64) -> f64 {
        let sum: f64 = (0..12).map(|_| self.gen_f64()).sum();
        mean + (sum - 6.0) * stddev
    }

    /// Log-normal sample (used for the paper's LiDAR image-size spread).
    pub fn gen_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.gen_normal(mu, sigma).exp()
    }

    /// Fill a byte slice with pseudo-random bytes.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(0, items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Random lowercase ASCII string of length `len`.
    pub fn ascii_lower(&mut self, len: usize) -> String {
        (0..len)
            .map(|_| (b'a' + self.gen_range(0, 26) as u8) as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::seeded(7);
        let mut b = Prng::seeded(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::seeded(1);
        let mut b = Prng::seeded(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn range_respects_bounds() {
        let mut p = Prng::seeded(3);
        for _ in 0..10_000 {
            let v = p.gen_range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn range_hits_all_values() {
        let mut p = Prng::seeded(4);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[p.gen_range(0, 8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::seeded(5);
        for _ in 0..10_000 {
            let v = p.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_roughly_centered() {
        let mut p = Prng::seeded(6);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.gen_normal(5.0, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut p = Prng::seeded(8);
        let mut buf = [0u8; 13];
        p.fill_bytes(&mut buf);
        // Extremely unlikely all zero.
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
