//! Timing helpers for benches and metrics.

use std::time::{Duration, Instant};

/// Simple stopwatch around `Instant`.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed duration since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed microseconds.
    pub fn micros(&self) -> u64 {
        self.elapsed().as_micros() as u64
    }

    /// Restart and return the previous elapsed duration.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Format a duration compactly for bench output (e.g. `1.23ms`, `45.6µs`).
pub fn fmt_duration(d: Duration) -> String {
    let nanos = d.as_nanos();
    if nanos < 1_000 {
        format!("{nanos}ns")
    } else if nanos < 1_000_000 {
        format!("{:.2}µs", nanos as f64 / 1e3)
    } else if nanos < 1_000_000_000 {
        format!("{:.2}ms", nanos as f64 / 1e6)
    } else {
        format!("{:.2}s", nanos as f64 / 1e9)
    }
}

/// Format a throughput (items/s or bytes/s) with SI prefixes.
pub fn fmt_rate(per_sec: f64, unit: &str) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G{unit}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{unit}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k{unit}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} {unit}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let first = sw.lap();
        assert!(first >= Duration::from_millis(2));
        assert!(sw.elapsed() < first);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn fmt_rate_prefixes() {
        assert!(fmt_rate(2.5e6, "msg").contains("Mmsg/s"));
        assert!(fmt_rate(999.0, "B").contains("B/s"));
    }
}
