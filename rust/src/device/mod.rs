//! Edge-device emulation (DESIGN.md §Environment substitutions).
//!
//! The paper measures on a Raspberry Pi 3, a Moto G5 Plus and Chameleon
//! VMs. This host is none of those, so every disk-bound component
//! (baseline brokers/stores, Table I) routes its I/O through a
//! [`throttle::ThrottledDisk`] parameterised by a [`DeviceProfile`]
//! calibrated to the paper's Table I measurements. Components that are
//! memory-bound (the mmap queue, the memtable) are throttled by the
//! profile's RAM bandwidth, which is what makes the paper's comparisons
//! reproduce *quantitatively*, not just in spirit.

pub mod profile;
pub mod throttle;

pub use profile::DeviceProfile;
pub use throttle::ThrottledDisk;
