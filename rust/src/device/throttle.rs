//! I/O throttling substrate.
//!
//! Every disk-bound component (baselines, Table I bench) performs its
//! byte movement through a [`ThrottledDisk`], which *accounts* the time
//! the operation would take on the emulated device and (in `RealTime`
//! mode) actually sleeps it, or (in `Virtual` mode) accumulates it on a
//! virtual clock — the latter lets scalability benches run in seconds
//! while reporting device-accurate latencies.

use super::profile::DeviceProfile;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Which storage medium an operation touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Medium {
    Disk,
    Ram,
}

/// Access pattern of an operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    Sequential,
    Random,
}

/// Operation direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

/// How elapsed throttle time is applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// Sleep for the computed duration (end-to-end realism).
    RealTime,
    /// Only accumulate on the virtual clock (fast benches).
    Virtual,
}

/// A throttled I/O device.
#[derive(Debug, Clone)]
pub struct ThrottledDisk {
    profile: DeviceProfile,
    mode: ClockMode,
    /// Accumulated virtual time in nanoseconds.
    virtual_ns: Arc<AtomicU64>,
}

impl ThrottledDisk {
    pub fn new(profile: DeviceProfile, mode: ClockMode) -> Self {
        ThrottledDisk { profile, mode, virtual_ns: Arc::new(AtomicU64::new(0)) }
    }

    /// Unthrottled native device (tests).
    pub fn native() -> Self {
        Self::new(DeviceProfile::native(), ClockMode::Virtual)
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Time one operation of `bytes` would take on this device.
    pub fn cost(&self, medium: Medium, pattern: Pattern, dir: Dir, bytes: usize) -> Duration {
        let mbps = match (medium, pattern, dir) {
            (Medium::Disk, Pattern::Sequential, Dir::Read) => self.profile.disk_seq_read,
            (Medium::Disk, Pattern::Sequential, Dir::Write) => self.profile.disk_seq_write,
            (Medium::Disk, Pattern::Random, Dir::Read) => self.profile.disk_rand_read,
            (Medium::Disk, Pattern::Random, Dir::Write) => self.profile.disk_rand_write,
            (Medium::Ram, Pattern::Sequential, Dir::Read) => self.profile.ram_seq_read,
            (Medium::Ram, Pattern::Sequential, Dir::Write) => self.profile.ram_seq_write,
            (Medium::Ram, Pattern::Random, Dir::Read) => self.profile.ram_rand_read,
            (Medium::Ram, Pattern::Random, Dir::Write) => self.profile.ram_rand_write,
        };
        let transfer_secs = if mbps.is_finite() && mbps > 0.0 {
            bytes as f64 / (mbps * 1e6)
        } else {
            0.0
        };
        let op_secs = if medium == Medium::Disk {
            self.profile.io_op_latency_us * 1e-6
        } else {
            // RAM ops: no syscall; negligible fixed cost.
            0.0
        };
        Duration::from_nanos(((transfer_secs + op_secs) * 1e9) as u64)
    }

    /// Account (and possibly sleep) one operation.
    pub fn charge(&self, medium: Medium, pattern: Pattern, dir: Dir, bytes: usize) -> Duration {
        let d = self.cost(medium, pattern, dir, bytes);
        self.apply(d);
        d
    }

    /// Account one storage-operation's fixed CPU cost (profile parsing,
    /// matching, index maintenance on the emulated device's cores).
    pub fn charge_cpu_op(&self) -> Duration {
        let d = Duration::from_nanos((self.profile.cpu_op_latency_us * 1e3) as u64);
        self.apply(d);
        d
    }

    /// Account compute measured on the host, scaled to the device
    /// (`compute_scale` = how much slower the device's cores are).
    pub fn charge_compute(&self, host_time: Duration) -> Duration {
        let d = Duration::from_secs_f64(host_time.as_secs_f64() * self.profile.compute_scale);
        self.apply(d);
        d
    }

    /// Account an fsync.
    pub fn charge_fsync(&self) -> Duration {
        let d = Duration::from_nanos((self.profile.fsync_latency_us * 1e3) as u64);
        self.apply(d);
        d
    }

    /// Account a network transfer of `bytes` (one hop).
    pub fn charge_network(&self, bytes: usize) -> Duration {
        let bw = self.profile.net_bandwidth;
        let transfer = if bw.is_finite() && bw > 0.0 { bytes as f64 / (bw * 1e6) } else { 0.0 };
        let d = Duration::from_nanos(
            ((self.profile.net_latency_us * 1e-6 + transfer) * 1e9) as u64,
        );
        self.apply(d);
        d
    }

    fn apply(&self, d: Duration) {
        self.virtual_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        if self.mode == ClockMode::RealTime && !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    /// Total accumulated virtual time.
    pub fn virtual_elapsed(&self) -> Duration {
        Duration::from_nanos(self.virtual_ns.load(Ordering::Relaxed))
    }

    /// Reset the virtual clock (bench iterations).
    pub fn reset(&self) {
        self.virtual_ns.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pi() -> ThrottledDisk {
        ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::Virtual)
    }

    #[test]
    fn cost_matches_table1_bandwidth() {
        let d = pi();
        // 1 MB sequential disk read at 18.89 MB/s ≈ 52.9 ms + op latency.
        let c = d.cost(Medium::Disk, Pattern::Sequential, Dir::Read, 1_000_000);
        let expected = 1.0 / 18.89 + 120e-6;
        assert!((c.as_secs_f64() - expected).abs() < 1e-6, "{c:?}");
        // Same read from RAM ≈ 1.58 ms, no op latency.
        let r = d.cost(Medium::Ram, Pattern::Sequential, Dir::Read, 1_000_000);
        assert!((r.as_secs_f64() - 1.0 / 631.34).abs() < 1e-6, "{r:?}");
    }

    #[test]
    fn random_write_is_slowest_mode() {
        let d = pi();
        let modes = [
            d.cost(Medium::Disk, Pattern::Sequential, Dir::Read, 4096),
            d.cost(Medium::Disk, Pattern::Sequential, Dir::Write, 4096),
            d.cost(Medium::Disk, Pattern::Random, Dir::Read, 4096),
            d.cost(Medium::Disk, Pattern::Random, Dir::Write, 4096),
        ];
        assert_eq!(modes.iter().max(), Some(&modes[3]));
    }

    #[test]
    fn virtual_clock_accumulates_without_sleeping() {
        let d = pi();
        let wall = std::time::Instant::now();
        for _ in 0..100 {
            d.charge(Medium::Disk, Pattern::Random, Dir::Write, 4096);
        }
        assert!(wall.elapsed() < Duration::from_millis(200), "must not sleep in Virtual mode");
        // 100 × (4096 B / 0.15 MB/s + 120 µs) ≈ 100 × 27.4 ms ≈ 2.74 s.
        let v = d.virtual_elapsed().as_secs_f64();
        assert!(v > 2.0 && v < 3.5, "virtual {v}");
    }

    #[test]
    fn native_costs_nothing() {
        let d = ThrottledDisk::native();
        let c = d.charge(Medium::Disk, Pattern::Random, Dir::Write, 1 << 20);
        assert_eq!(c, Duration::ZERO);
        assert_eq!(d.virtual_elapsed(), Duration::ZERO);
    }

    #[test]
    fn fsync_dominates_small_writes() {
        let d = pi();
        let write = d.cost(Medium::Disk, Pattern::Sequential, Dir::Write, 64);
        d.reset();
        let fsync = d.charge_fsync();
        assert!(fsync > write, "fsync {fsync:?} vs write {write:?}");
    }

    #[test]
    fn network_charge_scales_with_bytes() {
        let d = pi();
        let small = d.cost_net_probe(64);
        let large = d.cost_net_probe(1 << 20);
        assert!(large > small);
    }

    impl ThrottledDisk {
        fn cost_net_probe(&self, bytes: usize) -> Duration {
            let before = self.virtual_elapsed();
            self.charge_network(bytes);
            self.virtual_elapsed() - before
        }
    }

    #[test]
    fn reset_clears_clock() {
        let d = pi();
        d.charge_fsync();
        assert!(d.virtual_elapsed() > Duration::ZERO);
        d.reset();
        assert_eq!(d.virtual_elapsed(), Duration::ZERO);
    }

    #[test]
    fn realtime_mode_actually_sleeps() {
        let d = ThrottledDisk::new(DeviceProfile::raspberry_pi(), ClockMode::RealTime);
        let wall = std::time::Instant::now();
        d.charge(Medium::Disk, Pattern::Random, Dir::Write, 4096); // ≈ 27 ms
        assert!(wall.elapsed() >= Duration::from_millis(20));
    }
}
