//! Device I/O profiles calibrated to the paper's Table I.

use crate::config::DeviceKind;

/// Bandwidth/latency model of one device class. Bandwidths in MB/s
/// (Table I uses MB/s), latencies in microseconds per operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub kind: DeviceKind,
    /// Sequential disk read bandwidth, MB/s.
    pub disk_seq_read: f64,
    /// Sequential disk write bandwidth, MB/s.
    pub disk_seq_write: f64,
    /// Random (4 KiB-block) disk read bandwidth, MB/s.
    pub disk_rand_read: f64,
    /// Random (4 KiB-block) disk write bandwidth, MB/s.
    pub disk_rand_write: f64,
    /// RAM sequential read bandwidth, MB/s.
    pub ram_seq_read: f64,
    /// RAM sequential write bandwidth, MB/s.
    pub ram_seq_write: f64,
    /// RAM random read bandwidth, MB/s.
    pub ram_rand_read: f64,
    /// RAM random write bandwidth, MB/s.
    pub ram_rand_write: f64,
    /// Fixed per-I/O-operation latency, µs (syscall + device overhead).
    pub io_op_latency_us: f64,
    /// Fixed per-storage-operation CPU latency, µs — parsing, profile
    /// matching and index maintenance on the device's cores (the paper's
    /// implementation is JVM-based; dominant for small records).
    pub cpu_op_latency_us: f64,
    /// fsync latency, µs (dominates per-message disk persistence).
    pub fsync_latency_us: f64,
    /// Multiplier translating *measured host compute time* into device
    /// compute time (Cortex-A53 ≈ 20× slower than a server core for the
    /// pipeline's f32 kernels; Snapdragon 625 with JVM ≈ 35×).
    pub compute_scale: f64,
    /// One-way network latency to a peer, µs.
    pub net_latency_us: f64,
    /// Network bandwidth, MB/s (10/100 Ethernet on the Pi).
    pub net_bandwidth: f64,
}

impl DeviceProfile {
    /// Raspberry Pi 3 — Table I of the paper, exactly.
    pub fn raspberry_pi() -> Self {
        DeviceProfile {
            kind: DeviceKind::RaspberryPi,
            disk_seq_read: 18.89,
            disk_seq_write: 7.12,
            disk_rand_read: 0.78,
            disk_rand_write: 0.15,
            ram_seq_read: 631.34,
            ram_seq_write: 573.65,
            ram_rand_read: 65.96,
            ram_rand_write: 65.88,
            io_op_latency_us: 120.0,
            cpu_op_latency_us: 110.0,
            fsync_latency_us: 2_500.0, // SD-card fsync is notoriously slow
            compute_scale: 20.0,
            net_latency_us: 300.0,
            net_bandwidth: 11.0, // 10/100 Ethernet ≈ 11–12 MB/s payload
        }
    }

    /// Moto G5 Plus (Android): faster flash than the Pi's SD card, more
    /// RAM bandwidth, but higher per-op syscall cost (paper §V-A3 shows
    /// Android routing slower than the Pi by ~2× at equal complexity).
    pub fn android() -> Self {
        DeviceProfile {
            kind: DeviceKind::Android,
            disk_seq_read: 160.0,
            disk_seq_write: 80.0,
            disk_rand_read: 18.0,
            disk_rand_write: 9.0,
            ram_seq_read: 2_800.0,
            ram_seq_write: 2_500.0,
            ram_rand_read: 300.0,
            ram_rand_write: 290.0,
            io_op_latency_us: 260.0, // higher VFS/scheduler overhead observed on Android
            cpu_op_latency_us: 240.0,
            fsync_latency_us: 7_000.0,
            compute_scale: 35.0,
            net_latency_us: 1_200.0, // WiFi
            net_bandwidth: 6.0,
        }
    }

    /// Chameleon m1.small-class VM (paper §V-A5) — sized to "simulate
    /// computation capabilities of a Raspberry Pi" but with cloud network.
    pub fn cloud_small() -> Self {
        DeviceProfile {
            kind: DeviceKind::CloudSmall,
            disk_seq_read: 120.0,
            disk_seq_write: 90.0,
            disk_rand_read: 10.0,
            disk_rand_write: 5.0,
            ram_seq_read: 4_000.0,
            ram_seq_write: 3_500.0,
            ram_rand_read: 500.0,
            ram_rand_write: 480.0,
            io_op_latency_us: 60.0,
            cpu_op_latency_us: 35.0,
            fsync_latency_us: 1_500.0,
            compute_scale: 18.0, // m1.small vCPU, sized like a Pi (paper §V)
            net_latency_us: 150.0,
            net_bandwidth: 120.0,
        }
    }

    /// No throttling: raw host performance (unit tests, CI).
    pub fn native() -> Self {
        DeviceProfile {
            kind: DeviceKind::Native,
            disk_seq_read: f64::INFINITY,
            disk_seq_write: f64::INFINITY,
            disk_rand_read: f64::INFINITY,
            disk_rand_write: f64::INFINITY,
            ram_seq_read: f64::INFINITY,
            ram_seq_write: f64::INFINITY,
            ram_rand_read: f64::INFINITY,
            ram_rand_write: f64::INFINITY,
            io_op_latency_us: 0.0,
            cpu_op_latency_us: 0.0,
            fsync_latency_us: 0.0,
            compute_scale: 0.0,
            net_latency_us: 0.0,
            net_bandwidth: f64::INFINITY,
        }
    }

    /// Profile for a [`DeviceKind`].
    pub fn for_kind(kind: DeviceKind) -> Self {
        match kind {
            DeviceKind::RaspberryPi => Self::raspberry_pi(),
            DeviceKind::Android => Self::android(),
            DeviceKind::CloudSmall => Self::cloud_small(),
            DeviceKind::Native => Self::native(),
        }
    }

    /// Whether this profile throttles at all.
    pub fn is_throttled(&self) -> bool {
        self.kind != crate::config::DeviceKind::Native
    }

    /// Canonicalized network bandwidth for cost arithmetic, MB/s.
    ///
    /// [`DeviceProfile::native`] stores `f64::INFINITY` (the profile
    /// tables pin Table I exactly, infinity included), which leaks NaNs
    /// into `bytes / bandwidth` rankings and makes 0-cost ties compare
    /// nondeterministically. Every consumer that divides by bandwidth —
    /// the placement cost model and `SimNetwork::charge_hop` — goes
    /// through here instead: infinite, NaN and non-positive values
    /// clamp to a large-but-finite cap, everything else to a sane
    /// positive range.
    pub fn effective_net_bandwidth(&self) -> f64 {
        /// Stand-in for an "unthrottled" link: 10 GB/s, comfortably
        /// above any Table-I figure yet finite, so per-byte costs stay
        /// ordered and arithmetic stays NaN-free.
        const BANDWIDTH_CAP_MBPS: f64 = 10_000.0;
        const BANDWIDTH_FLOOR_MBPS: f64 = 1e-3;
        if self.net_bandwidth.is_finite() && self.net_bandwidth > 0.0 {
            self.net_bandwidth.clamp(BANDWIDTH_FLOOR_MBPS, BANDWIDTH_CAP_MBPS)
        } else {
            BANDWIDTH_CAP_MBPS
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values_are_exact() {
        // Table I of the paper.
        let pi = DeviceProfile::raspberry_pi();
        assert_eq!(pi.disk_seq_read, 18.89);
        assert_eq!(pi.disk_seq_write, 7.12);
        assert_eq!(pi.disk_rand_read, 0.78);
        assert_eq!(pi.disk_rand_write, 0.15);
        assert_eq!(pi.ram_seq_read, 631.34);
        assert_eq!(pi.ram_seq_write, 573.65);
        assert_eq!(pi.ram_rand_read, 65.96);
        assert_eq!(pi.ram_rand_write, 65.88);
    }

    #[test]
    fn table1_ram_dominates_disk() {
        // The observation motivating the memory-mapped design: RAM is
        // 30–440× faster than the SD card in every mode.
        let pi = DeviceProfile::raspberry_pi();
        assert!(pi.ram_seq_read / pi.disk_seq_read > 30.0);
        assert!(pi.ram_seq_write / pi.disk_seq_write > 30.0);
        assert!(pi.ram_rand_read / pi.disk_rand_read > 80.0);
        assert!(pi.ram_rand_write / pi.disk_rand_write > 400.0);
    }

    #[test]
    fn for_kind_round_trip() {
        use crate::config::DeviceKind::*;
        for k in [RaspberryPi, Android, CloudSmall, Native] {
            assert_eq!(DeviceProfile::for_kind(k).kind, k);
        }
    }

    #[test]
    fn native_is_unthrottled() {
        let n = DeviceProfile::native();
        assert!(!n.is_throttled());
        assert!(DeviceProfile::raspberry_pi().is_throttled());
        assert!(n.disk_seq_read.is_infinite());
    }

    #[test]
    fn effective_bandwidth_is_always_finite_and_positive() {
        use crate::config::DeviceKind::*;
        for k in [RaspberryPi, Android, CloudSmall, Native] {
            let bw = DeviceProfile::for_kind(k).effective_net_bandwidth();
            assert!(bw.is_finite() && bw > 0.0, "{k:?} → {bw}");
        }
        // Table-I figures pass through unchanged…
        assert_eq!(DeviceProfile::raspberry_pi().effective_net_bandwidth(), 11.0);
        assert_eq!(DeviceProfile::cloud_small().effective_net_bandwidth(), 120.0);
        // …while infinity, NaN and zero canonicalize to the finite cap.
        let mut weird = DeviceProfile::native();
        assert_eq!(weird.effective_net_bandwidth(), 10_000.0);
        weird.net_bandwidth = f64::NAN;
        assert_eq!(weird.effective_net_bandwidth(), 10_000.0);
        weird.net_bandwidth = 0.0;
        assert_eq!(weird.effective_net_bandwidth(), 10_000.0);
        weird.net_bandwidth = -5.0;
        assert_eq!(weird.effective_net_bandwidth(), 10_000.0);
    }

    #[test]
    fn android_slower_per_op_than_pi() {
        // Matches the paper's routing-overhead comparison (Fig. 9 vs 10):
        // Android per-message overheads exceed the Pi's.
        assert!(DeviceProfile::android().io_op_latency_us
            > DeviceProfile::raspberry_pi().io_op_latency_us);
    }
}
