//! In-process multi-node cluster (substitutes the paper's Chameleon
//! deployment for Figs. 11–12 and hosts the integration tests).
//!
//! The cluster owns N [`Node`]s, the shared geographic [`QuadTree`],
//! converged routing tables (what the stabilisation mode maintains), a
//! [`SimNetwork`] for latency accounting, and the content router. Its
//! `post` implements the paper's routing process end to end: quadtree
//! region selection → SFC mapping → overlay lookup → delivery, charging
//! each hop to the virtual clock.

use super::node::Node;
use crate::ar::message::ArMessage;
use crate::ar::primitives::RendezvousNetwork;
use crate::ar::rendezvous::Reaction;
use crate::ar::shard::ShardMap;
use crate::config::DeviceKind;
use crate::device::profile::DeviceProfile;
use crate::error::{Error, Result};
use crate::metrics::Registry;
use crate::net::sim::SimNetwork;
use crate::net::tcp::TcpEndpoint;
use crate::net::wire::NetMessage;
use crate::overlay::geo::GeoPoint;
use crate::overlay::node_id::NodeId;
use crate::overlay::quadtree::QuadTree;
use crate::overlay::ring::{build_converged_tables, simulate_lookup, RoutingTable};
use crate::pipeline::trigger::{TriggerOptions, TriggerStats};
use crate::routing::router::ContentRouter;
use crate::stream::checkpoint::{
    checkpointing_enabled, CheckpointJournal, CheckpointRecord, CheckpointReport, RouteCheckpoint,
};
use crate::stream::deploy::TopologyManager;
use crate::stream::dist::{
    self, plan_placement, ClusterPolicy, Fragment, FragmentHost, MigrationReport, PlacementPlan,
    PolicyAction, RouteState,
};
use crate::stream::engine::RescaleReport;
use crate::stream::pipeline::{handle_for, Deployer, Pipeline, PipelineHandle, StageFactory};
use crate::stream::topology::Topology;
use crate::stream::tuple::Tuple;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Test hook: when set to a node *name*, that node is kill-9'd (crash
/// semantics, no drain) the next time a stream feed touches the
/// cluster — whole-node failure injection for the recovery suite.
/// Idempotent: once the node is gone the variable matches nothing.
pub const NODE_CRASH_ENV: &str = "RPULSAR_TEST_NODE_CRASH";

/// The in-process cluster.
pub struct Cluster {
    nodes: BTreeMap<NodeId, Node>,
    quadtree: QuadTree,
    tables: BTreeMap<NodeId, RoutingTable>,
    router: ContentRouter,
    network: SimNetwork,
    device: DeviceKind,
    base_dir: PathBuf,
    /// Distributed stream topologies deployed across the nodes:
    /// key → route of per-node fragments (see `stream::dist`).
    streams: BTreeMap<String, RouteState>,
    /// Cluster-level stream metrics (`net.hop.*` wire-path counters).
    metrics: Registry,
    /// Whether newly deployed streams get a background shipper.
    async_net: bool,
    /// HRW map over the live nodes' names: the federated matching
    /// plane routes each published topic to exactly one owner node.
    fed_map: ShardMap,
    /// Rotating start offset for federated fetches (no node starves).
    fed_rr: usize,
    /// Consecutive same-direction watermark hits per `frag_key/stage`,
    /// debouncing [`Cluster::stream_policy_tick`] rescales.
    policy_streaks: BTreeMap<String, (usize, u32)>,
    /// The durable checkpoint journal (`base_dir/ckpt`), opened lazily
    /// by the first [`Cluster::enable_checkpoints`] /
    /// [`Cluster::enable_checkpoint_journal`].
    ckpt_journal: Option<CheckpointJournal>,
    /// Identities of killed nodes ([`Cluster::kill_node`]), so
    /// [`Cluster::restart_node`] can rebuild the same member — same
    /// name, same [`NodeId`], same durable directories.
    graveyard: BTreeMap<NodeId, (String, GeoPoint)>,
}

/// The cluster hosts topology fragments on its nodes' own managers and
/// charges inter-fragment hops to its simulated network.
impl FragmentHost for Cluster {
    fn manager(&self, node: &NodeId) -> Option<&TopologyManager> {
        self.nodes.get(node).map(|n| n.topologies())
    }

    fn manager_mut(&mut self, node: &NodeId) -> Option<&mut TopologyManager> {
        self.nodes.get_mut(node).map(|n| n.topologies_mut())
    }

    fn network(&self) -> &SimNetwork {
        &self.network
    }

    fn metrics(&self) -> &Registry {
        &self.metrics
    }
}

impl Cluster {
    /// Build a cluster of `n` nodes placed deterministically on a grid
    /// around the paper's use-case area (NJ/NY).
    pub fn new(name: &str, n: usize, device: DeviceKind) -> Result<Self> {
        let base_dir = std::env::temp_dir()
            .join("rpulsar-cluster")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base_dir);
        let mut nodes = BTreeMap::new();
        let mut quadtree = QuadTree::new(2);
        let network = SimNetwork::new();
        let side = (n as f64).sqrt().ceil() as usize;
        for i in 0..n {
            let lat = 40.0 + (i / side) as f64 * 0.05;
            let lon = -74.5 + (i % side) as f64 * 0.05;
            let node_name = format!("{name}-rp-{i}");
            let mut cfg = crate::config::NodeConfig::default();
            cfg.name = node_name;
            cfg.latitude = lat;
            cfg.longitude = lon;
            cfg.device = device;
            cfg.queue.dir = base_dir.join("queue");
            cfg.storage.dir = base_dir.join("store");
            let node = Node::new(cfg)?;
            let id = node.id();
            quadtree.insert(id, GeoPoint::new(lat, lon))?;
            network.register(id, DeviceProfile::for_kind(device));
            nodes.insert(id, node);
        }
        // Stabilised routing tables + mutual peer knowledge.
        let ids: Vec<NodeId> = nodes.keys().copied().collect();
        let tables = build_converged_tables(&ids, 8);
        for node in nodes.values_mut() {
            for &peer in &ids {
                if peer != node.id() {
                    node.learn_peer(peer);
                }
            }
        }
        let fed_map = ShardMap::new(nodes.values().map(|n| n.name().to_string()));
        Ok(Cluster {
            nodes,
            quadtree,
            tables,
            router: ContentRouter::new(),
            network,
            device,
            base_dir,
            streams: BTreeMap::new(),
            metrics: Registry::new(),
            async_net: dist::netplane_async_default(),
            fed_map,
            fed_rr: 0,
            policy_streaks: BTreeMap::new(),
            ckpt_journal: None,
            graveyard: BTreeMap::new(),
        })
    }

    /// Node ids, sorted.
    pub fn ids(&self) -> Vec<NodeId> {
        self.nodes.keys().copied().collect()
    }

    /// Number of live nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Access a node.
    pub fn node(&self, id: &NodeId) -> Option<&Node> {
        self.nodes.get(id)
    }

    pub fn node_mut(&mut self, id: &NodeId) -> Option<&mut Node> {
        self.nodes.get_mut(id)
    }

    /// Bind a data-driven pipeline on `node`'s trigger plane: matching
    /// data reaching that node's broker activates the pipeline on
    /// demand, and [`Cluster::tick`] (which runs every node's
    /// housekeeping tick) pumps the lifecycle — a cluster can host
    /// thousands of bindings with no external pump loop.
    pub fn bind_trigger(
        &mut self,
        node: &NodeId,
        pipeline: Pipeline,
        profile: crate::ar::profile::Profile,
        opts: TriggerOptions,
    ) -> Result<()> {
        self.nodes
            .get_mut(node)
            .ok_or_else(|| Error::Overlay(format!("unknown node {node}")))?
            .bind_trigger(pipeline, profile, opts)
    }

    /// Remove a trigger binding from `node`; returns untaken outputs.
    pub fn unbind_trigger(&mut self, node: &NodeId, name: &str) -> Result<Vec<Tuple>> {
        self.nodes
            .get_mut(node)
            .ok_or_else(|| Error::Overlay(format!("unknown node {node}")))?
            .unbind_trigger(name)
    }

    /// Take everything a node-hosted trigger binding has produced.
    pub fn trigger_outputs(&mut self, node: &NodeId, name: &str) -> Vec<Tuple> {
        self.nodes
            .get_mut(node)
            .map(|n| n.triggers_mut().take_outputs(name))
            .unwrap_or_default()
    }

    /// A node-hosted trigger binding's lifetime counters.
    pub fn trigger_stats(&self, node: &NodeId, name: &str) -> Option<TriggerStats> {
        self.nodes.get(node)?.triggers().stats(name)
    }

    /// The simulated network (virtual clock, counters).
    pub fn network(&self) -> &SimNetwork {
        &self.network
    }

    /// Cluster-level stream metrics: the `net.hop.*` wire-path
    /// counters of every deployed stream.
    pub fn stream_metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Choose the net-plane mode for *subsequently deployed* streams:
    /// `true` (the default, unless `RPULSAR_NETPLANE=sync`) gives every
    /// multi-fragment route a background shipper; `false` keeps hops on
    /// the legacy synchronous pump. Deployed streams are unaffected.
    pub fn set_async_shippers(&mut self, on: bool) {
        self.async_net = on;
    }

    /// The shared quadtree view.
    pub fn quadtree(&self) -> &QuadTree {
        &self.quadtree
    }

    /// The content router.
    pub fn router(&self) -> &ContentRouter {
        &self.router
    }

    /// Converged routing tables (routing simulations in benches).
    pub fn tables(&self) -> &BTreeMap<NodeId, RoutingTable> {
        &self.tables
    }

    /// Crash a node: partition it and remove it from routing state.
    /// Its on-disk shard stays (data durability); replicas keep serving.
    pub fn crash(&mut self, id: &NodeId) -> Result<()> {
        if !self.nodes.contains_key(id) {
            return Err(Error::NotFound(format!("no node {id}")));
        }
        let name = self.nodes[id].name().to_string();
        self.fed_map.remove(&name);
        self.network.take_down(*id);
        self.tables.remove(id);
        for t in self.tables.values_mut() {
            t.remove(id);
        }
        for node in self.nodes.values_mut() {
            node.forget_peer(id);
        }
        self.quadtree.remove(id);
        self.nodes.remove(id);
        Ok(())
    }

    /// Master election over the remaining members of a region, using
    /// Hirschberg–Sinclair (paper §IV-A).
    pub fn elect_master(&mut self, region: crate::overlay::quadtree::RegionId) -> Result<NodeId> {
        let members: Vec<NodeId> = self
            .quadtree
            .members_of(region)
            .ok_or_else(|| Error::Overlay(format!("region {region} not found")))?
            .iter()
            .map(|m| m.id)
            .collect();
        if members.is_empty() {
            return Err(Error::Overlay(format!("region {region} has no members")));
        }
        let result = crate::overlay::election::hirschberg_sinclair(&members);
        self.quadtree.set_master(region, result.leader)?;
        Ok(result.leader)
    }

    /// Route an AR message from `origin`: full paper routing process.
    /// Returns per-target reactions; charges network hops.
    pub fn post_from(
        &mut self,
        origin: NodeId,
        msg: &ArMessage,
    ) -> Result<Vec<(NodeId, Vec<Reaction>)>> {
        let targets = self.resolve(msg)?;
        let wire = msg.encode().len() + 4;
        let mut out = Vec::with_capacity(targets.len());
        for target in targets {
            // Hop accounting along the simulated lookup path.
            let path = simulate_lookup(&self.tables, origin, &target).path;
            let mut prev = origin;
            for hop in path.iter().chain(std::iter::once(&target)) {
                if *hop != prev {
                    self.network.charge_hop(&prev, hop, wire);
                    prev = *hop;
                }
            }
            let node = self
                .nodes
                .get_mut(&target)
                .ok_or_else(|| Error::Overlay(format!("target {target} gone")))?;
            let reactions = node.handle_ar(msg)?;
            out.push((target, reactions));
        }
        Ok(out)
    }

    /// Charge the network along the greedy overlay route from `from`
    /// toward `to` (every intermediary RP forwards the message — the
    /// source of the paper's Figs. 11–12 growth with cluster size).
    fn charge_route(&self, from: NodeId, to: NodeId, bytes: usize) {
        let path = simulate_lookup(&self.tables, from, &to).path;
        let mut prev = from;
        for hop in path.iter().chain(std::iter::once(&to)) {
            if *hop != prev {
                self.network.charge_hop(&prev, hop, bytes);
                prev = *hop;
            }
        }
    }

    /// Store a record with replication: route to the `replicas`
    /// XOR-closest live nodes (paper's DHT replication), paying every
    /// overlay hop along the way.
    pub fn store_replicated(
        &mut self,
        origin: NodeId,
        msg: &ArMessage,
        replicas: usize,
    ) -> Result<Vec<NodeId>> {
        let key = crate::storage::dht::key_id(&msg.header.profile)?;
        let live: Vec<NodeId> = self.nodes.keys().copied().collect();
        let targets = crate::storage::dht::replica_set(&key, &live, replicas);
        let wire = msg.encode().len() + 4;
        for t in &targets {
            self.charge_route(origin, *t, wire);
            self.nodes.get_mut(t).unwrap().handle_ar(msg)?;
        }
        Ok(targets)
    }

    /// Exact query: route to the owner, read its shard, route the reply.
    pub fn query_exact(
        &mut self,
        origin: NodeId,
        profile: &crate::ar::profile::Profile,
    ) -> Result<Option<Vec<u8>>> {
        let key = crate::storage::dht::key_id(profile)?;
        let live: Vec<NodeId> = self.nodes.keys().copied().collect();
        let targets = crate::storage::dht::replica_set(&key, &live, 2);
        let storage_key = profile.render().into_bytes();
        for t in targets {
            self.charge_route(origin, t, 64);
            if let Some(v) = self.nodes[&t].store().get(&storage_key)? {
                self.charge_route(t, origin, v.len() + 4);
                return Ok(Some(v));
            }
        }
        Ok(None)
    }

    /// Wildcard query: fan out to every RP the clusters resolve to.
    pub fn query_wildcard(
        &mut self,
        origin: NodeId,
        pattern: &crate::ar::profile::Profile,
    ) -> Result<Vec<(String, Vec<u8>)>> {
        let rendered = pattern.render();
        let literal: String = rendered.chars().take_while(|&c| c != '*').collect();
        let mut out: BTreeMap<String, Vec<u8>> = BTreeMap::new();
        let ids: Vec<NodeId> = self.nodes.keys().copied().collect();
        for id in ids {
            self.network.charge_hop(&origin, &id, 64);
            let hits = self.nodes[&id].store().scan_prefix(literal.as_bytes())?;
            let mut reply_bytes = 0usize;
            for (k, v) in hits {
                let key_str = String::from_utf8_lossy(&k).to_string();
                if let Ok(stored) = crate::ar::profile::Profile::parse(&key_str) {
                    if crate::ar::matching::matches(pattern, &stored) {
                        reply_bytes += v.len();
                        out.insert(key_str, v);
                    }
                }
            }
            self.network.charge_hop(&id, &origin, reply_bytes.max(16));
        }
        Ok(out.into_iter().collect())
    }

    // ---- Federated matching plane (rendezvous federation with TTLs) ----

    /// Register `consumer` across the whole cluster (the libp2p
    /// rendezvous idiom: every node is both rendezvous server and
    /// registrant). The registration applies at `origin`, then a
    /// [`NetMessage::Register`] frame is forwarded to every peer,
    /// charging each overlay route. Every node subscribes the consumer
    /// — associative matching means any node's topics can match — while
    /// publishes route to exactly one HRW owner
    /// ([`Cluster::federated_publish`]).
    ///
    /// `ttl` of `None` never expires; otherwise the registration lapses
    /// once the TTL passes and [`Node::tick`] (run by
    /// [`Cluster::tick`] and the stream pump paths) sweeps it. Re-sent
    /// registrations restart the watermark; a registration re-applied
    /// *after* expiry is a fresh subscription that replays the retained
    /// backlog (at-least-once). Note the wire frame encodes "no expiry"
    /// as `ttl_ms == 0`, so a zero TTL is an in-process test idiom
    /// only.
    pub fn federated_subscribe(
        &mut self,
        origin: NodeId,
        consumer: &str,
        profile: &crate::ar::profile::Profile,
        ttl: Option<std::time::Duration>,
    ) -> Result<()> {
        if !self.nodes.contains_key(&origin) {
            return Err(Error::Overlay(format!("unknown origin {origin}")));
        }
        let frame = NetMessage::Register {
            from: origin,
            consumer: consumer.to_string(),
            profile: profile.clone(),
            ttl_ms: ttl.map(|d| d.as_millis() as u64).unwrap_or(0),
        };
        let wire = frame.wire_size();
        let ids: Vec<NodeId> = self.nodes.keys().copied().collect();
        for id in ids {
            if id != origin {
                self.charge_route(origin, id, wire);
                self.metrics.counter("cluster.registers_forwarded").inc();
            }
            self.nodes.get_mut(&id).unwrap().apply_registration(consumer, profile.clone(), ttl);
        }
        // With the checkpoint journal enabled, registrations are
        // durable: a node restarted after a crash re-applies them (see
        // `Cluster::restart_node`).
        if let Some(journal) = &self.ckpt_journal {
            journal.record_registration(
                consumer,
                profile,
                ttl.map(|d| d.as_millis() as u64).unwrap_or(0),
            )?;
        }
        Ok(())
    }

    /// Withdraw a federated registration everywhere before its TTL
    /// lapses (forwards [`NetMessage::Unregister`] to every peer).
    /// Returns whether any node held it.
    pub fn federated_unsubscribe(&mut self, origin: NodeId, consumer: &str) -> Result<bool> {
        if !self.nodes.contains_key(&origin) {
            return Err(Error::Overlay(format!("unknown origin {origin}")));
        }
        let frame = NetMessage::Unregister { from: origin, consumer: consumer.to_string() };
        let wire = frame.wire_size();
        let ids: Vec<NodeId> = self.nodes.keys().copied().collect();
        let mut any = false;
        for id in ids {
            if id != origin {
                self.charge_route(origin, id, wire);
            }
            any |= self.nodes.get_mut(&id).unwrap().remove_registration(consumer);
        }
        if let Some(journal) = &self.ckpt_journal {
            journal.remove_registration(consumer)?;
        }
        Ok(any)
    }

    /// Publish on the federated plane: the topic's HRW owner over the
    /// live node names (stable under churn — only keys owned by a
    /// crashed node move) hosts the queue; the publish routes there,
    /// paying the overlay hops. Returns `(owner, offset)`.
    pub fn federated_publish(
        &mut self,
        origin: NodeId,
        profile: &crate::ar::profile::Profile,
        payload: &[u8],
    ) -> Result<(NodeId, u64)> {
        let key = profile.render();
        let owner = NodeId::from_name(
            self.fed_map.owner(&key).ok_or_else(|| Error::Overlay("empty cluster".into()))?,
        );
        self.charge_route(origin, owner, key.len() + payload.len() + 16);
        let offset = self
            .nodes
            .get_mut(&owner)
            .ok_or_else(|| Error::Overlay(format!("owner {owner} gone")))?
            .publish(profile, payload)?;
        Ok((owner, offset))
    }

    /// Drain `consumer`'s matched backlog from every node, starting at
    /// a rotating node so no shard starves, charging each reply route
    /// back to `origin`. Errors if the consumer holds no live federated
    /// registration anywhere.
    pub fn federated_fetch(
        &mut self,
        origin: NodeId,
        consumer: &str,
        max: usize,
    ) -> Result<Vec<(String, Vec<u8>)>> {
        let ids: Vec<NodeId> = self.nodes.keys().copied().collect();
        if !ids.iter().any(|id| self.nodes[id].is_registered(consumer)) {
            return Err(Error::NotFound(format!(
                "no federated registration for `{consumer}`"
            )));
        }
        let start = self.fed_rr % ids.len();
        self.fed_rr = self.fed_rr.wrapping_add(1);
        let mut out = Vec::new();
        for i in 0..ids.len() {
            if out.len() >= max {
                break;
            }
            let id = ids[(start + i) % ids.len()];
            if !self.nodes[&id].is_registered(consumer) {
                continue;
            }
            let msgs =
                self.nodes.get_mut(&id).unwrap().broker_mut().fetch(consumer, max - out.len())?;
            let bytes: usize = msgs.iter().map(|(k, m)| k.len() + m.len()).sum();
            self.charge_route(id, origin, bytes.max(16));
            out.extend(msgs.into_iter().map(|(k, m)| (k, m.to_vec())));
        }
        Ok(out)
    }

    /// Retire a topic from the federated plane: sweeps EVERY node, not
    /// just the current HRW owner. Under churn a topic's queue — and
    /// the brokers' subscription match-cache entries for it — can live
    /// on nodes that no longer own the key, so an owner-routed retire
    /// would leave stale matches behind. Returns whether any node
    /// dropped state.
    pub fn federated_retire(&mut self, profile: &crate::ar::profile::Profile) -> Result<bool> {
        let mut any = false;
        for node in self.nodes.values_mut() {
            any |= node.broker_mut().retire_topic(profile)?;
        }
        Ok(any)
    }

    /// The federated plane's HRW map over live node names.
    pub fn federation_map(&self) -> &ShardMap {
        &self.fed_map
    }

    /// Apply one federation control frame received from a transport —
    /// the TCP ingress half of [`Cluster::federated_subscribe`] /
    /// [`Cluster::federated_unsubscribe`]. A frame whose `from` is a
    /// cluster node replays the full federated call (simulated
    /// forwarding routes charged); an external registrant's frame
    /// already paid the real wire, so it applies at every node
    /// directly. The wire encodes "no expiry" as `ttl_ms == 0`.
    /// Returns whether the frame changed any node. Errors on frames
    /// that are not federation control traffic.
    pub fn apply_federation_frame(&mut self, frame: NetMessage) -> Result<bool> {
        match frame {
            NetMessage::Register { from, consumer, profile, ttl_ms } => {
                let ttl = (ttl_ms > 0).then(|| Duration::from_millis(ttl_ms));
                if self.nodes.contains_key(&from) {
                    self.federated_subscribe(from, &consumer, &profile, ttl)?;
                } else {
                    for node in self.nodes.values_mut() {
                        node.apply_registration(&consumer, profile.clone(), ttl);
                    }
                }
                self.metrics.counter("cluster.federation.frames_applied").inc();
                Ok(true)
            }
            NetMessage::Unregister { from, consumer } => {
                let any = if self.nodes.contains_key(&from) {
                    self.federated_unsubscribe(from, &consumer)?
                } else {
                    let mut any = false;
                    for node in self.nodes.values_mut() {
                        any |= node.remove_registration(&consumer);
                    }
                    any
                };
                self.metrics.counter("cluster.federation.frames_applied").inc();
                Ok(any)
            }
            other => Err(Error::Net(format!("not a federation frame: {other:?}"))),
        }
    }

    /// Drain an endpoint's inbox into the federated plane: every
    /// Register/Unregister frame that arrived over the wire is applied
    /// via [`Cluster::apply_federation_frame`]; other message kinds are
    /// logged and skipped (they belong to other planes). Waits up to
    /// `wait` for each successive frame, so `Duration::ZERO` is a pure
    /// poll. Returns how many frames were applied.
    pub fn drain_federation(&mut self, endpoint: &TcpEndpoint, wait: Duration) -> Result<usize> {
        let mut applied = 0;
        while let Some(frame) = endpoint.recv_timeout(wait) {
            match frame {
                f @ (NetMessage::Register { .. } | NetMessage::Unregister { .. }) => {
                    self.apply_federation_frame(f)?;
                    applied += 1;
                }
                other => log::warn!("federation ingress: ignoring {other:?}"),
            }
        }
        Ok(applied)
    }

    // ---- Distributed stream topologies (cross-node stage placement) ----

    /// Deploy a stream topology split across the cluster per `plan`:
    /// each fragment starts on its node's own `TopologyManager`
    /// (stages must be registered there beforehand), and inter-node
    /// hops ship `NetMessage::StreamBatch` frames charged to the
    /// simulated network. Fails — rolling back started fragments —
    /// on unknown nodes, unknown stages, or a plan that does not cover
    /// the chain contiguously.
    pub fn deploy_stream(&mut self, key: &str, spec: &str, plan: &PlacementPlan) -> Result<()> {
        if self.streams.contains_key(key) {
            return Err(Error::Stream(format!("stream topology `{key}` already deployed")));
        }
        let topo = Topology::parse(key, spec)?;
        let mut route = dist::start_fragments(self, key, &topo, plan)?;
        if self.async_net {
            dist::start_shipper(&*self, &mut route)?;
        }
        self.streams.insert(key.to_string(), route);
        Ok(())
    }

    /// Feed one tuple into a deployed stream (blocks under cross-node
    /// backpressure).
    pub fn stream_send(&mut self, key: &str, tuple: Tuple) -> Result<()> {
        self.stream_send_batch(key, vec![tuple])
    }

    /// Feed a batch. Async streams hand hop movement to their
    /// background shipper; sync streams pump inter-node hops inline.
    /// On a checkpointed stream the batch is write-ahead logged first,
    /// a dead hop triggers recovery before any new data enters the
    /// route, and the periodic epoch barrier fires when due.
    pub fn stream_send_batch(&mut self, key: &str, batch: Vec<Tuple>) -> Result<()> {
        self.maybe_inject_crash();
        let checkpointed =
            self.streams.get(key).map(|r| r.checkpoint().is_some()).unwrap_or(false);
        if checkpointed {
            return self.checkpointed_send(key, batch);
        }
        self.feed_deployed(key, batch)
    }

    /// The plain (pre-checkpoint) feed body, shared by both paths.
    fn feed_deployed(&mut self, key: &str, batch: Vec<Tuple>) -> Result<()> {
        {
            let this = &*self;
            if let Some(route) = this.streams.get(key) {
                if route.has_shipper() {
                    return dist::feed_route_async(this, route, batch);
                }
            }
        }
        let mut route = self.take_stream(key)?;
        let r = dist::feed_route(&*self, &mut route, batch);
        self.streams.insert(key.to_string(), route);
        r
    }

    /// Checkpointed feed: detect-and-recover, write-ahead log, feed,
    /// then run the epoch barrier if the interval has elapsed.
    fn checkpointed_send(&mut self, key: &str, batch: Vec<Tuple>) -> Result<()> {
        if self.stream_has_dead_hop(key) {
            self.recover_stream(key)?;
        }
        {
            let route = self
                .streams
                .get_mut(key)
                .ok_or_else(|| Error::NotRunning(format!("stream topology `{key}`")))?;
            let ckpt = route.checkpoint_mut().expect("caller checked the route is checkpointed");
            ckpt.note_input(key, &batch)?;
        }
        self.feed_deployed(key, batch)?;
        let due =
            self.streams.get(key).and_then(|r| r.checkpoint()).map(|c| c.due()).unwrap_or(false);
        if due {
            self.checkpoint_stream(key)?;
        }
        Ok(())
    }

    /// Whether any of a deployed stream's fragments is hosted on a node
    /// that is no longer a cluster member — the failure detector.
    fn stream_has_dead_hop(&self, key: &str) -> bool {
        self.streams
            .get(key)
            .map(|st| st.hops().iter().any(|h| !self.nodes.contains_key(&h.node)))
            .unwrap_or(false)
    }

    /// Kill the node named by [`NODE_CRASH_ENV`], if it is (still) a
    /// member. No-op without the variable — and after the first hit,
    /// because the victim is gone.
    fn maybe_inject_crash(&mut self) {
        let Ok(victim) = std::env::var(NODE_CRASH_ENV) else { return };
        let Some(id) = self.nodes.values().find(|n| n.name() == victim).map(|n| n.id()) else {
            return;
        };
        log::warn!("injected whole-node crash: {victim} ({id})");
        let _ = self.kill_node(&id);
    }

    /// Move in-flight batches across the stream's node hops
    /// (non-blocking) and return outputs collected so far from the
    /// final fragment. On a pump error the collected outputs stay in
    /// the route — a later `stream_stop` can still return them. Doubles
    /// as a housekeeping edge: every pump runs [`Cluster::tick`].
    pub fn stream_pump(&mut self, key: &str) -> Result<Vec<Tuple>> {
        self.pump_stream_collect(key, usize::MAX)
    }

    /// Shared pump-and-collect body of [`Cluster::stream_pump`] and the
    /// `Deployer::poll` surface: housekeeping tick, pump the route, and
    /// take up to `max` collected outputs. On a pump error the
    /// collected outputs stay in the route — a later `stream_stop` can
    /// still return them.
    fn pump_stream_collect(&mut self, key: &str, max: usize) -> Result<Vec<Tuple>> {
        self.tick();
        let checkpointed = self
            .streams
            .get(key)
            .ok_or_else(|| Error::NotRunning(format!("stream topology `{key}`")))?
            .checkpoint()
            .is_some();
        if checkpointed {
            // The committed-output gate: fresh outputs park in the
            // pending set; only epochs that committed are released.
            if self.stream_has_dead_hop(key) {
                self.recover_stream(key)?;
            }
            let outs = self.drain_outputs(key)?;
            let route = self.streams.get_mut(key).expect("checked above");
            let ckpt = route.checkpoint_mut().expect("checked above");
            ckpt.pending.extend(outs);
            return Ok(ckpt.take_committed(max));
        }
        {
            let route = self
                .streams
                .get(key)
                .ok_or_else(|| Error::NotRunning(format!("stream topology `{key}`")))?;
            if route.has_shipper() {
                return dist::poll_route_async(route, max);
            }
        }
        let mut route = self.take_stream(key)?;
        let r = dist::pump_route(&*self, &mut route);
        let out = if r.is_ok() { route.take_up_to(max) } else { Vec::new() };
        self.streams.insert(key.to_string(), route);
        r.map(|()| out)
    }

    /// Drain everything the route has produced so far (ungated — the
    /// checkpointed pump path parks the result in the pending gate).
    fn drain_outputs(&mut self, key: &str) -> Result<Vec<Tuple>> {
        {
            let route = self
                .streams
                .get(key)
                .ok_or_else(|| Error::NotRunning(format!("stream topology `{key}`")))?;
            if route.has_shipper() {
                return dist::poll_route_async(route, usize::MAX);
            }
        }
        let mut route = self.take_stream(key)?;
        let r = dist::pump_route(&*self, &mut route);
        let out = if r.is_ok() { route.take_collected() } else { Vec::new() };
        self.streams.insert(key.to_string(), route);
        r.map(|()| out)
    }

    /// Live-rescale a stage of a deployed stream on whichever node
    /// hosts its fragment (zero loss, per-key order preserved — the
    /// executor's own rescale contract).
    pub fn stream_rescale(
        &mut self,
        key: &str,
        stage: &str,
        parallelism: usize,
    ) -> Result<RescaleReport> {
        let (node, frag_key) = {
            let route = self
                .streams
                .get(key)
                .ok_or_else(|| Error::NotRunning(format!("stream topology `{key}`")))?;
            let hop = route
                .hops()
                .iter()
                .find(|h| h.stages.iter().any(|s| s == stage))
                .ok_or_else(|| {
                    Error::Stream(format!("stream topology `{key}` has no stage `{stage}`"))
                })?;
            (hop.node, hop.frag_key.clone())
        };
        self.nodes
            .get(&node)
            .ok_or_else(|| Error::Net(format!("no stream manager for node {node}")))?
            .topologies()
            .rescale(&frag_key, stage, parallelism)
    }

    /// Live-migrate one fragment of a deployed stream to another
    /// cluster node: same pause/zero-loss/per-key-order contract as
    /// [`DistributedTopologyManager::migrate_fragment`] — the shared
    /// [`dist::migrate_route`] mechanism runs against the cluster's
    /// nodes and simulated network. The target node must know the
    /// fragment's stages (register them there, or deploy through the
    /// [`Deployer`] surface, which registers attached factories on
    /// every node).
    ///
    /// [`DistributedTopologyManager::migrate_fragment`]:
    /// crate::stream::dist::DistributedTopologyManager::migrate_fragment
    pub fn stream_migrate(
        &mut self,
        key: &str,
        fragment: usize,
        to: NodeId,
    ) -> Result<MigrationReport> {
        let mut route = self.take_stream(key)?;
        let r = dist::migrate_route(self, &mut route, fragment, to);
        self.streams.insert(key.to_string(), route);
        r
    }

    /// Current placement of a deployed stream, from its live hops
    /// (reflects past migrations).
    pub fn stream_placement(&self, key: &str) -> Option<PlacementPlan> {
        self.streams.get(key).map(|st| PlacementPlan {
            fragments: st
                .hops()
                .iter()
                .map(|h| Fragment { node: h.node, stages: h.specs.clone() })
                .collect(),
        })
    }

    /// Device profiles the stream planner sees for the cluster's nodes
    /// (uniform: every node runs as [`Cluster::device`]).
    fn stream_profiles(&self) -> BTreeMap<NodeId, DeviceProfile> {
        self.nodes.keys().map(|id| (*id, DeviceProfile::for_kind(self.device))).collect()
    }

    /// One cluster policy pass over the deployed streams — the
    /// coordinator flavour of
    /// [`DistributedTopologyManager::policy_tick`]. Runs the
    /// housekeeping [`Cluster::tick`] (which publishes each node's
    /// gauges cluster-wide), then samples every fragment's depth gauges
    /// *from its hosting node's own registry*, rescales between the
    /// policy watermarks (`sustain`-debounced), and finally re-ranks
    /// each stream's placement with the policy's cost model, migrating
    /// a fragment when another host wins by `migrate_min_gain`. On a
    /// uniform cluster the placement pass converges immediately; it
    /// earns its keep under churn (see [`Cluster::decommission`]).
    ///
    /// [`DistributedTopologyManager::policy_tick`]:
    /// crate::stream::dist::DistributedTopologyManager::policy_tick
    pub fn stream_policy_tick(&mut self, policy: &ClusterPolicy) -> Result<Vec<PolicyAction>> {
        self.tick();
        let mut actions = Vec::new();
        // -- Elasticity: watermark rescales, debounced per stage.
        let mut samples: Vec<(String, Arc<str>, NodeId, String, usize, i64)> = Vec::new();
        for (key, st) in &self.streams {
            for hop in st.hops() {
                for stage in &hop.stages {
                    let Some(node) = self.nodes.get(&hop.node) else { continue };
                    let Ok(current) = node.topologies().parallelism(&hop.frag_key, stage)
                    else {
                        continue;
                    };
                    let reg = node.metrics();
                    let mut depth =
                        reg.gauge(&format!("stream.{}.{stage}.in.depth", hop.frag_key)).get();
                    for r in 0..current {
                        depth = depth.max(
                            reg.gauge(&format!("stream.{}.{stage}.r{r}.depth", hop.frag_key))
                                .get(),
                        );
                    }
                    samples.push((
                        key.clone(),
                        hop.frag_key.clone(),
                        hop.node,
                        stage.clone(),
                        current,
                        depth,
                    ));
                }
            }
        }
        for (key, frag_key, node, stage, current, depth) in samples {
            let streak_key = format!("{frag_key}/{stage}");
            let Some(target) = policy.decide(depth, current) else {
                self.policy_streaks.remove(&streak_key);
                continue;
            };
            let streak = match self.policy_streaks.get(&streak_key) {
                Some((t, n)) if *t == target => n + 1,
                _ => 1,
            };
            if streak < policy.sustain.max(1) {
                self.policy_streaks.insert(streak_key, (target, streak));
                continue;
            }
            self.policy_streaks.remove(&streak_key);
            self.nodes
                .get(&node)
                .ok_or_else(|| Error::Net(format!("no stream manager for node {node}")))?
                .topologies()
                .rescale(&frag_key, &stage, target)?;
            actions.push(PolicyAction::Rescale { topology: key, stage, parallelism: target });
        }
        // -- Placement: migrate when the cost model finds a clearly
        //    better host for a non-ingestion fragment.
        let profiles = self.stream_profiles();
        let heavy: Vec<&str> = policy.cpu_heavy.iter().map(String::as_str).collect();
        let keys: Vec<String> = self.streams.keys().cloned().collect();
        for key in keys {
            let Some(plan) = self.stream_placement(&key) else { continue };
            let Some(current) = policy.cost.plan_cost(&plan, &profiles, &heavy) else { continue };
            if let Some((c, f, target)) =
                dist::best_single_move(&policy.cost, &plan, &profiles, &heavy)
            {
                if current > 0.0 && (current - c) / current >= policy.migrate_min_gain {
                    self.stream_migrate(&key, f, target)?;
                    actions.push(PolicyAction::Migrate { topology: key, fragment: f, to: target });
                }
            }
        }
        Ok(actions)
    }

    /// Gracefully drain a node out of the cluster: every stream
    /// fragment it hosts is live-migrated to the best-cost surviving
    /// node (zero loss — the antithesis of [`Cluster::crash`], which
    /// stays lossy by design), the node is shut down (topologies
    /// stopped, queue and store flushed), and then removed from the
    /// overlay, federation map and network exactly like a crash. Fails
    /// — with the node still serving — when it hosts a fragment no
    /// surviving node can take.
    pub fn decommission(
        &mut self,
        id: NodeId,
        policy: &ClusterPolicy,
    ) -> Result<Vec<MigrationReport>> {
        if !self.nodes.contains_key(&id) {
            return Err(Error::NotFound(format!("no node {id}")));
        }
        let survivors: Vec<NodeId> =
            self.nodes.keys().copied().filter(|n| *n != id).collect();
        let profiles = self.stream_profiles();
        let heavy: Vec<&str> = policy.cpu_heavy.iter().map(String::as_str).collect();
        let mut reports = Vec::new();
        let keys: Vec<String> = self.streams.keys().cloned().collect();
        for key in keys {
            loop {
                let Some(plan) = self.stream_placement(&key) else { break };
                let Some(f) = plan.fragments.iter().position(|fr| fr.node == id) else { break };
                let best =
                    dist::best_host_for(&policy.cost, &plan, f, &survivors, &profiles, &heavy);
                let Some((_, to)) = best else {
                    return Err(Error::Net(format!(
                        "cannot decommission node {id}: no surviving node can host \
                         fragment #{f} of `{key}`"
                    )));
                };
                reports.push(self.stream_migrate(&key, f, to)?);
            }
        }
        self.nodes.get_mut(&id).expect("presence checked above").shutdown()?;
        self.crash(&id)?;
        Ok(reports)
    }

    // ---- Checkpoint/recovery plane (durable progress, crash failover) ----

    /// Open (or hand back) the cluster's durable checkpoint journal at
    /// `base_dir/ckpt`. Reopening after a process restart recovers
    /// every journaled record.
    fn open_checkpoint_journal(&mut self) -> Result<CheckpointJournal> {
        if let Some(j) = &self.ckpt_journal {
            return Ok(j.clone());
        }
        let j = CheckpointJournal::open(self.base_dir.join("ckpt"))?;
        self.ckpt_journal = Some(j.clone());
        Ok(j)
    }

    /// Opt the cluster into the durable journal without checkpointing
    /// any stream yet — federation registrations start journaling (and
    /// surviving node loss) from here. Returns `false` (no-op) when
    /// `RPULSAR_CHECKPOINT=off` disables the plane.
    pub fn enable_checkpoint_journal(&mut self) -> Result<bool> {
        if !checkpointing_enabled() {
            return Ok(false);
        }
        self.open_checkpoint_journal()?;
        Ok(true)
    }

    /// The journal handle, if the plane has been enabled (tests,
    /// benches, warm-pool snapshot seeding).
    pub fn checkpoint_journal(&self) -> Option<&CheckpointJournal> {
        self.ckpt_journal.as_ref()
    }

    /// Enable periodic checkpoints on a deployed stream: every
    /// `interval` input tuples an epoch barrier snapshots all fragment
    /// state plus the input cursor into the journal. Call right after
    /// [`Cluster::deploy_stream`], before the first feed — the
    /// write-ahead ingest log must see every batch the route sees.
    /// From here outputs are released only as their epoch commits (or
    /// at clean stop), and a node crash recovers exactly-once instead
    /// of losing the stream. Returns `false` (leaving the data path
    /// bit-for-bit unchanged) when `RPULSAR_CHECKPOINT=off`.
    pub fn enable_checkpoints(&mut self, key: &str, interval: u64) -> Result<bool> {
        if !checkpointing_enabled() {
            return Ok(false);
        }
        if !self.streams.contains_key(key) {
            return Err(Error::NotRunning(format!("stream topology `{key}`")));
        }
        let journal = self.open_checkpoint_journal()?;
        let route = self.streams.get_mut(key).expect("presence checked above");
        if route.checkpoint().is_some() {
            return Err(Error::Stream(format!("stream `{key}` is already checkpointed")));
        }
        route.set_checkpoint(Some(RouteCheckpoint::new(journal, interval)));
        Ok(true)
    }

    /// Run one epoch barrier over a checkpointed stream now (the
    /// periodic trigger calls this from the feed path when the
    /// interval elapses). See [`dist::checkpoint_route`].
    pub fn checkpoint_stream(&mut self, key: &str) -> Result<CheckpointReport> {
        let mut route = self.take_stream(key)?;
        let r = dist::checkpoint_route(self, &mut route);
        self.streams.insert(key.to_string(), route);
        r
    }

    /// Kill a node with crash semantics — no drain, no migration; the
    /// same lossy removal as [`Cluster::crash`] — but remember its
    /// identity so [`Cluster::restart_node`] can bring the member back
    /// and checkpointed streams can fail over.
    pub fn kill_node(&mut self, id: &NodeId) -> Result<()> {
        let node =
            self.nodes.get(id).ok_or_else(|| Error::NotFound(format!("no node {id}")))?;
        let identity = (node.name().to_string(), node.location());
        self.graveyard.insert(*id, identity);
        self.crash(id)
    }

    /// Rebuild a killed node as the same member: same name (hence the
    /// same [`NodeId`] and the same durable queue/store directories —
    /// `Node::new` namespaces them by name, so on-disk state is
    /// recovered), same location, re-registered with the network,
    /// overlay, routing tables and federation map. Journaled federation
    /// registrations are re-applied, so the restarted node resumes
    /// matching where the crashed one stopped.
    pub fn restart_node(&mut self, id: &NodeId) -> Result<()> {
        if self.nodes.contains_key(id) {
            return Err(Error::Stream(format!("node {id} is still a live member")));
        }
        let (name, loc) = self
            .graveyard
            .remove(id)
            .ok_or_else(|| Error::NotFound(format!("node {id} was never killed")))?;
        let mut cfg = crate::config::NodeConfig::default();
        cfg.name = name.clone();
        cfg.latitude = loc.lat;
        cfg.longitude = loc.lon;
        cfg.device = self.device;
        cfg.queue.dir = self.base_dir.join("queue");
        cfg.storage.dir = self.base_dir.join("store");
        let node = Node::new(cfg)?;
        self.quadtree.insert(*id, loc)?;
        self.network.register(*id, DeviceProfile::for_kind(self.device));
        self.network.bring_up(id);
        self.nodes.insert(*id, node);
        // Converged routing + mutual peer knowledge over the restored
        // membership, exactly as the constructor builds them.
        let ids: Vec<NodeId> = self.nodes.keys().copied().collect();
        self.tables = build_converged_tables(&ids, 8);
        for n in self.nodes.values_mut() {
            for &peer in &ids {
                if peer != n.id() {
                    n.learn_peer(peer);
                }
            }
        }
        self.fed_map.add(&name);
        if let Some(journal) = self.ckpt_journal.clone() {
            let regs = journal.registrations()?;
            let n = self.nodes.get_mut(id).expect("inserted above");
            for (consumer, profile, ttl_ms) in regs {
                let ttl = (ttl_ms > 0).then(|| Duration::from_millis(ttl_ms));
                n.apply_registration(&consumer, profile, ttl);
            }
        }
        Ok(())
    }

    /// Fail a checkpointed stream over after a node crash: re-home the
    /// dead hops onto the best-[`dist::PlacementCost`] survivors, roll
    /// *every* fragment back to the latest committed epoch (global
    /// rollback — survivors included, so no two fragments run in
    /// different epochs), and replay the write-ahead ingest log from
    /// the checkpointed cursor. Uncommitted outputs were discarded by
    /// the rollback and are regenerated by the replay; committed ones
    /// are never re-released — exactly-once end to end. Returns how
    /// many tuples were replayed; counted under `recovery.*`.
    pub fn recover_stream(&mut self, key: &str) -> Result<usize> {
        let mut route = self.take_stream(key)?;
        let r = self.recover_route(key, &mut route);
        self.streams.insert(key.to_string(), route);
        r
    }

    fn recover_route(&mut self, key: &str, route: &mut RouteState) -> Result<usize> {
        if route.checkpoint().is_none() {
            return Err(Error::Stream(format!(
                "stream `{key}` is not checkpointed (a crash is lossy without the \
                 checkpoint plane — see `Cluster::enable_checkpoints`)"
            )));
        }
        let pause_clock = Instant::now();
        // Single-thread the route; a fault the shipper recorded against
        // the dead node is expected and void — the rollback discards
        // everything uncommitted anyway.
        let _ = dist::halt_shipper(route);
        let record = route
            .checkpoint()
            .expect("checked above")
            .journal
            .latest(key)?
            .unwrap_or_else(|| CheckpointRecord {
                topology: key.to_string(),
                epoch: 0,
                cursor: 0,
                fragments: Vec::new(),
            });
        let survivors: Vec<NodeId> = self.nodes.keys().copied().collect();
        if survivors.is_empty() {
            return Err(Error::Net(format!(
                "cannot recover stream `{key}`: no surviving node"
            )));
        }
        // Re-place dead hops with the shared cost model. Dead hosts are
        // costed as uniform cluster devices so every candidate plan
        // stays rankable; recovery may move the ingestion fragment —
        // unlike a policy migrate, there is nothing left to pin it to.
        let plan = PlacementPlan {
            fragments: route
                .hops()
                .iter()
                .map(|h| Fragment { node: h.node, stages: h.specs.clone() })
                .collect(),
        };
        let mut profiles = self.stream_profiles();
        for h in route.hops() {
            profiles.entry(h.node).or_insert_with(|| DeviceProfile::for_kind(self.device));
        }
        let cost = dist::PlacementCost::default();
        let dead: Vec<usize> = route
            .hops()
            .iter()
            .enumerate()
            .filter(|(_, h)| !self.nodes.contains_key(&h.node))
            .map(|(f, _)| f)
            .collect();
        for f in dead {
            let to = dist::best_host_for(&cost, &plan, f, &survivors, &profiles, &[])
                .map(|(_, id)| id)
                .unwrap_or(survivors[0]);
            route.rehome_hop(f, to);
        }
        let restarted = dist::rollback_route(self, route, &record)?;
        {
            let ckpt = route.checkpoint_mut().expect("checked above");
            ckpt.pending.clear();
            ckpt.epoch = record.epoch;
            ckpt.cursor = record.cursor;
            // `input_seq` stays: the WAL writer (this process) survived
            // the node crash, so the in-memory log position is valid.
        }
        if self.async_net {
            dist::start_shipper(&*self, route)?;
        }
        // Replay the backlog from the checkpointed cursor — straight
        // into the route, never re-logged (the entries are already in
        // the WAL under their original sequence numbers).
        let batches =
            route.checkpoint().expect("checked above").journal.replay_input(key, record.cursor)?;
        let mut replayed = 0usize;
        for (_, batch) in batches {
            replayed += batch.len();
            if route.has_shipper() {
                dist::feed_route_async(&*self, route, batch)?;
            } else {
                dist::feed_route(&*self, route, batch)?;
            }
        }
        let pause = pause_clock.elapsed();
        self.metrics.counter("recovery.restarts").add(restarted as u64);
        self.metrics.counter("recovery.replayed_tuples").add(replayed as u64);
        self.metrics.counter("recovery.pause_ms").add(pause.as_millis() as u64);
        log::info!(
            "recovered stream `{key}` from epoch {} (cursor {}): {restarted} fragments \
             restarted, {replayed} tuples replayed, pause {pause:?}",
            record.epoch,
            record.cursor
        );
        Ok(replayed)
    }

    /// Housekeeping pass over every node: publishes each node's gauges
    /// into the cluster registry as `node.{name}.{gauge}` (the policy
    /// plane's cluster-wide view), then runs broker idle-topic
    /// retirement via [`Node::tick`] (nodes without a retire policy are
    /// no-ops). Called from the stream pump paths; safe to call any
    /// time. Returns `(node, retired topic)` pairs.
    pub fn tick(&mut self) -> Vec<(NodeId, String)> {
        let mut retired = Vec::new();
        for (id, node) in self.nodes.iter_mut() {
            node.publish_gauges(&self.metrics);
            match node.tick() {
                Ok(topics) => retired.extend(topics.into_iter().map(|t| (*id, t))),
                Err(e) => log::warn!("node {id} housekeeping tick: {e}"),
            }
        }
        // Failure detection for the checkpoint plane: a checkpointed
        // stream with a hop on a departed member fails over here (the
        // feed/pump paths also check, so whichever runs first wins).
        let orphaned: Vec<String> = self
            .streams
            .iter()
            .filter(|(_, st)| {
                st.checkpoint().is_some()
                    && st.hops().iter().any(|h| !self.nodes.contains_key(&h.node))
            })
            .map(|(k, _)| k.clone())
            .collect();
        for key in orphaned {
            if let Err(e) = self.recover_stream(&key) {
                log::warn!("stream `{key}` recovery from tick failed: {e}");
            }
        }
        retired
    }

    /// Tear a deployed stream down: halt its shipper (if any), then
    /// cascade-drain every fragment front-to-back (zero loss across
    /// node boundaries) and return the complete remaining output. A
    /// fault the shipper recorded wins. On a checkpointed stream the
    /// clean stop releases the gated outputs too (committed-but-unread
    /// first, then uncommitted, then the drain tail — input order) and
    /// retires the stream's journal state.
    pub fn stream_stop(&mut self, key: &str) -> Result<Vec<Tuple>> {
        let mut route = self.take_stream(key)?;
        let fault = dist::halt_shipper(&mut route);
        let gated = route.checkpoint_mut().map(|ckpt| {
            let mut head: Vec<Tuple> = ckpt.committed.drain(..).collect();
            head.append(&mut ckpt.pending);
            (head, ckpt.journal.clone())
        });
        let tail = dist::stop_route_seeded(self, route, fault)?;
        match gated {
            Some((mut head, journal)) => {
                journal.forget(key)?;
                head.extend(tail);
                Ok(head)
            }
            None => Ok(tail),
        }
    }

    /// Keys of deployed distributed streams.
    pub fn streams(&self) -> Vec<String> {
        self.streams.keys().cloned().collect()
    }

    /// Fragment route of a deployed stream (tests/inspection).
    pub fn stream_route(&self, key: &str) -> Option<&RouteState> {
        self.streams.get(key)
    }

    fn take_stream(&mut self, key: &str) -> Result<RouteState> {
        self.streams
            .remove(key)
            .ok_or_else(|| Error::NotRunning(format!("stream topology `{key}`")))
    }

    /// Shut every node down and remove scratch directories. Deployed
    /// streams are cascade-drained first (best-effort — their outputs
    /// are discarded; call [`Cluster::stream_stop`] to keep them).
    pub fn shutdown(mut self) -> Result<()> {
        for key in self.streams() {
            let _ = self.stream_stop(&key);
        }
        for node in self.nodes.values_mut() {
            node.shutdown()?;
        }
        let _ = std::fs::remove_dir_all(&self.base_dir);
        Ok(())
    }

    /// Resolve an AR message's profile to target RPs (content routing).
    fn resolve(&self, msg: &ArMessage) -> Result<Vec<NodeId>> {
        if self.nodes.is_empty() {
            return Err(Error::Overlay("empty cluster".into()));
        }
        let start = *self.nodes.keys().next().unwrap();
        let outcome = self.router.route(&msg.header.profile, &self.tables, start)?;
        Ok(outcome.targets)
    }

    /// Device kind the cluster runs as.
    pub fn device(&self) -> DeviceKind {
        self.device
    }
}

/// The cluster as a [`Deployer`] surface: the *same* `Pipeline` value
/// that runs in-process deploys split across the cluster's RP nodes —
/// placement planned from the builder's hints, fragments on each
/// node's own manager, hops charged to the simulated network. See
/// `docs/pipeline-api.md`.
impl Deployer for Cluster {
    fn surface(&self) -> &'static str {
        "cluster"
    }

    fn validate(&self, pipeline: &Pipeline) -> Result<()> {
        // A named stage resolves only when *every* node knows it:
        // placement decides the hosting node later, so a stage
        // registered on just some nodes would pass an any-node check
        // here and still fail at fragment start — violating the
        // reject-before-deploy contract. (Attached factories are
        // registered on every node by `deploy`, so they cannot
        // disagree either way.)
        pipeline.validate_resolved(|name| {
            let mut factories = self.nodes.values().map(|n| n.topologies().factory(name));
            let first = factories.next().flatten()?;
            if factories.all(|f| f.is_some()) {
                Some(first)
            } else {
                None
            }
        })
    }

    fn deploy(&mut self, pipeline: &Pipeline) -> Result<PipelineHandle> {
        Deployer::validate(self, pipeline)?;
        for s in pipeline.stages() {
            if let Some(f) = s.factory_ref() {
                for node in self.nodes.values_mut() {
                    node.topologies_mut().register_stage_factory(s.name(), f.clone());
                }
            }
        }
        let source = match pipeline.source_hint() {
            Some(node) if self.nodes.contains_key(&node) => node,
            Some(node) => {
                return Err(Error::Net(format!(
                    "pipeline `{}`: source hint {node} is not a cluster node",
                    pipeline.name()
                )))
            }
            None => *self
                .nodes
                .keys()
                .next()
                .ok_or_else(|| Error::Overlay("empty cluster".into()))?,
        };
        let profiles: BTreeMap<NodeId, DeviceProfile> = self
            .nodes
            .keys()
            .map(|id| (*id, DeviceProfile::for_kind(self.device)))
            .collect();
        let heavy: Vec<&str> =
            pipeline.cpu_heavy_hints().iter().map(String::as_str).collect();
        let plan = plan_placement(&pipeline.topology(), source, &profiles, &heavy)?;
        if pipeline.scale_policy().is_some() {
            log::warn!(
                "pipeline `{}`: ScalePolicy watchers are an in-process surface feature; \
                 cluster fragments rescale via Deployer::rescale",
                pipeline.name()
            );
        }
        self.deploy_stream(pipeline.name(), &pipeline.to_spec(), &plan)?;
        Ok(handle_for(pipeline, Deployer::surface(self)))
    }

    fn send_batch(&mut self, handle: &PipelineHandle, batch: Vec<Tuple>) -> Result<()> {
        self.stream_send_batch(handle.key(), batch)
    }

    fn poll(&mut self, handle: &PipelineHandle, max: usize) -> Result<Vec<Tuple>> {
        self.pump_stream_collect(handle.key(), max)
    }

    fn rescale(
        &mut self,
        handle: &PipelineHandle,
        stage: &str,
        parallelism: usize,
    ) -> Result<RescaleReport> {
        self.stream_rescale(handle.key(), stage, parallelism)
    }

    fn stop(&mut self, handle: &PipelineHandle) -> Result<Vec<Tuple>> {
        self.stream_stop(handle.key())
    }

    fn is_deployed(&self, handle: &PipelineHandle) -> bool {
        self.streams.contains_key(handle.key())
    }

    fn stage_factory(&self, name: &str) -> Option<StageFactory> {
        // All-nodes agreement, same reasoning as `validate`: a stage
        // known to only some nodes must not resolve — placement could
        // host the fragment anywhere.
        let mut factories = self.nodes.values().map(|n| n.topologies().factory(name));
        let first = factories.next().flatten()?;
        if factories.all(|f| f.is_some()) {
            Some(first)
        } else {
            None
        }
    }
}

/// The `RendezvousNetwork` view used by `ar::primitives::Client`.
impl RendezvousNetwork for Cluster {
    fn resolve(&self, msg: &ArMessage) -> Result<Vec<NodeId>> {
        Cluster::resolve(self, msg)
    }

    fn deliver(&mut self, target: NodeId, msg: &ArMessage) -> Result<Vec<Reaction>> {
        let wire = msg.encode().len() + 4;
        let origin = *self.nodes.keys().next().unwrap();
        self.network.charge_hop(&origin, &target, wire);
        self.nodes
            .get_mut(&target)
            .ok_or_else(|| Error::Overlay(format!("unknown target {target}")))?
            .handle_ar(msg)
    }

    fn fetch(&mut self, target: NodeId, msg: &ArMessage) -> Result<Vec<Vec<u8>>> {
        let node = self
            .nodes
            .get_mut(&target)
            .ok_or_else(|| Error::Overlay(format!("unknown target {target}")))?;
        let consumer = msg.header.sender.clone();
        node.broker_mut().subscribe(&consumer, msg.header.profile.clone());
        let msgs = node.broker_mut().fetch(&consumer, 1024)?;
        Ok(msgs.into_iter().map(|(_, m)| m.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::message::Action;
    use crate::ar::profile::Profile;

    fn store_msg(profile: &str, data: &[u8]) -> ArMessage {
        ArMessage::builder()
            .set_header(Profile::parse(profile).unwrap())
            .set_sender("test")
            .set_action(Action::Store)
            .set_data(data.to_vec())
            .build()
            .unwrap()
    }

    #[test]
    fn cluster_boots_n_nodes() {
        let c = Cluster::new("boot", 8, DeviceKind::Native).unwrap();
        assert_eq!(c.len(), 8);
        assert_eq!(c.quadtree().len(), 8);
        c.shutdown().unwrap();
    }

    #[test]
    fn post_stores_at_owner() {
        let mut c = Cluster::new("post", 8, DeviceKind::Native).unwrap();
        let origin = c.ids()[0];
        let results = c.post_from(origin, &store_msg("drone,lidar", b"img")).unwrap();
        assert_eq!(results.len(), 1);
        let owner = results[0].0;
        assert_eq!(
            c.node(&owner).unwrap().store().get(b"drone,lidar").unwrap(),
            Some(b"img".to_vec())
        );
        c.shutdown().unwrap();
    }

    #[test]
    fn replicated_store_survives_crash() {
        let mut c = Cluster::new("crash", 8, DeviceKind::Native).unwrap();
        let origin = c.ids()[0];
        let targets = c
            .store_replicated(origin, &store_msg("drone,lidar", b"precious"), 3)
            .unwrap();
        assert_eq!(targets.len(), 3);
        c.crash(&targets[0]).unwrap();
        let got = c.query_exact(origin, &Profile::parse("drone,lidar").unwrap()).unwrap();
        assert_eq!(got, Some(b"precious".to_vec()));
        c.shutdown().unwrap();
    }

    #[test]
    fn wildcard_query_spans_nodes() {
        let mut c = Cluster::new("wild", 8, DeviceKind::Native).unwrap();
        let origin = c.ids()[0];
        c.store_replicated(origin, &store_msg("alpha,lidar", b"1"), 2).unwrap();
        c.store_replicated(origin, &store_msg("beta,lidar", b"2"), 2).unwrap();
        c.store_replicated(origin, &store_msg("gamma,gps", b"3"), 2).unwrap();
        let hits = c.query_wildcard(origin, &Profile::parse("*,lidar").unwrap()).unwrap();
        assert_eq!(hits.len(), 2);
        c.shutdown().unwrap();
    }

    #[test]
    fn network_time_accumulates() {
        let mut c = Cluster::new("net", 4, DeviceKind::RaspberryPi).unwrap();
        let origin = c.ids()[0];
        // Several distinct profiles: at least one lands on a remote owner
        // (self-delivery legitimately costs no network time).
        for (i, p) in ["a,b", "zeta,x", "mid,y", "qrs,t", "other,w"].iter().enumerate() {
            c.post_from(origin, &store_msg(p, format!("v{i}").as_bytes())).unwrap();
        }
        assert!(c.network().messages() > 0);
        assert!(c.network().virtual_elapsed().as_micros() > 0);
        c.shutdown().unwrap();
    }

    #[test]
    fn election_after_master_crash() {
        let mut c = Cluster::new("elect", 9, DeviceKind::Native).unwrap();
        let region = c.quadtree().regions().next().unwrap();
        let master = c.quadtree().master_of(region).unwrap();
        c.crash(&master).unwrap();
        // Region may have changed shape after removal; elect on a region
        // that still has members.
        let region = c
            .quadtree()
            .regions()
            .find(|r| c.quadtree().members_of(*r).map(|m| !m.is_empty()).unwrap_or(false))
            .unwrap();
        let leader = c.elect_master(region).unwrap();
        assert_eq!(c.quadtree().master_of(region), Some(leader));
        c.shutdown().unwrap();
    }

    #[test]
    fn distributed_stream_spans_cluster_nodes() {
        use crate::stream::operator::OperatorKind;
        let mut c = Cluster::new("stream", 4, DeviceKind::Native).unwrap();
        let ids = c.ids();
        let (edge, core) = (ids[0], ids[1]);
        for id in [edge, core] {
            let topologies = c.node_mut(&id).unwrap().topologies_mut();
            topologies.register_stage("inc", || {
                Box::new(OperatorKind::map("inc", |mut t| {
                    let v = t.get("X").unwrap_or(0.0);
                    t.set("X", v + 1.0);
                    t
                }))
            });
            topologies.register_stage("sum", || {
                Box::new(OperatorKind::window_by("sum", "X", 2, "K"))
            });
        }
        let topo = Topology::parse("job", "inc->sum@K").unwrap();
        let plan = PlacementPlan::split_at(&topo, 1, edge, core);
        c.deploy_stream("job", "inc->sum@K", &plan).unwrap();
        assert_eq!(c.streams(), vec!["job"]);
        // Double-deploy is rejected without disturbing the instance.
        assert!(c.deploy_stream("job", "inc->sum@K", &plan).is_err());
        for i in 0..8u64 {
            c.stream_send(
                "job",
                Tuple::new(i, vec![]).with("K", (i % 2) as f64).with("X", 1.0),
            )
            .unwrap();
        }
        let out = c.stream_stop("job").unwrap();
        // 2 keys × 4 samples → two full windows of 2 per key.
        assert_eq!(out.len(), 4, "{out:?}");
        assert!(out.iter().all(|t| t.get("COUNT") == Some(2.0)), "{out:?}");
        assert!(c.network().messages() > 0, "cross-node hops must be charged");
        assert!(c.streams().is_empty());
        // The fragments are gone from the hosting nodes' managers.
        assert!(c.node(&edge).unwrap().topologies().running().is_empty());
        assert!(c.node(&core).unwrap().topologies().running().is_empty());
        c.shutdown().unwrap();
    }

    #[test]
    fn pipeline_deploys_via_cluster_surface() {
        use crate::stream::operator::OperatorKind;
        use crate::stream::pipeline::PipelineStage;
        let mut c = Cluster::new("psurf", 4, DeviceKind::Native).unwrap();
        let ids = c.ids();
        // Source ≠ the most capable node (uniform profiles tie-break to
        // the smallest id) → the planner splits at the cpu-heavy hint.
        let p = Pipeline::builder("job")
            .stage(PipelineStage::new("inc").operator(|| {
                Box::new(OperatorKind::map("inc", |mut t| {
                    let v = t.get("X").unwrap_or(0.0);
                    t.set("X", v + 1.0);
                    t
                }))
            }))
            .stage(PipelineStage::new("sum").parallel(2).keyed("K").operator(|| {
                Box::new(OperatorKind::window_by("sum", "X", 2, "K"))
            }))
            .cpu_heavy("sum")
            .source(ids[1])
            .build()
            .unwrap();
        Deployer::validate(&c, &p).unwrap();
        let h = c.deploy(&p).unwrap();
        assert_eq!(h.surface(), "cluster");
        assert!(Deployer::is_deployed(&c, &h));
        for i in 0..8u64 {
            Deployer::send(
                &mut c,
                &h,
                Tuple::new(i, vec![]).with("K", (i % 2) as f64).with("X", 1.0),
            )
            .unwrap();
        }
        let polled = Deployer::poll(&mut c, &h, 1024).unwrap();
        let rest = Deployer::stop(&mut c, &h).unwrap();
        // 2 keys × 4 samples → two full windows of 2 per key.
        assert_eq!(polled.len() + rest.len(), 4);
        assert!(c.network().messages() > 0, "split placement must cross the network");
        assert!(!Deployer::is_deployed(&c, &h));
        // A bad source hint is rejected before anything starts.
        let ghost = Pipeline::builder("g")
            .stage(PipelineStage::new("inc"))
            .source(NodeId::from_name("nowhere"))
            .build()
            .unwrap();
        assert!(c.deploy(&ghost).is_err());
        assert!(c.streams().is_empty());
        c.shutdown().unwrap();
    }

    #[test]
    fn cluster_tick_retires_idle_topics_on_opted_in_nodes() {
        use crate::mmq::pubsub::RetirePolicy;
        use std::time::Duration;
        let mut c = Cluster::new("tick", 2, DeviceKind::Native).unwrap();
        let ids = c.ids();
        let p = Profile::parse("sensor,temp").unwrap();
        c.node_mut(&ids[0]).unwrap().publish(&p, b"x").unwrap();
        // No policy anywhere: the housekeeping pass is a no-op.
        assert!(c.tick().is_empty());
        c.node_mut(&ids[0]).unwrap().set_retire_policy(Some(RetirePolicy {
            max_publish_idle: Duration::ZERO,
            max_fetch_idle: Duration::ZERO,
            min_age: Duration::ZERO,
        }));
        let retired = c.tick();
        assert_eq!(retired, vec![(ids[0], "sensor,temp".to_string())]);
        c.shutdown().unwrap();
    }

    #[test]
    fn trigger_bindings_ride_the_cluster_tick() {
        use crate::mmq::pubsub::RetirePolicy;
        use crate::stream::operator::OperatorKind;
        use std::time::Duration;
        let mut c = Cluster::new("ctrig", 3, DeviceKind::Native).unwrap();
        let ids = c.ids();
        let host = ids[1];
        c.node_mut(&host).unwrap().topologies_mut().register_stage("inc", || {
            Box::new(OperatorKind::map("inc", |mut t| {
                let v = t.get("X").unwrap_or(0.0);
                t.set("X", v + 1.0);
                t
            }))
        });
        let eager = TriggerOptions {
            idle: RetirePolicy {
                max_publish_idle: Duration::ZERO,
                max_fetch_idle: Duration::ZERO,
                min_age: Duration::ZERO,
            },
            decode_payloads: true,
            tenant: None,
        };
        c.bind_trigger(
            &host,
            Pipeline::parse("incjob", "inc").unwrap(),
            Profile::parse("drone,*").unwrap(),
            eager,
        )
        .unwrap();
        c.node_mut(&host)
            .unwrap()
            .publish(
                &Profile::parse("drone,lidar").unwrap(),
                &Tuple::new(0, vec![]).with("X", 1.0).encode(),
            )
            .unwrap();
        // The cluster's housekeeping pass activates, feeds and (after
        // the backlog drains) decommissions — no external pump loop.
        for _ in 0..200 {
            c.tick();
            let active = c.node(&host).unwrap().triggers().is_active("incjob");
            let stats = c.trigger_stats(&host, "incjob").unwrap();
            if !active && stats.activations > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let stats = c.trigger_stats(&host, "incjob").unwrap();
        assert_eq!(stats.activations, 1);
        assert_eq!(stats.tuples_fed, 1);
        let out = c.trigger_outputs(&host, "incjob");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(2.0));
        assert!(c.unbind_trigger(&host, "incjob").unwrap().is_empty());
        c.shutdown().unwrap();
    }

    #[test]
    fn federated_subscribe_publish_fetch_lifecycle() {
        use std::time::Duration;
        let mut c = Cluster::new("fed", 4, DeviceKind::Native).unwrap();
        let ids = c.ids();
        let origin = ids[0];
        let watch = Profile::parse("drone,*").unwrap();
        c.federated_subscribe(origin, "watch", &watch, None).unwrap();
        for id in &ids {
            assert!(c.node(id).unwrap().is_registered("watch"), "registered at every node");
        }
        assert!(c.network().messages() > 0, "register forwarding must be charged");
        // Publishes land on their HRW owners; one fetch drains them all.
        let mut owners = std::collections::BTreeSet::new();
        for i in 0..12 {
            let p = Profile::parse(&format!("drone,cam{i:02}")).unwrap();
            let (owner, _) =
                c.federated_publish(origin, &p, format!("f{i}").as_bytes()).unwrap();
            owners.insert(owner);
        }
        assert!(owners.len() > 1, "12 topics should spread over >1 of 4 nodes: {owners:?}");
        assert_eq!(c.federated_fetch(origin, "watch", 1024).unwrap().len(), 12);
        // TTL lifecycle: a zero TTL expires on the next housekeeping tick…
        c.federated_subscribe(origin, "ephemeral", &watch, Some(Duration::ZERO)).unwrap();
        c.tick();
        assert!(ids.iter().all(|id| !c.node(id).unwrap().is_registered("ephemeral")));
        assert!(c.federated_fetch(origin, "ephemeral", 16).is_err(), "swept everywhere");
        // …and a post-expiry re-register is a fresh subscription that
        // replays the retained backlog (at-least-once).
        c.federated_subscribe(origin, "ephemeral", &watch, Some(Duration::from_secs(3600)))
            .unwrap();
        assert_eq!(c.federated_fetch(origin, "ephemeral", 1024).unwrap().len(), 12);
        assert!(c.federated_unsubscribe(origin, "ephemeral").unwrap());
        assert!(c.federated_fetch(origin, "ephemeral", 16).is_err());
        c.shutdown().unwrap();
    }

    #[test]
    fn federated_retire_sweeps_all_nodes_after_churn() {
        let mut c = Cluster::new("fedret", 4, DeviceKind::Native).unwrap();
        let origin = c.ids()[0];
        let watch = Profile::parse("sensor,*").unwrap();
        c.federated_subscribe(origin, "watch", &watch, None).unwrap();
        let p = Profile::parse("sensor,temp").unwrap();
        let (owner, _) = c.federated_publish(origin, &p, b"v").unwrap();
        // Churn: crash a bystander — some keys' ownership moves, but
        // `sensor,temp`'s queue stays where it was published.
        let victim = *c.ids().iter().find(|id| **id != owner && **id != origin).unwrap();
        c.crash(&victim).unwrap();
        assert_eq!(c.federation_map().len(), 3, "crashed node left the HRW map");
        // The all-node retire drops the queue and every broker's
        // match-cache entry for the topic, wherever they live.
        assert!(c.federated_retire(&p).unwrap());
        assert!(!c.federated_retire(&p).unwrap(), "second sweep finds nothing");
        assert!(c.federated_fetch(origin, "watch", 16).unwrap().is_empty());
        c.shutdown().unwrap();
    }

    #[test]
    fn crash_unknown_node_errors() {
        let mut c = Cluster::new("unknown", 2, DeviceKind::Native).unwrap();
        assert!(c.crash(&NodeId::from_name("ghost")).is_err());
        c.shutdown().unwrap();
    }

    /// Register the inc/sum test stages on every node, so any node can
    /// host (or receive a migrated) fragment.
    fn register_stream_stages(c: &mut Cluster) {
        use crate::stream::operator::OperatorKind;
        for id in c.ids() {
            let topologies = c.node_mut(&id).unwrap().topologies_mut();
            topologies.register_stage("inc", || {
                Box::new(OperatorKind::map("inc", |mut t| {
                    let v = t.get("X").unwrap_or(0.0);
                    t.set("X", v + 1.0);
                    t
                }))
            });
            topologies.register_stage("sum", || {
                Box::new(OperatorKind::window_by("sum", "X", 2, "K"))
            });
        }
    }

    #[test]
    fn stream_migration_moves_fragment_between_cluster_nodes() {
        let mut c = Cluster::new("mig", 4, DeviceKind::Native).unwrap();
        register_stream_stages(&mut c);
        let ids = c.ids();
        let (edge, core, spare) = (ids[0], ids[1], ids[2]);
        let topo = Topology::parse("job", "inc->sum@K").unwrap();
        c.deploy_stream("job", "inc->sum@K", &PlacementPlan::split_at(&topo, 1, edge, core))
            .unwrap();
        // Half-fill both per-key windows across the node boundary.
        for k in 0..2u64 {
            c.stream_send("job", Tuple::new(k, vec![]).with("K", k as f64).with("X", 1.0))
                .unwrap();
        }
        let report = c.stream_migrate("job", 1, spare).unwrap();
        assert_eq!((report.from, report.to), (core, spare));
        assert!(report.moved_keys <= 2, "{report:?}");
        let route = c.stream_route("job").unwrap();
        assert_eq!(route.hops()[1].node, spare);
        assert_eq!(route.migrations().len(), 1);
        assert_eq!(c.stream_metrics().counter("net.migration.completed").get(), 1);
        // The old host no longer runs the fragment; the new one does.
        assert!(c.node(&core).unwrap().topologies().running().is_empty());
        assert_eq!(c.node(&spare).unwrap().topologies().running(), vec!["job#f1"]);
        // Second halves land on the new host: both windows complete.
        for k in 0..2u64 {
            c.stream_send("job", Tuple::new(2 + k, vec![]).with("K", k as f64).with("X", 1.0))
                .unwrap();
        }
        let out = c.stream_stop("job").unwrap();
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().all(|t| t.get("COUNT") == Some(2.0)), "{out:?}");
        c.shutdown().unwrap();
    }

    #[test]
    fn policy_tick_rescales_from_node_gauges_and_exports_them() {
        let mut c = Cluster::new("cpol", 2, DeviceKind::Native).unwrap();
        register_stream_stages(&mut c);
        let ids = c.ids();
        let host = ids[0];
        let topo = Topology::parse("job", "inc").unwrap();
        c.deploy_stream("job", "inc", &PlacementPlan::single(host, &topo)).unwrap();
        let policy = ClusterPolicy { high_depth: 8, sustain: 2, ..ClusterPolicy::default() };
        // Backlog appears in the *hosting node's* registry — where the
        // engine's depth gauges actually live.
        c.node(&host).unwrap().metrics().gauge("stream.job#f0.inc.in.depth").set(50);
        assert!(c.stream_policy_tick(&policy).unwrap().is_empty(), "sustain debounces");
        // The tick's housekeeping pass published the node's gauges
        // cluster-wide under a node.{name} prefix.
        let exported = format!(
            "node.{}.stream.job#f0.inc.in.depth",
            c.node(&host).unwrap().name()
        );
        assert_eq!(c.stream_metrics().gauge(&exported).get(), 50);
        let actions = c.stream_policy_tick(&policy).unwrap();
        assert_eq!(
            actions,
            vec![PolicyAction::Rescale {
                topology: "job".to_string(),
                stage: "inc".to_string(),
                parallelism: 2
            }]
        );
        assert_eq!(
            c.node(&host).unwrap().topologies().parallelism("job#f0", "inc").unwrap(),
            2
        );
        // Uniform profiles: the placement pass never finds a gain.
        c.node(&host).unwrap().metrics().gauge("stream.job#f0.inc.in.depth").set(4);
        assert!(c.stream_policy_tick(&policy).unwrap().is_empty());
        c.stream_stop("job").unwrap();
        c.shutdown().unwrap();
    }

    #[test]
    fn decommission_relocates_stream_fragments_then_removes_node() {
        let mut c = Cluster::new("decom", 4, DeviceKind::Native).unwrap();
        register_stream_stages(&mut c);
        let ids = c.ids();
        let (edge, core) = (ids[0], ids[1]);
        let topo = Topology::parse("job", "inc->sum@K").unwrap();
        c.deploy_stream("job", "inc->sum@K", &PlacementPlan::split_at(&topo, 1, edge, core))
            .unwrap();
        for k in 0..2u64 {
            c.stream_send("job", Tuple::new(k, vec![]).with("K", k as f64).with("X", 1.0))
                .unwrap();
        }
        let policy = ClusterPolicy::default();
        let reports = c.decommission(core, &policy).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].from, core);
        assert_eq!(c.len(), 3);
        assert!(c.node(&core).is_none());
        assert!(!c.network().is_reachable(&core));
        let new_host = c.stream_route("job").unwrap().hops()[1].node;
        assert_ne!(new_host, core, "fragment re-homed before the node left");
        for k in 0..2u64 {
            c.stream_send("job", Tuple::new(2 + k, vec![]).with("K", k as f64).with("X", 1.0))
                .unwrap();
        }
        let out = c.stream_stop("job").unwrap();
        assert_eq!(out.len(), 2, "windows opened pre-leave complete: {out:?}");
        assert!(out.iter().all(|t| t.get("COUNT") == Some(2.0)), "{out:?}");
        // Unknown node refuses.
        assert!(c.decommission(NodeId::from_name("ghost"), &policy).is_err());
        c.shutdown().unwrap();
    }

    #[test]
    fn federation_frames_apply_over_live_tcp() {
        use std::time::Duration;
        let mut c = Cluster::new("fedtcp", 3, DeviceKind::Native).unwrap();
        let ep = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.local_addr().to_string();
        // An external registrant (not a cluster member) registers over
        // the real wire; the drained frame applies at every node.
        let watch = Profile::parse("drone,*").unwrap();
        TcpEndpoint::send_to(
            &addr,
            &NetMessage::Register {
                from: NodeId::from_name("external-client"),
                consumer: "watch".to_string(),
                profile: watch.clone(),
                ttl_ms: 0,
            },
        )
        .unwrap();
        assert_eq!(c.drain_federation(&ep, Duration::from_secs(2)).unwrap(), 1);
        for id in c.ids() {
            assert!(c.node(&id).unwrap().is_registered("watch"));
        }
        // The registration is live: a publish is fetchable.
        let origin = c.ids()[0];
        c.federated_publish(origin, &Profile::parse("drone,cam").unwrap(), b"f").unwrap();
        assert_eq!(c.federated_fetch(origin, "watch", 16).unwrap().len(), 1);
        // Unregister over the same wire withdraws it everywhere.
        TcpEndpoint::send_to(
            &addr,
            &NetMessage::Unregister {
                from: NodeId::from_name("external-client"),
                consumer: "watch".to_string(),
            },
        )
        .unwrap();
        assert_eq!(c.drain_federation(&ep, Duration::from_secs(2)).unwrap(), 1);
        assert!(c.ids().iter().all(|id| !c.node(id).unwrap().is_registered("watch")));
        // Non-federation frames are rejected by the applier.
        assert!(c
            .apply_federation_frame(NetMessage::Ping { from: origin })
            .is_err());
        ep.shutdown();
        c.shutdown().unwrap();
    }

    #[test]
    fn checkpointed_stream_survives_node_kill_exactly_once() {
        if !checkpointing_enabled() {
            return; // RPULSAR_CHECKPOINT=off A/B arm: the plane is a no-op.
        }
        let mut c = Cluster::new("ckpt", 4, DeviceKind::Native).unwrap();
        register_stream_stages(&mut c);
        let ids = c.ids();
        let (edge, core) = (ids[0], ids[1]);
        let topo = Topology::parse("job", "inc->sum@K").unwrap();
        c.deploy_stream("job", "inc->sum@K", &PlacementPlan::split_at(&topo, 1, edge, core))
            .unwrap();
        assert!(c.enable_checkpoints("job", 4).unwrap());
        assert!(c.enable_checkpoints("job", 4).is_err(), "double enable refuses");
        for i in 0..8u64 {
            c.stream_send("job", Tuple::new(i, vec![]).with("K", (i % 2) as f64).with("X", 1.0))
                .unwrap();
        }
        assert!(c.stream_metrics().counter("ckpt.epochs").get() >= 1, "interval 4 must fire");
        // Kill-9 the tail fragment's host mid-stream: no drain, no
        // goodbye. The next feed detects the dead hop, fails over to a
        // survivor, rolls back to the last epoch and replays the WAL.
        c.kill_node(&core).unwrap();
        for i in 8..16u64 {
            c.stream_send("job", Tuple::new(i, vec![]).with("K", (i % 2) as f64).with("X", 1.0))
                .unwrap();
        }
        assert!(c.stream_metrics().counter("recovery.restarts").get() >= 1);
        let route = c.stream_route("job").unwrap();
        assert!(route.hops().iter().all(|h| h.node != core), "dead hop re-homed");
        // Exactly-once: 16 tuples over 2 keys with window 2 make
        // exactly 8 complete windows — no loss, no duplicates — same
        // multiset an uncrashed run produces.
        let mut out = c.stream_pump("job").unwrap();
        out.extend(c.stream_stop("job").unwrap());
        assert_eq!(out.len(), 8, "{out:?}");
        assert!(out.iter().all(|t| t.get("COUNT") == Some(2.0)), "{out:?}");
        c.shutdown().unwrap();
    }

    #[test]
    fn restart_node_rejoins_and_reapplies_journaled_registrations() {
        let mut c = Cluster::new("restart", 3, DeviceKind::Native).unwrap();
        let journaled = c.enable_checkpoint_journal().unwrap();
        let ids = c.ids();
        let (origin, victim) = (ids[0], ids[2]);
        let watch = Profile::parse("drone,*").unwrap();
        c.federated_subscribe(origin, "watch", &watch, None).unwrap();
        c.kill_node(&victim).unwrap();
        assert_eq!(c.len(), 2);
        c.restart_node(&victim).unwrap();
        assert_eq!(c.len(), 3);
        assert_eq!(c.federation_map().len(), 3, "restarted member rejoins the HRW map");
        if journaled {
            // Satellite contract: the fresh node resumes matching where
            // the crashed one stopped — from the journal, not gossip.
            assert!(c.node(&victim).unwrap().is_registered("watch"));
        }
        // A live member is not restartable; neither is a stranger.
        assert!(c.restart_node(&victim).is_err());
        assert!(c.restart_node(&NodeId::from_name("ghost")).is_err());
        c.shutdown().unwrap();
    }
}
