//! One Rendezvous Point: the composition of overlay membership, content
//! routing, the AR matching engine, the mmap broker, the storage shard
//! and the topology manager (paper §IV-E "Implementation Overview").

use crate::ar::message::ArMessage;
use crate::ar::rendezvous::{Reaction, RendezvousPoint};
use crate::config::NodeConfig;
use crate::device::profile::DeviceProfile;
use crate::device::throttle::{ClockMode, ThrottledDisk};
use crate::error::Result;
use crate::metrics::Registry;
use crate::mmq::pubsub::{Broker, RetirePolicy};
use crate::mmq::queue::QueueOptions;
use crate::overlay::geo::GeoPoint;
use crate::overlay::node_id::NodeId;
use crate::overlay::ring::{Contact, RoutingTable};
use crate::pipeline::trigger::{TriggerManager, TriggerOptions};
use crate::storage::lsm::{LsmOptions, LsmStore};
use crate::stream::deploy::TopologyManager;
use crate::stream::engine::StreamEngine;
use crate::stream::pipeline::Pipeline;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A running RP node (in-process flavour; the `rpulsar node` binary
/// wraps one of these behind a TCP endpoint).
pub struct Node {
    config: NodeConfig,
    id: NodeId,
    location: GeoPoint,
    routing_table: RoutingTable,
    rendezvous: RendezvousPoint,
    broker: Broker,
    store: LsmStore,
    /// The trigger plane wrapping this node's topology manager: every
    /// deployed topology (AR-started or trigger-activated) runs on the
    /// same in-process executor; trigger bindings additionally scale
    /// to zero and back as data arrives ([`Node::bind_trigger`]).
    triggers: TriggerManager<TopologyManager>,
    metrics: Registry,
    device: ThrottledDisk,
    /// Broker topic-retirement policy swept by [`Node::tick`]. `None`
    /// (the default) disables retirement — a node only reclaims topics
    /// once an operator opts in with [`Node::set_retire_policy`].
    retire_policy: Option<RetirePolicy>,
    /// Federated subscription registrations (libp2p rendezvous idiom:
    /// peers register their consumers here with a TTL). Keyed by
    /// consumer name; the broker holds the matching subscription, this
    /// map holds the TTL watermark [`Node::tick`] sweeps. `None` TTL
    /// never expires.
    registrations: BTreeMap<String, (Option<Duration>, Instant)>,
}

impl Node {
    /// Build a node from config. Directories are namespaced by node name
    /// so multiple in-process nodes don't collide.
    pub fn new(config: NodeConfig) -> Result<Self> {
        config.validate()?;
        let id = NodeId::from_name(&config.name);
        let location = GeoPoint::new(config.latitude, config.longitude);
        let metrics = Registry::new();
        let device =
            ThrottledDisk::new(DeviceProfile::for_kind(config.device), ClockMode::Virtual);

        let queue_opts = QueueOptions {
            dir: config.queue.dir.join(&config.name),
            segment_bytes: config.queue.segment_bytes,
            max_segments: config.queue.max_segments,
            sync_every: config.queue.sync_every,
        };
        let broker = Broker::with_metrics(queue_opts, metrics.clone());

        let lsm_opts = LsmOptions {
            dir: config.storage.dir.join(&config.name),
            memtable_bytes: config.storage.memtable_bytes,
            bloom_bits_per_key: config.storage.bloom_bits_per_key,
            max_tables: 6,
        };
        let store = LsmStore::open(lsm_opts, device.clone())?;

        let triggers = TriggerManager::with_metrics(
            TopologyManager::new(StreamEngine::with_metrics(metrics.clone())),
            metrics.clone(),
        );

        Ok(Node {
            config,
            id,
            location,
            routing_table: RoutingTable::new(id, 8),
            rendezvous: RendezvousPoint::with_metrics(metrics.clone()),
            broker,
            store,
            triggers,
            metrics,
            device,
            retire_policy: None,
            registrations: BTreeMap::new(),
        })
    }

    /// Convenience constructor for tests/clusters.
    pub fn with_name_at(name: &str, lat: f64, lon: f64, base_dir: &std::path::Path) -> Result<Self> {
        let mut cfg = NodeConfig::default();
        cfg.name = name.to_string();
        cfg.latitude = lat;
        cfg.longitude = lon;
        cfg.queue.dir = base_dir.join("queue");
        cfg.storage.dir = base_dir.join("store");
        Self::new(cfg)
    }

    pub fn id(&self) -> NodeId {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.config.name
    }

    pub fn location(&self) -> GeoPoint {
        self.location
    }

    pub fn config(&self) -> &NodeConfig {
        &self.config
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn device(&self) -> &ThrottledDisk {
        &self.device
    }

    /// Seed the routing table with a peer (join / stabilisation).
    pub fn learn_peer(&mut self, id: NodeId) {
        self.routing_table.insert(Contact::new(id));
    }

    /// Forget a failed peer.
    pub fn forget_peer(&mut self, id: &NodeId) {
        self.routing_table.remove(id);
    }

    pub fn routing_table(&self) -> &RoutingTable {
        &self.routing_table
    }

    /// The node's bucket size config.
    pub fn bucket_size(&self) -> usize {
        self.config.bucket_size
    }

    /// Handle an AR message addressed to this RP: run the matching
    /// engine, apply storage-affecting reactions locally, and return all
    /// reactions for the caller (cluster/transport) to propagate.
    pub fn handle_ar(&mut self, msg: &ArMessage) -> Result<Vec<Reaction>> {
        let reactions = self.rendezvous.receive(msg)?;
        let mut notified = false;
        for r in &reactions {
            match r {
                Reaction::Stored { profile } => {
                    // Persist to the local shard (DHT replication is the
                    // cluster's job — it posts to each replica).
                    self.store.put(profile.render().as_bytes(), &msg.data)?;
                    self.metrics.counter("node.stored").inc();
                }
                Reaction::StartTopology { function_profile, topology } => {
                    let key = function_profile.render();
                    let topologies = self.triggers.deployer_mut();
                    if !topologies.running().contains(&key) {
                        topologies.start(&key, topology)?;
                        self.metrics.counter("node.topologies_started").inc();
                    }
                }
                Reaction::StopTopology { function_profile } => {
                    let key = function_profile.render();
                    let topologies = self.triggers.deployer_mut();
                    if topologies.running().contains(&key) {
                        topologies.stop(&key)?;
                    }
                }
                Reaction::ConsumerNotified { .. } => notified = true,
                _ => {}
            }
        }
        // Data reached a consumer: give the trigger plane a pass right
        // away instead of waiting for the next housekeeping tick —
        // this is what activates bound pipelines at data-arrival
        // latency on an AR-driven node. Trigger faults are the
        // bindings' problem (counted + logged), not the AR path's.
        if notified && !self.triggers.bound().is_empty() {
            let name = self.config.name.clone();
            let Node { triggers, broker, .. } = self;
            if let Err(e) = triggers.pump(broker) {
                log::warn!("node {name}: trigger pump: {e}");
            }
        }
        Ok(reactions)
    }

    /// Publish to the node's mmap broker (`push` primitive data path).
    pub fn publish(&mut self, profile: &crate::ar::profile::Profile, payload: &[u8]) -> Result<u64> {
        self.broker.publish(profile, payload)
    }

    /// Broker access (subscriptions, fetch).
    pub fn broker_mut(&mut self) -> &mut Broker {
        &mut self.broker
    }

    /// Local storage shard access.
    pub fn store(&self) -> &LsmStore {
        &self.store
    }

    pub fn store_mut(&mut self) -> &mut LsmStore {
        &mut self.store
    }

    /// Topology manager access (stage registration).
    pub fn topologies_mut(&mut self) -> &mut TopologyManager {
        self.triggers.deployer_mut()
    }

    /// Shared topology-manager access: feeding, non-blocking egress /
    /// ingress polling of deployed fragments (`send`/`poll_outputs`/
    /// `try_send_batch` all take `&self`) — what the cluster's
    /// cross-node stage hops drive.
    pub fn topologies(&self) -> &TopologyManager {
        self.triggers.deployer()
    }

    /// The node's trigger plane: bindings, stats, admission and
    /// warm-pool knobs.
    pub fn triggers(&self) -> &TriggerManager<TopologyManager> {
        &self.triggers
    }

    pub fn triggers_mut(&mut self) -> &mut TriggerManager<TopologyManager> {
        &mut self.triggers
    }

    /// Bind `pipeline` to `profile` on this node's broker: matching
    /// data arriving here (published locally or routed in by the
    /// cluster) activates the pipeline on demand, and the node's own
    /// [`Node::tick`] / AR reaction path pumps the lifecycle — no
    /// external pump loop needed.
    pub fn bind_trigger(
        &mut self,
        pipeline: Pipeline,
        profile: crate::ar::profile::Profile,
        opts: TriggerOptions,
    ) -> Result<()> {
        let Node { triggers, broker, .. } = self;
        triggers.bind(broker, pipeline, profile, opts)
    }

    /// Remove a trigger binding; returns its untaken outputs.
    pub fn unbind_trigger(&mut self, name: &str) -> Result<Vec<crate::stream::tuple::Tuple>> {
        let Node { triggers, broker, .. } = self;
        triggers.unbind(broker, name)
    }

    /// One explicit trigger pass (tests/benches; [`Node::tick`] and
    /// the AR reaction path call this implicitly).
    pub fn pump_triggers(&mut self) -> Result<()> {
        let Node { triggers, broker, .. } = self;
        triggers.pump(broker)
    }

    /// Rendezvous state access (tests).
    pub fn rendezvous(&self) -> &RendezvousPoint {
        &self.rendezvous
    }

    /// Opt the node's broker into idle-topic retirement: [`Node::tick`]
    /// sweeps every topic through `policy` (see
    /// [`Broker::retire_idle`]). `None` disables the sweep again.
    pub fn set_retire_policy(&mut self, policy: Option<RetirePolicy>) {
        self.retire_policy = policy;
    }

    /// The active retirement policy, if any.
    pub fn retire_policy(&self) -> Option<&RetirePolicy> {
        self.retire_policy.as_ref()
    }

    /// Apply a federated subscription registration (a local bind or a
    /// peer's forwarded `NetMessage::Register`): subscribe `consumer`
    /// on the broker and start the TTL watermark. Re-applying replaces
    /// the subscription (the broker preserves cursors of topics that
    /// still match) and restarts the watermark — the register →
    /// expire → re-register lifecycle. `None` never expires; a TTL of
    /// [`Duration::ZERO`] expires on the next [`Node::tick`] (the
    /// test idiom — no clock mocking needed).
    pub fn apply_registration(
        &mut self,
        consumer: &str,
        profile: crate::ar::profile::Profile,
        ttl: Option<Duration>,
    ) {
        self.broker.subscribe(consumer, profile);
        self.registrations.insert(consumer.to_string(), (ttl, Instant::now()));
        self.metrics.counter("node.registrations").inc();
    }

    /// Withdraw a federated registration (`NetMessage::Unregister`)
    /// before its TTL lapses. Returns whether it existed here.
    pub fn remove_registration(&mut self, consumer: &str) -> bool {
        if self.registrations.remove(consumer).is_none() {
            return false;
        }
        self.broker.unsubscribe(consumer);
        true
    }

    /// Whether `consumer` holds a live federated registration here.
    pub fn is_registered(&self, consumer: &str) -> bool {
        self.registrations.contains_key(consumer)
    }

    /// Live federated registrations, sorted by consumer name.
    pub fn registrations(&self) -> Vec<&str> {
        self.registrations.keys().map(String::as_str).collect()
    }

    /// Housekeeping tick (called from the cluster's pump paths, or by
    /// whatever loop owns a standalone node): sweeps the broker's
    /// topics through the retirement policy, reclaiming queues, disk
    /// segments and match-cache entries of idle topics. Returns the
    /// retired topic keys; a node without a policy does nothing.
    ///
    /// Retirement is *retention*, not delivery: a topic idle past both
    /// watermarks is dropped together with any unfetched backlog and
    /// its cursors (the broker's documented `retire_topic` semantics).
    /// Active consumers are safe — every `fetch` refreshes the
    /// `last_fetch` watermark of all its matched topics, empty or not
    /// — so pick `max_fetch_idle` comfortably above the slowest
    /// consumer's poll cadence (e.g. a trigger binding's pump loop)
    /// before opting a node in.
    pub fn tick(&mut self) -> Result<Vec<String>> {
        // TTL sweep of federated registrations first (independent of the
        // retire policy): an expired consumer must stop matching before
        // anything else observes the broker this tick.
        let now = Instant::now();
        let expired: Vec<String> = self
            .registrations
            .iter()
            .filter(|(_, (ttl, at))| {
                ttl.is_some_and(|t| now.saturating_duration_since(*at) >= t)
            })
            .map(|(c, _)| c.clone())
            .collect();
        for consumer in &expired {
            self.registrations.remove(consumer);
            self.broker.unsubscribe(consumer);
        }
        if !expired.is_empty() {
            self.metrics.counter("node.regs_expired").add(expired.len() as u64);
        }
        // Pump the trigger plane every tick: activates bindings whose
        // topics accumulated backlog, feeds live ones, decommissions
        // past the idle watermark. Faults are per-binding (counted in
        // `trigger.faults`), never a tick failure.
        if !self.triggers.bound().is_empty() {
            let name = self.config.name.clone();
            let Node { triggers, broker, .. } = self;
            if let Err(e) = triggers.pump(broker) {
                log::warn!("node {name}: trigger pump: {e}");
            }
        }
        let Some(policy) = self.retire_policy.clone() else {
            return Ok(Vec::new());
        };
        let retired = self.broker.retire_idle(&policy)?;
        if !retired.is_empty() {
            self.metrics.counter("node.tick_topics_retired").add(retired.len() as u64);
        }
        Ok(retired)
    }

    /// Export every gauge of this node's private registry into `into`,
    /// re-keyed as `node.{name}.{gauge}`. The cluster calls this from
    /// its tick so cluster-level observers (the policy plane, bench
    /// probes) see per-node stream depths without reaching into each
    /// node's registry — the per-node registries stay the only writers.
    pub fn publish_gauges(&self, into: &Registry) {
        for (name, value) in self.metrics.gauges_with_prefix("") {
            into.gauge(&format!("node.{}.{name}", self.config.name)).set(value);
        }
    }

    /// Graceful shutdown: decommission trigger activations and drain
    /// warm pools, stop topologies, flush queue + store.
    pub fn shutdown(&mut self) -> Result<()> {
        self.triggers.decommission_all()?;
        self.triggers.deployer_mut().stop_all()?;
        self.broker.flush(true)?;
        self.store.flush()?;
        Ok(())
    }
}

impl std::fmt::Debug for Node {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Node({} @ {:?})", self.config.name, self.location)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ar::message::Action;
    use crate::ar::profile::Profile;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("rpulsar-node-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn store_msg(profile: &str, data: &[u8]) -> ArMessage {
        ArMessage::builder()
            .set_header(Profile::parse(profile).unwrap())
            .set_sender("test")
            .set_action(Action::Store)
            .set_data(data.to_vec())
            .build()
            .unwrap()
    }

    #[test]
    fn node_boots_and_stores() {
        let dir = tmp("boot");
        let mut n = Node::with_name_at("rp-a", 40.0, -74.0, &dir).unwrap();
        assert_eq!(n.id(), NodeId::from_name("rp-a"));
        let reactions = n.handle_ar(&store_msg("drone,lidar", b"img")).unwrap();
        assert!(matches!(reactions[0], Reaction::Stored { .. }));
        assert_eq!(
            n.store().get(b"drone,lidar").unwrap(),
            Some(b"img".to_vec())
        );
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn start_topology_via_ar() {
        let dir = tmp("topo");
        let mut n = Node::with_name_at("rp-b", 40.0, -74.0, &dir).unwrap();
        n.topologies_mut().register_stage("noop", || {
            Box::new(crate::stream::operator::OperatorKind::map("noop", |t| t))
        });
        let store_fn = ArMessage::builder()
            .set_header(Profile::parse("post_processing_func").unwrap())
            .set_action(Action::StoreFunction)
            .set_topology("noop")
            .build()
            .unwrap();
        n.handle_ar(&store_fn).unwrap();
        let start = ArMessage::builder()
            .set_header(Profile::parse("post_processing_func").unwrap())
            .set_action(Action::StartFunction)
            .build()
            .unwrap();
        n.handle_ar(&start).unwrap();
        assert_eq!(n.topologies_mut().running(), vec!["post_processing_func"]);
        // Stop it via AR too.
        let stop = ArMessage::builder()
            .set_header(Profile::parse("post_processing_func").unwrap())
            .set_action(Action::StopFunction)
            .build()
            .unwrap();
        n.handle_ar(&stop).unwrap();
        assert!(n.topologies_mut().running().is_empty());
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn peers_learned_and_forgotten() {
        let dir = tmp("peers");
        let mut n = Node::with_name_at("rp-c", 0.0, 0.0, &dir).unwrap();
        let peer = NodeId::from_name("rp-d");
        n.learn_peer(peer);
        assert!(n.routing_table().contains(&peer));
        n.forget_peer(&peer);
        assert!(!n.routing_table().contains(&peer));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tick_retires_idle_topics_once_opted_in() {
        let dir = tmp("tick");
        let mut n = Node::with_name_at("rp-t", 0.0, 0.0, &dir).unwrap();
        let p = Profile::parse("sensor,temp").unwrap();
        n.publish(&p, b"x").unwrap();
        // No policy: tick is a no-op (existing deployments unaffected).
        assert!(n.retire_policy().is_none());
        assert!(n.tick().unwrap().is_empty());
        // Zero-threshold policy: every topic is idle by definition.
        n.set_retire_policy(Some(RetirePolicy {
            max_publish_idle: std::time::Duration::ZERO,
            max_fetch_idle: std::time::Duration::ZERO,
            min_age: std::time::Duration::ZERO,
        }));
        let retired = n.tick().unwrap();
        assert_eq!(retired, ["sensor,temp"]);
        assert!(n.tick().unwrap().is_empty(), "second sweep finds nothing");
        assert_eq!(n.metrics().counter("node.tick_topics_retired").get(), 1);
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn federated_registrations_expire_on_tick() {
        let dir = tmp("regs");
        let mut n = Node::with_name_at("rp-f", 0.0, 0.0, &dir).unwrap();
        let watch = Profile::parse("drone,*").unwrap();
        // A TTL-free registration survives any number of ticks.
        n.apply_registration("steady", watch.clone(), None);
        // A zero TTL expires on the very next sweep.
        n.apply_registration("ephemeral", watch.clone(), Some(std::time::Duration::ZERO));
        assert_eq!(n.registrations(), ["ephemeral", "steady"]);
        n.tick().unwrap();
        assert!(n.is_registered("steady"));
        assert!(!n.is_registered("ephemeral"));
        assert!(n.broker_mut().fetch("ephemeral", 10).is_err(), "swept from the broker too");
        assert_eq!(n.metrics().counter("node.regs_expired").get(), 1);
        // Re-register after expiry: fresh subscription, replays backlog.
        n.publish(&Profile::parse("drone,lidar").unwrap(), b"scan").unwrap();
        n.apply_registration("ephemeral", watch, Some(std::time::Duration::from_secs(3600)));
        assert_eq!(n.broker_mut().fetch("ephemeral", 10).unwrap().len(), 1);
        // Explicit withdrawal beats the TTL.
        assert!(n.remove_registration("ephemeral"));
        assert!(!n.remove_registration("ephemeral"), "second withdrawal is a no-op");
        assert!(n.broker_mut().fetch("ephemeral", 10).is_err());
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trigger_bindings_ride_the_node_tick() {
        use crate::stream::tuple::Tuple;
        let dir = tmp("trig");
        let mut n = Node::with_name_at("rp-g", 0.0, 0.0, &dir).unwrap();
        n.topologies_mut().register_stage("inc", || {
            Box::new(crate::stream::operator::OperatorKind::map("inc", |mut t| {
                let v = t.get("X").unwrap_or(0.0);
                t.set("X", v + 1.0);
                t
            }))
        });
        let eager = TriggerOptions {
            idle: RetirePolicy {
                max_publish_idle: Duration::ZERO,
                max_fetch_idle: Duration::ZERO,
                min_age: Duration::ZERO,
            },
            decode_payloads: true,
            tenant: None,
        };
        n.bind_trigger(
            Pipeline::parse("incjob", "inc").unwrap(),
            Profile::parse("drone,*").unwrap(),
            eager,
        )
        .unwrap();
        // Backlog arrives; the next housekeeping tick activates the
        // binding with no external pump loop.
        n.publish(
            &Profile::parse("drone,lidar").unwrap(),
            &Tuple::new(0, vec![]).with("X", 1.0).encode(),
        )
        .unwrap();
        n.tick().unwrap();
        assert!(n.triggers().is_active("incjob"), "tick must activate on backlog");
        // Further ticks drain and decommission back to zero.
        for _ in 0..200 {
            n.tick().unwrap();
            if !n.triggers().is_active("incjob") {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(!n.triggers().is_active("incjob"));
        let out = n.triggers_mut().take_outputs("incjob");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].get("X"), Some(2.0));
        assert_eq!(n.triggers().stats("incjob").unwrap().activations, 1);
        // Unbind returns nothing further and the node shuts down clean.
        assert!(n.unbind_trigger("incjob").unwrap().is_empty());
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn consumer_notified_reaction_pumps_triggers() {
        use crate::stream::tuple::Tuple;
        let dir = tmp("trig-ar");
        let mut n = Node::with_name_at("rp-h", 0.0, 0.0, &dir).unwrap();
        n.topologies_mut().register_stage("inc", || {
            Box::new(crate::stream::operator::OperatorKind::map("inc", |mut t| {
                let v = t.get("X").unwrap_or(0.0);
                t.set("X", v + 1.0);
                t
            }))
        });
        n.bind_trigger(
            Pipeline::parse("incjob", "inc").unwrap(),
            Profile::parse("drone,*").unwrap(),
            TriggerOptions::default(),
        )
        .unwrap();
        // An AR consumer waits on matching data, so a later Store
        // emits ConsumerNotified — the node piggybacks a trigger pump
        // on that reaction instead of waiting for the next tick.
        n.handle_ar(
            &ArMessage::builder()
                .set_header(Profile::parse("drone,li*").unwrap())
                .set_sender("watcher")
                .set_action(Action::NotifyData)
                .build()
                .unwrap(),
        )
        .unwrap();
        n.publish(
            &Profile::parse("drone,lidar").unwrap(),
            &Tuple::new(0, vec![]).with("X", 1.0).encode(),
        )
        .unwrap();
        assert!(!n.triggers().is_active("incjob"));
        let reactions = n.handle_ar(&store_msg("drone,lidar", b"img")).unwrap();
        assert!(reactions
            .iter()
            .any(|r| matches!(r, Reaction::ConsumerNotified { .. })));
        assert!(
            n.triggers().is_active("incjob"),
            "ConsumerNotified must pump the trigger plane"
        );
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn publish_goes_to_broker() {
        let dir = tmp("pub");
        let mut n = Node::with_name_at("rp-e", 0.0, 0.0, &dir).unwrap();
        let p = Profile::parse("drone,lidar").unwrap();
        n.broker_mut().subscribe("consumer", Profile::parse("drone,*").unwrap());
        n.publish(&p, b"payload").unwrap();
        let msgs = n.broker_mut().fetch("consumer", 10).unwrap();
        assert_eq!(msgs.len(), 1);
        n.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
