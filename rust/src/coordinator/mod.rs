//! Layer-3 coordinator: composes every subsystem into a Rendezvous
//! Point [`node::Node`] and provides the in-process multi-node
//! [`cluster::Cluster`] used by the scalability experiments, integration
//! tests and the end-to-end pipeline.

pub mod cluster;
pub mod node;

pub use cluster::{Cluster, NODE_CRASH_ENV};
pub use node::Node;
