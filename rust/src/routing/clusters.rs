//! Query-region → SFC-cluster decomposition (paper §IV-B, Fig. 2b).
//!
//! A complex keyword tuple identifies a hyper-rectangular region of the
//! keyword space; the region corresponds to *clusters* — contiguous
//! segments of the Hilbert curve. We compute them by recursive spatial
//! subdivision: a level-`L` cell (side `2^(bits-L)`, aligned) maps to one
//! contiguous index interval of length `2^((bits-L)·dims)`; cells fully
//! inside the query region emit their interval, partially-overlapping
//! cells recurse until `max_level`, then over-approximate. Adjacent
//! intervals are merged.

use super::hilbert::HilbertCurve;
use super::keyspace::DimRange;
use crate::error::Result;

/// An inclusive interval `[lo, hi]` of Hilbert indices.
pub type IndexRange = (u64, u64);

/// Decompose the query region given by one [`DimRange`] per dimension
/// into merged, sorted Hilbert index ranges.
///
/// `max_level` bounds refinement depth (and therefore cluster count):
/// deeper = tighter approximation but more clusters. The paper's routing
/// fans out one message per cluster, so this is the precision/fan-out
/// trade-off knob.
pub fn clusters_for_region(
    curve: &HilbertCurve,
    region: &[DimRange],
    max_level: u32,
) -> Result<Vec<IndexRange>> {
    assert_eq!(region.len(), curve.dims() as usize, "region arity mismatch");
    let side = curve.side();
    let bounds: Vec<(u64, u64)> = region.iter().map(|r| r.bounds(side)).collect();
    let max_level = max_level.min(curve.bits());

    // Fast path: a pure point region is a single index.
    if region.iter().all(|r| r.is_point()) {
        let coords: Vec<u64> = bounds.iter().map(|&(lo, _)| lo).collect();
        let idx = curve.encode(&coords)?;
        return Ok(vec![(idx, idx)]);
    }

    let mut ranges: Vec<IndexRange> = Vec::new();
    let origin = vec![0u64; curve.dims() as usize];
    recurse(curve, &bounds, &origin, 0, max_level, &mut ranges)?;
    ranges.sort_unstable();
    Ok(merge(ranges))
}

/// Total number of curve points covered by a cluster set.
pub fn covered_points(ranges: &[IndexRange]) -> u128 {
    ranges.iter().map(|&(lo, hi)| (hi - lo) as u128 + 1).sum()
}

fn recurse(
    curve: &HilbertCurve,
    query: &[(u64, u64)],
    cell_origin: &[u64],
    level: u32,
    max_level: u32,
    out: &mut Vec<IndexRange>,
) -> Result<()> {
    let bits = curve.bits();
    let cell_side = 1u64 << (bits - level);

    // Classify cell vs query region.
    let mut fully_inside = true;
    for (d, &(qlo, qhi)) in query.iter().enumerate() {
        let clo = cell_origin[d];
        let chi = clo + cell_side - 1;
        if chi < qlo || clo > qhi {
            return Ok(()); // disjoint — prune
        }
        if clo < qlo || chi > qhi {
            fully_inside = false;
        }
    }

    if fully_inside || level >= max_level {
        // Emit the cell's contiguous index interval. All points in an
        // aligned cell share the top `level*dims` index bits.
        let idx = curve.encode(cell_origin)?;
        let span_bits = (bits - level) * curve.dims();
        let lo = if span_bits >= 64 { 0 } else { (idx >> span_bits) << span_bits };
        let hi = if span_bits >= 64 {
            u64::MAX >> (64 - curve.bits() * curve.dims()).min(63)
        } else {
            lo + ((1u64 << span_bits) - 1)
        };
        out.push((lo, hi));
        return Ok(());
    }

    // Recurse into the 2^dims children.
    let child_side = cell_side / 2;
    let dims = curve.dims() as usize;
    for child in 0..(1u32 << dims) {
        let mut origin = cell_origin.to_vec();
        for (d, item) in origin.iter_mut().enumerate().take(dims) {
            if child >> d & 1 == 1 {
                *item += child_side;
            }
        }
        recurse(curve, query, &origin, level + 1, max_level, out)?;
    }
    Ok(())
}

/// Merge sorted, possibly-adjacent/overlapping ranges.
fn merge(sorted: Vec<IndexRange>) -> Vec<IndexRange> {
    let mut out: Vec<IndexRange> = Vec::with_capacity(sorted.len());
    for (lo, hi) in sorted {
        match out.last_mut() {
            Some((_, prev_hi)) if lo <= prev_hi.saturating_add(1) => {
                *prev_hi = (*prev_hi).max(hi);
            }
            _ => out.push((lo, hi)),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve2d() -> HilbertCurve {
        HilbertCurve::new(2, 5).unwrap() // 32×32
    }

    #[test]
    fn point_region_is_single_index() {
        let c = curve2d();
        let r = clusters_for_region(&c, &[DimRange::Point(3), DimRange::Point(7)], 5).unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, r[0].1);
        assert_eq!(c.decode(r[0].0), vec![3, 7]);
    }

    #[test]
    fn full_region_is_whole_curve() {
        let c = curve2d();
        let r = clusters_for_region(&c, &[DimRange::Full, DimRange::Full], 5).unwrap();
        assert_eq!(r, vec![(0, (1u64 << 10) - 1)]);
    }

    #[test]
    fn clusters_cover_exactly_the_query_points_at_full_depth() {
        let c = curve2d();
        let query = [DimRange::Range(3, 9), DimRange::Range(10, 20)];
        let ranges = clusters_for_region(&c, &query, 5).unwrap();
        // At max refinement the clusters must contain exactly the indices
        // of the points in the rectangle.
        let expected: u128 = 7 * 11;
        assert_eq!(covered_points(&ranges), expected);
        // Every query point's index is inside some range.
        for x in 3..=9u64 {
            for y in 10..=20u64 {
                let idx = c.encode(&[x, y]).unwrap();
                assert!(
                    ranges.iter().any(|&(lo, hi)| idx >= lo && idx <= hi),
                    "({x},{y}) idx {idx} not covered"
                );
            }
        }
        // No non-query point is covered.
        for x in 0..32u64 {
            for y in 0..32u64 {
                let inside = (3..=9).contains(&x) && (10..=20).contains(&y);
                let idx = c.encode(&[x, y]).unwrap();
                let covered = ranges.iter().any(|&(lo, hi)| idx >= lo && idx <= hi);
                assert_eq!(inside, covered, "({x},{y})");
            }
        }
    }

    #[test]
    fn shallow_refinement_over_approximates() {
        let c = curve2d();
        let query = [DimRange::Range(3, 9), DimRange::Range(10, 20)];
        let deep = clusters_for_region(&c, &query, 5).unwrap();
        let shallow = clusters_for_region(&c, &query, 2).unwrap();
        assert!(covered_points(&shallow) >= covered_points(&deep));
        assert!(shallow.len() <= deep.len(), "shallower must not produce more clusters");
        // Over-approximation still covers every query point.
        for x in 3..=9u64 {
            for y in 10..=20u64 {
                let idx = c.encode(&[x, y]).unwrap();
                assert!(shallow.iter().any(|&(lo, hi)| idx >= lo && idx <= hi));
            }
        }
    }

    #[test]
    fn ranges_are_sorted_and_disjoint() {
        let c = HilbertCurve::new(3, 4).unwrap();
        let query = [DimRange::Range(1, 9), DimRange::Full, DimRange::Range(4, 5)];
        let ranges = clusters_for_region(&c, &query, 4).unwrap();
        for w in ranges.windows(2) {
            assert!(w[0].1 + 1 < w[1].0, "ranges must be disjoint and non-adjacent: {w:?}");
        }
    }

    #[test]
    fn aligned_cell_is_one_cluster() {
        // An aligned half-space in 2D: x in [0,15], y in [0,31] of a 32×32
        // grid is two level-1 cells... while x in [0,15], y in [0,15]
        // (one quadrant) must be exactly one contiguous range.
        let c = curve2d();
        let quadrant = [DimRange::Range(0, 15), DimRange::Range(0, 15)];
        let ranges = clusters_for_region(&c, &quadrant, 5).unwrap();
        assert_eq!(ranges.len(), 1, "{ranges:?}");
        assert_eq!(covered_points(&ranges), 256);
    }

    #[test]
    fn merge_joins_adjacent() {
        assert_eq!(merge(vec![(0, 3), (4, 7), (10, 12)]), vec![(0, 7), (10, 12)]);
        assert_eq!(merge(vec![(0, 5), (2, 3)]), vec![(0, 5)]);
        assert_eq!(merge(vec![]), vec![]);
    }

    #[test]
    fn six_dimensional_profile_routing_works() {
        // Paper Fig. 9/10 routes profiles of up to 6 properties.
        let c = HilbertCurve::new(6, 10).unwrap();
        let query = [
            DimRange::Point(512),
            DimRange::Range(100, 200),
            DimRange::Full,
            DimRange::Point(7),
            DimRange::Range(0, 1023),
            DimRange::Point(99),
        ];
        let ranges = clusters_for_region(&c, &query, 3).unwrap();
        assert!(!ranges.is_empty());
        // Covers at least the true point count (over-approximation OK).
        let true_points: u128 = 1 * 101 * 1024 * 1 * 1024 * 1;
        assert!(covered_points(&ranges) >= true_points);
    }
}
