//! The n-dimensional keyword space (paper §IV-B).
//!
//! Each profile property is one dimension. A keyword maps to a coordinate
//! by interpreting its characters as base-37 fractional digits, which
//! makes the mapping *prefix-preserving*: all keywords starting with
//! `"li"` occupy one contiguous coordinate interval, so partial keywords
//! (`"Li*"`) and wildcards become coordinate ranges — exactly what the
//! SFC cluster machinery needs. Numeric values (ranges) are scaled
//! linearly into the same coordinate space.

use crate::error::{Error, Result};

/// Base of the character alphabet: `a-z` (26) + `0-9` (10) + other (1).
const BASE: u64 = 37;
/// Number of leading characters that contribute to a coordinate.
/// 37^12 < 2^64, so the accumulator stays exact in u64.
const MAX_CHARS: usize = 12;

/// Per-dimension query shape after keyword→coordinate mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DimRange {
    /// Exact keyword → a single coordinate.
    Point(u64),
    /// Partial keyword / numeric range → inclusive coordinate interval.
    Range(u64, u64),
    /// Wildcard `*` → the whole dimension.
    Full,
}

impl DimRange {
    /// Inclusive (lo, hi) bounds of this range within a space of
    /// `side = 2^bits` coordinates.
    pub fn bounds(&self, side: u64) -> (u64, u64) {
        match *self {
            DimRange::Point(p) => (p, p),
            DimRange::Range(lo, hi) => (lo.min(side - 1), hi.min(side - 1)),
            DimRange::Full => (0, side - 1),
        }
    }

    /// True if the range covers a single coordinate.
    pub fn is_point(&self) -> bool {
        matches!(self, DimRange::Point(_)) || matches!(self, DimRange::Range(a, b) if a == b)
    }
}

/// Maps keywords and numeric values into `bits`-bit coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeySpace {
    bits: u32,
}

impl KeySpace {
    /// Create a keyspace with `bits` bits per dimension (1..=32).
    pub fn new(bits: u32) -> Result<Self> {
        if bits == 0 || bits > 32 {
            return Err(Error::Profile(format!("keyspace: bits {bits} out of [1,32]")));
        }
        Ok(KeySpace { bits })
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Side length of each dimension: `2^bits`.
    pub fn side(&self) -> u64 {
        1u64 << self.bits
    }

    fn digit(c: u8) -> u64 {
        match c {
            b'a'..=b'z' => 1 + (c - b'a') as u64,
            b'A'..=b'Z' => 1 + (c - b'A') as u64,
            b'0'..=b'9' => 27 + (c - b'0') as u64,
            _ => 0,
        }
    }

    /// Fractional base-37 value of the first [`MAX_CHARS`] characters,
    /// returned as (numerator, denominator = 37^k).
    fn fraction(s: &str) -> (u64, u64) {
        let mut acc = 0u64;
        let mut denom = 1u64;
        for &c in s.as_bytes().iter().take(MAX_CHARS) {
            acc = acc * BASE + Self::digit(c);
            denom *= BASE;
        }
        (acc, denom)
    }

    /// Map an exact keyword to its coordinate (prefix-preserving).
    pub fn keyword_point(&self, keyword: &str) -> u64 {
        let (num, denom) = Self::fraction(keyword);
        if denom == 1 {
            return 0; // empty keyword
        }
        ((num as u128 * self.side() as u128) / denom as u128) as u64
    }

    /// Map a keyword prefix (`"li*"` minus the `*`) to the inclusive
    /// coordinate interval covering every keyword with that prefix.
    pub fn prefix_range(&self, prefix: &str) -> DimRange {
        if prefix.is_empty() {
            return DimRange::Full;
        }
        let (num, denom) = Self::fraction(prefix);
        let side = self.side() as u128;
        let lo = (num as u128 * side) / denom as u128;
        // Everything with this prefix is < (num+1)/denom.
        let hi_exclusive = ((num as u128 + 1) * side + denom as u128 - 1) / denom as u128;
        let hi = hi_exclusive.saturating_sub(1).min(side - 1);
        let (lo, hi) = (lo as u64, hi as u64);
        if lo >= hi {
            DimRange::Point(lo)
        } else {
            DimRange::Range(lo, hi)
        }
    }

    /// Canonical numeric domain used to scale numbers into coordinates.
    /// Values are clamped. Chosen to cover lat/lon and sensor magnitudes.
    pub const NUM_LO: f64 = -1.0e6;
    pub const NUM_HI: f64 = 1.0e6;

    /// Map a numeric value to a coordinate (linear scaling, clamped).
    pub fn numeric_point(&self, v: f64) -> u64 {
        let clamped = v.clamp(Self::NUM_LO, Self::NUM_HI);
        let unit = (clamped - Self::NUM_LO) / (Self::NUM_HI - Self::NUM_LO);
        let side = self.side();
        ((unit * (side - 1) as f64).round() as u64).min(side - 1)
    }

    /// Map a numeric interval to an inclusive coordinate range.
    pub fn numeric_range(&self, lo: f64, hi: f64) -> DimRange {
        let (a, b) = if lo <= hi { (lo, hi) } else { (hi, lo) };
        let (pa, pb) = (self.numeric_point(a), self.numeric_point(b));
        if pa == pb {
            DimRange::Point(pa)
        } else {
            DimRange::Range(pa, pb)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ks() -> KeySpace {
        KeySpace::new(10).unwrap()
    }

    #[test]
    fn rejects_bad_bits() {
        assert!(KeySpace::new(0).is_err());
        assert!(KeySpace::new(33).is_err());
    }

    #[test]
    fn keyword_point_is_deterministic_and_ordered_by_prefix() {
        let k = ks();
        assert_eq!(k.keyword_point("drone"), k.keyword_point("drone"));
        // Lexicographic-ish ordering: "a..." < "b..." in coordinate space.
        assert!(k.keyword_point("apple") < k.keyword_point("banana"));
        assert!(k.keyword_point("lidar") < k.keyword_point("zebra"));
    }

    #[test]
    fn keyword_point_case_insensitive() {
        let k = ks();
        assert_eq!(k.keyword_point("LiDAR"), k.keyword_point("lidar"));
    }

    #[test]
    fn prefix_range_contains_matching_keywords() {
        let k = ks();
        let range = k.prefix_range("li");
        let (lo, hi) = range.bounds(k.side());
        for word in ["li", "lidar", "lizard", "light"] {
            let p = k.keyword_point(word);
            assert!(p >= lo && p <= hi, "{word}: {p} not in [{lo},{hi}]");
        }
        // Non-matching keywords fall outside.
        for word in ["la", "lz", "drone", "m"] {
            let p = k.keyword_point(word);
            assert!(p < lo || p > hi, "{word} should be outside [{lo},{hi}]");
        }
    }

    #[test]
    fn longer_prefix_gives_narrower_range() {
        let k = KeySpace::new(20).unwrap();
        let (lo1, hi1) = k.prefix_range("l").bounds(k.side());
        let (lo2, hi2) = k.prefix_range("li").bounds(k.side());
        let (lo3, hi3) = k.prefix_range("lid").bounds(k.side());
        assert!(lo1 <= lo2 && hi2 <= hi1);
        assert!(lo2 <= lo3 && hi3 <= hi2);
        assert!((hi2 - lo2) < (hi1 - lo1));
    }

    #[test]
    fn empty_prefix_is_full_dimension() {
        assert_eq!(ks().prefix_range(""), DimRange::Full);
    }

    #[test]
    fn numeric_point_monotonic_and_clamped() {
        let k = ks();
        assert!(k.numeric_point(-10.0) < k.numeric_point(10.0));
        assert_eq!(k.numeric_point(-2.0e6), 0);
        assert_eq!(k.numeric_point(2.0e6), k.side() - 1);
    }

    #[test]
    fn numeric_range_normalises_order() {
        let k = ks();
        assert_eq!(k.numeric_range(5.0, -5.0), k.numeric_range(-5.0, 5.0));
    }

    #[test]
    fn dim_range_bounds() {
        let side = 1024;
        assert_eq!(DimRange::Point(7).bounds(side), (7, 7));
        assert_eq!(DimRange::Range(5, 10).bounds(side), (5, 10));
        assert_eq!(DimRange::Full.bounds(side), (0, 1023));
        assert!(DimRange::Point(3).is_point());
        assert!(DimRange::Range(4, 4).is_point());
        assert!(!DimRange::Full.is_point());
    }

    #[test]
    fn digits_distinguish_letters_and_numbers() {
        // The mapping is prefix-weighted: differences in early characters
        // dominate, so distinguishing late characters needs enough bits
        // (by design — locality for prefix queries comes first).
        let k = KeySpace::new(20).unwrap();
        assert_ne!(k.keyword_point("a1"), k.keyword_point("ab"));
        assert_ne!(k.keyword_point("s1"), k.keyword_point("s2"));
        let k32 = KeySpace::new(32).unwrap();
        assert_ne!(k32.keyword_point("sens1"), k32.keyword_point("sens2"));
    }
}
