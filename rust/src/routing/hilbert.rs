//! n-dimensional Hilbert space-filling curve (paper §IV-B, after
//! Sagan [22]), using John Skilling's public-domain transpose algorithm
//! ("Programming the Hilbert curve", AIP Conf. Proc. 707, 2004).
//!
//! Supports `dims ∈ [1, 8]` dimensions at `bits` bits of precision per
//! dimension with `dims * bits <= 64`, so a full curve index fits in one
//! `u64` and can be embedded into the top bits of a 160-bit overlay id.

use crate::error::{Error, Result};

/// A Hilbert curve of fixed dimensionality and per-dimension precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HilbertCurve {
    dims: u32,
    bits: u32,
}

impl HilbertCurve {
    /// Create a curve; `dims * bits` must be ≤ 64 and ≥ 1.
    pub fn new(dims: u32, bits: u32) -> Result<Self> {
        if dims == 0 || dims > 8 {
            return Err(Error::Profile(format!("hilbert: dims {dims} out of [1,8]")));
        }
        if bits == 0 || dims * bits > 64 {
            return Err(Error::Profile(format!(
                "hilbert: dims*bits = {} exceeds 64",
                dims * bits
            )));
        }
        Ok(HilbertCurve { dims, bits })
    }

    pub fn dims(&self) -> u32 {
        self.dims
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Maximum coordinate value (exclusive): `2^bits`.
    pub fn side(&self) -> u64 {
        1u64 << self.bits
    }

    /// Total number of points on the curve: `2^(dims*bits)`.
    pub fn capacity(&self) -> u128 {
        1u128 << (self.dims * self.bits)
    }

    /// Encode coordinates to a Hilbert index. Coordinates must be
    /// `< 2^bits` each; `coords.len()` must equal `dims`.
    pub fn encode(&self, coords: &[u64]) -> Result<u64> {
        if coords.len() != self.dims as usize {
            return Err(Error::Profile(format!(
                "hilbert: expected {} coords, got {}",
                self.dims,
                coords.len()
            )));
        }
        let side = self.side();
        let mut x: Vec<u64> = Vec::with_capacity(coords.len());
        for &c in coords {
            if c >= side {
                return Err(Error::Profile(format!("hilbert: coord {c} >= side {side}")));
            }
            x.push(c);
        }
        self.axes_to_transpose(&mut x);
        Ok(self.interleave(&x))
    }

    /// Decode a Hilbert index back to coordinates.
    pub fn decode(&self, index: u64) -> Vec<u64> {
        let mut x = self.deinterleave(index);
        self.transpose_to_axes(&mut x);
        x
    }

    // --- Skilling transform -------------------------------------------------

    fn axes_to_transpose(&self, x: &mut [u64]) {
        let n = x.len();
        let m = 1u64 << (self.bits - 1);
        // Inverse undo excess work
        let mut q = m;
        while q > 1 {
            let p = q - 1;
            for i in 0..n {
                if x[i] & q != 0 {
                    x[0] ^= p; // invert low bits of x[0]
                } else {
                    let t = (x[0] ^ x[i]) & p;
                    x[0] ^= t;
                    x[i] ^= t;
                }
            }
            q >>= 1;
        }
        // Gray encode
        for i in 1..n {
            x[i] ^= x[i - 1];
        }
        let mut t = 0u64;
        let mut q = m;
        while q > 1 {
            if x[n - 1] & q != 0 {
                t ^= q - 1;
            }
            q >>= 1;
        }
        for xi in x.iter_mut() {
            *xi ^= t;
        }
    }

    fn transpose_to_axes(&self, x: &mut [u64]) {
        let n = x.len();
        let m = 1u64 << (self.bits - 1);
        // Gray decode by H ^ (H/2)
        let mut t = x[n - 1] >> 1;
        for i in (1..n).rev() {
            x[i] ^= x[i - 1];
        }
        x[0] ^= t;
        // Undo excess work
        let mut q = 2u64;
        while q != m << 1 {
            let p = q - 1;
            for i in (0..n).rev() {
                if x[i] & q != 0 {
                    x[0] ^= p;
                } else {
                    t = (x[0] ^ x[i]) & p;
                    x[0] ^= t;
                    x[i] ^= t;
                }
            }
            q <<= 1;
        }
    }

    /// Interleave transposed form into a single index: bit `b` (MSB-first)
    /// of every dimension in turn.
    fn interleave(&self, x: &[u64]) -> u64 {
        let mut index = 0u64;
        for b in (0..self.bits).rev() {
            for xi in x {
                index = (index << 1) | ((xi >> b) & 1);
            }
        }
        index
    }

    fn deinterleave(&self, index: u64) -> Vec<u64> {
        let n = self.dims as usize;
        let mut x = vec![0u64; n];
        let total_bits = self.dims * self.bits;
        for pos in 0..total_bits {
            let bit = (index >> (total_bits - 1 - pos)) & 1;
            let dim = (pos % self.dims) as usize;
            x[dim] = (x[dim] << 1) | bit;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_bad_parameters() {
        assert!(HilbertCurve::new(0, 4).is_err());
        assert!(HilbertCurve::new(9, 4).is_err());
        assert!(HilbertCurve::new(4, 17).is_err());
        assert!(HilbertCurve::new(2, 32).is_ok());
    }

    #[test]
    fn d2_order1_layout() {
        // The classic 2x2 Hilbert curve: (0,0)→0, (0,1)→1, (1,1)→2, (1,0)→3
        // (one standard orientation; verify it is a bijection over 4 cells
        // and consecutive cells are adjacent).
        let h = HilbertCurve::new(2, 1).unwrap();
        let mut seen = [false; 4];
        for x in 0..2u64 {
            for y in 0..2u64 {
                let idx = h.encode(&[x, y]).unwrap() as usize;
                assert!(!seen[idx]);
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn encode_decode_round_trip_2d() {
        let h = HilbertCurve::new(2, 8).unwrap();
        for x in (0..256u64).step_by(17) {
            for y in (0..256u64).step_by(13) {
                let idx = h.encode(&[x, y]).unwrap();
                assert_eq!(h.decode(idx), vec![x, y]);
            }
        }
    }

    #[test]
    fn encode_decode_round_trip_6d() {
        // The paper routes profiles of up to 6 properties (Fig. 9/10).
        let h = HilbertCurve::new(6, 10).unwrap();
        let coords = [[0u64; 6], [1023; 6], [1, 2, 3, 4, 5, 6], [512, 0, 1023, 7, 99, 300]];
        for c in coords {
            let idx = h.encode(&c).unwrap();
            assert_eq!(h.decode(idx), c.to_vec());
        }
    }

    #[test]
    fn index_is_bijective_small() {
        let h = HilbertCurve::new(3, 3).unwrap();
        let total = 1usize << 9;
        let mut seen = vec![false; total];
        for x in 0..8u64 {
            for y in 0..8u64 {
                for z in 0..8u64 {
                    let idx = h.encode(&[x, y, z]).unwrap() as usize;
                    assert!(!seen[idx], "duplicate index {idx}");
                    seen[idx] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn consecutive_indices_are_adjacent_cells() {
        // The defining locality property of the Hilbert curve: walking the
        // index visits a path of unit steps (Manhattan distance 1).
        let h = HilbertCurve::new(2, 5).unwrap();
        let total = 1u64 << 10;
        let mut prev = h.decode(0);
        for idx in 1..total {
            let cur = h.decode(idx);
            let dist: u64 = prev
                .iter()
                .zip(&cur)
                .map(|(a, b)| a.abs_diff(*b))
                .sum();
            assert_eq!(dist, 1, "index {idx}: {prev:?} → {cur:?}");
            prev = cur;
        }
    }

    #[test]
    fn consecutive_adjacency_3d() {
        let h = HilbertCurve::new(3, 3).unwrap();
        let mut prev = h.decode(0);
        for idx in 1..(1u64 << 9) {
            let cur = h.decode(idx);
            let dist: u64 = prev.iter().zip(&cur).map(|(a, b)| a.abs_diff(*b)).sum();
            assert_eq!(dist, 1);
            prev = cur;
        }
    }

    #[test]
    fn out_of_range_coord_rejected() {
        let h = HilbertCurve::new(2, 4).unwrap();
        assert!(h.encode(&[16, 0]).is_err());
        assert!(h.encode(&[0]).is_err()); // wrong arity
    }

    #[test]
    fn index_windows_are_spatially_clustered() {
        // The clustering property motivating the design (paper: SFC maps
        // nearby keywords to nearby peers): any window of k consecutive
        // indices covers a region whose bounding box area is O(k).
        let h = HilbertCurve::new(2, 6).unwrap();
        let k = 64u64;
        for start in (0..(1u64 << 12) - k).step_by(97) {
            let (mut min_x, mut max_x, mut min_y, mut max_y) = (u64::MAX, 0, u64::MAX, 0);
            for idx in start..start + k {
                let c = h.decode(idx);
                min_x = min_x.min(c[0]);
                max_x = max_x.max(c[0]);
                min_y = min_y.min(c[1]);
                max_y = max_y.max(c[1]);
            }
            let area = (max_x - min_x + 1) * (max_y - min_y + 1);
            assert!(
                area <= 6 * k,
                "window [{start},{}) bounding box area {area} > {}",
                start + k,
                6 * k
            );
        }
    }
}
