//! Profile → Rendezvous-Point resolution (paper §IV-B, Fig. 2).
//!
//! Routing takes *(data, profile, location)*:
//!
//! 1. the **location** picks the overlay network (quadtree region) —
//!    messages for another region are forwarded via that region's master;
//! 2. the **profile** maps through the keyword space onto the Hilbert
//!    curve: simple tuples to one index (Fig. 2a), complex tuples to
//!    clusters of index ranges (Fig. 2b);
//! 3. the overlay **lookup** routes each index to the XOR-closest RP.
//!
//! [`ContentRouter`] is pure policy over a membership snapshot: the
//! coordinator feeds it the region's member list (kept fresh by the
//! stabilisation mode) and a hop model for latency accounting.

use super::clusters::{clusters_for_region, IndexRange};
use super::hilbert::HilbertCurve;
use super::keyspace::{DimRange, KeySpace};
use crate::ar::profile::Profile;
use crate::error::{Error, Result};
use crate::overlay::node_id::NodeId;
use crate::overlay::ring::{simulate_lookup, RoutingTable};
use std::collections::BTreeMap;

/// Maximum cluster refinement depth (precision vs fan-out; see
/// `clusters_for_region`).
pub const DEFAULT_REFINEMENT: u32 = 3;

/// Outcome of routing one profile.
#[derive(Debug, Clone)]
pub struct RouteOutcome {
    /// Responsible RPs, deduplicated.
    pub targets: Vec<NodeId>,
    /// The SFC index ranges the profile mapped to.
    pub clusters: Vec<IndexRange>,
    /// Overlay hops taken across all lookups (simulated greedy routing).
    pub hops: usize,
    /// Whether the profile was simple (single point) or complex.
    pub simple: bool,
}

/// Content-based router over one region's membership.
#[derive(Debug, Clone)]
pub struct ContentRouter {
    /// Hilbert curve parameters per profile arity (dims → curve).
    refinement: u32,
}

impl Default for ContentRouter {
    fn default() -> Self {
        Self::new()
    }
}

impl ContentRouter {
    pub fn new() -> Self {
        ContentRouter { refinement: DEFAULT_REFINEMENT }
    }

    pub fn with_refinement(refinement: u32) -> Self {
        ContentRouter { refinement }
    }

    /// Curve geometry for a given profile arity: spend the 64-bit index
    /// budget evenly (dims × bits ≤ 60 keeps headroom for 6D at 10 bits,
    /// the paper's maximum profile complexity).
    pub fn curve_for(dims: usize) -> Result<(HilbertCurve, KeySpace)> {
        if dims == 0 || dims > 8 {
            return Err(Error::Profile(format!("profile arity {dims} out of [1,8]")));
        }
        let bits = (60 / dims as u32).min(16);
        Ok((HilbertCurve::new(dims as u32, bits)?, KeySpace::new(bits)?))
    }

    /// Map a profile to its SFC clusters.
    pub fn clusters(&self, profile: &Profile) -> Result<Vec<IndexRange>> {
        let (curve, ks) = Self::curve_for(profile.dims())?;
        let region: Vec<DimRange> =
            profile.terms().iter().map(|t| t.to_dim_range(&ks)).collect();
        clusters_for_region(&curve, &region, self.refinement)
    }

    /// Normalise a raw SFC index (on a `dims×bits` curve) into the 64-bit
    /// id prefix space: left-align so indices from curves of different
    /// total bit-width share one id space.
    pub fn index_to_id(index: u64, curve: &HilbertCurve) -> NodeId {
        let total_bits = curve.dims() * curve.bits();
        let shifted = if total_bits >= 64 { index } else { index << (64 - total_bits) };
        NodeId::from_sfc_index(shifted)
    }

    /// Resolve a profile to the set of responsible RPs within a region,
    /// given converged routing tables (one per live member) and a start
    /// node. Returns targets, clusters and hop count.
    pub fn route(
        &self,
        profile: &Profile,
        tables: &BTreeMap<NodeId, RoutingTable>,
        start: NodeId,
    ) -> Result<RouteOutcome> {
        if tables.is_empty() {
            return Err(Error::Overlay("no live members to route to".into()));
        }
        let (curve, _) = Self::curve_for(profile.dims())?;
        let clusters = self.clusters(profile)?;
        let mut targets: Vec<NodeId> = Vec::new();
        let mut hops = 0usize;
        for &(lo, hi) in &clusters {
            // One lookup per cluster endpoint: the RPs owning the curve
            // segment. For tight clusters lo==hi this is a single lookup.
            for idx in [lo, hi] {
                let target_id = Self::index_to_id(idx, &curve);
                let res = simulate_lookup(tables, start, &target_id);
                hops += res.hops;
                if !targets.contains(&res.owner) {
                    targets.push(res.owner);
                }
                if lo == hi {
                    break;
                }
            }
        }
        targets.sort();
        Ok(RouteOutcome { targets, clusters, hops, simple: profile.is_simple() })
    }

    /// The single owner RP for a *simple* profile (storage placement).
    pub fn owner_for_simple(
        &self,
        profile: &Profile,
        tables: &BTreeMap<NodeId, RoutingTable>,
        start: NodeId,
    ) -> Result<NodeId> {
        if !profile.is_simple() {
            return Err(Error::Profile(format!(
                "profile `{}` is not simple; use route()",
                profile.render()
            )));
        }
        Ok(self.route(profile, tables, start)?.targets[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overlay::ring::build_converged_tables;

    fn members(n: usize) -> Vec<NodeId> {
        (0..n).map(|i| NodeId::from_name(&format!("rp-{i}"))).collect()
    }

    fn p(s: &str) -> Profile {
        Profile::parse(s).unwrap()
    }

    #[test]
    fn simple_profile_routes_to_one_target() {
        let ids = members(16);
        let tables = build_converged_tables(&ids, 8);
        let router = ContentRouter::new();
        let out = router.route(&p("drone,lidar"), &tables, ids[0]).unwrap();
        assert!(out.simple);
        assert_eq!(out.clusters.len(), 1);
        assert_eq!(out.targets.len(), 1);
    }

    #[test]
    fn routing_is_start_independent() {
        // All starts must agree on the owner (deterministic rendezvous).
        let ids = members(32);
        let tables = build_converged_tables(&ids, 8);
        let router = ContentRouter::new();
        let owners: Vec<NodeId> = ids
            .iter()
            .take(8)
            .map(|&s| router.route(&p("drone,lidar"), &tables, s).unwrap().targets[0])
            .collect();
        assert!(owners.windows(2).all(|w| w[0] == w[1]), "{owners:?}");
    }

    #[test]
    fn matching_data_and_interest_route_to_overlapping_rps() {
        // The core guarantee (paper §IV-B): "all peers responsible for
        // that profile will be found" — a complex interest profile must
        // reach the RP where the matching simple data profile lives.
        let ids = members(24);
        let tables = build_converged_tables(&ids, 8);
        let router = ContentRouter::new();
        let data_owner =
            router.owner_for_simple(&p("drone,lidar"), &tables, ids[3]).unwrap();
        let interest = router.route(&p("drone,li*"), &tables, ids[7]).unwrap();
        assert!(
            interest.targets.contains(&data_owner),
            "interest targets {:?} must include data owner {data_owner}",
            interest.targets
        );
    }

    #[test]
    fn wildcard_profile_fans_out_no_less_than_exact() {
        let ids = members(32);
        let tables = build_converged_tables(&ids, 8);
        let router = ContentRouter::new();
        let exact = router.route(&p("drone,lidar"), &tables, ids[0]).unwrap();
        let wild = router.route(&p("drone,*"), &tables, ids[0]).unwrap();
        assert!(wild.targets.len() >= exact.targets.len());
        assert!(!wild.simple);
    }

    #[test]
    fn owner_for_simple_rejects_complex() {
        let ids = members(8);
        let tables = build_converged_tables(&ids, 8);
        let router = ContentRouter::new();
        assert!(router.owner_for_simple(&p("li*"), &tables, ids[0]).is_err());
    }

    #[test]
    fn curve_for_scales_bits_with_dims() {
        for dims in 1..=6usize {
            let (curve, ks) = ContentRouter::curve_for(dims).unwrap();
            assert_eq!(curve.dims() as usize, dims);
            assert_eq!(curve.bits(), ks.bits());
            assert!(curve.dims() * curve.bits() <= 60);
        }
        assert!(ContentRouter::curve_for(0).is_err());
        assert!(ContentRouter::curve_for(9).is_err());
    }

    #[test]
    fn hops_increase_with_profile_complexity() {
        // Paper Figs. 9–10: routing cost grows with profile dimensions.
        let ids = members(48);
        let tables = build_converged_tables(&ids, 8);
        let router = ContentRouter::new();
        let simple = router.route(&p("a,b"), &tables, ids[0]).unwrap();
        let complex = router
            .route(&p("a*,b*,c*,d*,e*,f*"), &tables, ids[0])
            .unwrap();
        assert!(
            complex.clusters.len() >= simple.clusters.len(),
            "complex profile should produce at least as many clusters"
        );
    }

    #[test]
    fn empty_membership_errors() {
        let tables = BTreeMap::new();
        let router = ContentRouter::new();
        assert!(router.route(&p("a"), &tables, NodeId::ZERO).is_err());
    }

    #[test]
    fn index_to_id_left_aligns() {
        let curve = HilbertCurve::new(2, 8).unwrap(); // 16-bit indices
        let id = ContentRouter::index_to_id(0xFFFF, &curve);
        // Left-aligned: top 16 bits set.
        assert_eq!(id.sfc_index() >> 48, 0xFFFF);
    }
}
