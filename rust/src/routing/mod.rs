//! Layer 2 of the paper (§IV-B): content-based routing.
//!
//! Keyword profiles are mapped to coordinates in an n-dimensional keyword
//! space ([`keyspace`]); the Hilbert space-filling curve ([`hilbert`])
//! linearises that space onto the one-dimensional identifier space of the
//! XOR overlay. Simple keyword tuples map to a single point on the curve;
//! complex tuples (partial keywords, wildcards, ranges) map to *clusters*
//! — contiguous curve segments ([`clusters`]) — and the [`router`]
//! resolves either form to the set of responsible Rendezvous Points.

pub mod clusters;
pub mod hilbert;
pub mod keyspace;
pub mod router;

pub use hilbert::HilbertCurve;
pub use keyspace::{DimRange, KeySpace};
pub use router::{ContentRouter, RouteOutcome};
