//! Membership: join/bootstrap protocol state machine and keep-alive
//! failure detection (paper §IV-A and §IV-E).
//!
//! The join ("bootstrap") phase: a joining RP sends a discovery message;
//! if unanswered within a timeout it assumes it is first and becomes the
//! master. The running phase has a *stabilisation* mode (respond to
//! queries, keep routing tables fresh, verify peers are alive) and a
//! *user* mode. Keep-alive: peers ping the master periodically; a master
//! that misses `max_misses` keep-alives triggers a Hirschberg–Sinclair
//! election.

use super::node_id::NodeId;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Join-phase state machine states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinState {
    /// Discovery message sent, waiting for an answer.
    Discovering,
    /// An existing RP answered; routing table being built.
    Joining,
    /// No answer within the timeout: first node, becomes master.
    BecameMaster,
    /// Fully joined, running (stabilisation + user modes).
    Running,
}

/// Join-phase tracker for one node.
#[derive(Debug)]
pub struct JoinProtocol {
    state: JoinState,
    started: Instant,
    timeout: Duration,
}

impl JoinProtocol {
    /// Begin discovery with the paper's "order of seconds" timeout.
    pub fn start(timeout: Duration) -> Self {
        JoinProtocol { state: JoinState::Discovering, started: Instant::now(), timeout }
    }

    pub fn state(&self) -> JoinState {
        self.state
    }

    /// An existing RP answered our discovery.
    pub fn on_answer(&mut self) {
        if self.state == JoinState::Discovering {
            self.state = JoinState::Joining;
        }
    }

    /// Routing table has been built; enter running mode.
    pub fn on_table_built(&mut self) {
        if matches!(self.state, JoinState::Joining | JoinState::BecameMaster) {
            self.state = JoinState::Running;
        }
    }

    /// Drive timeouts; returns true if this tick made us master.
    pub fn tick(&mut self, now: Instant) -> bool {
        if self.state == JoinState::Discovering && now.duration_since(self.started) >= self.timeout
        {
            self.state = JoinState::BecameMaster;
            return true;
        }
        false
    }
}

/// Event emitted by the failure detector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MembershipEvent {
    /// Peer missed enough keep-alives to be declared failed.
    PeerFailed(NodeId),
    /// A failed peer answered again before removal (flapping).
    PeerRecovered(NodeId),
}

#[derive(Debug, Clone)]
struct PeerState {
    last_seen: Instant,
    misses: u32,
    failed: bool,
}

/// Keep-alive based failure detector ("peers send periodic keep alive
/// messages; if the master peer doesn't respond the leader election is
/// performed").
#[derive(Debug)]
pub struct FailureDetector {
    period: Duration,
    max_misses: u32,
    peers: BTreeMap<NodeId, PeerState>,
}

impl FailureDetector {
    pub fn new(period: Duration, max_misses: u32) -> Self {
        FailureDetector { period, max_misses: max_misses.max(1), peers: BTreeMap::new() }
    }

    /// Start tracking a peer (counts as just-seen).
    pub fn track(&mut self, id: NodeId, now: Instant) {
        self.peers.insert(id, PeerState { last_seen: now, misses: 0, failed: false });
    }

    /// Stop tracking a peer.
    pub fn untrack(&mut self, id: &NodeId) {
        self.peers.remove(id);
    }

    /// Record a keep-alive response from a peer.
    pub fn heard_from(&mut self, id: &NodeId, now: Instant) -> Option<MembershipEvent> {
        let st = self.peers.get_mut(id)?;
        st.last_seen = now;
        st.misses = 0;
        if st.failed {
            st.failed = false;
            return Some(MembershipEvent::PeerRecovered(*id));
        }
        None
    }

    /// Advance time; emit failure events for peers that crossed the miss
    /// threshold on this tick.
    pub fn tick(&mut self, now: Instant) -> Vec<MembershipEvent> {
        let mut events = Vec::new();
        for (id, st) in self.peers.iter_mut() {
            if st.failed {
                continue;
            }
            let silent_for = now.duration_since(st.last_seen);
            let misses = (silent_for.as_nanos() / self.period.as_nanos().max(1)) as u32;
            st.misses = misses;
            if misses >= self.max_misses {
                st.failed = true;
                events.push(MembershipEvent::PeerFailed(*id));
            }
        }
        events
    }

    /// Whether a peer is currently considered alive.
    pub fn is_alive(&self, id: &NodeId) -> bool {
        self.peers.get(id).map(|s| !s.failed).unwrap_or(false)
    }

    /// All currently-alive peer ids.
    pub fn alive_peers(&self) -> Vec<NodeId> {
        self.peers.iter().filter(|(_, s)| !s.failed).map(|(id, _)| *id).collect()
    }

    /// Tracked peer count.
    pub fn len(&self) -> usize {
        self.peers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.peers.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("m-{n}"))
    }

    #[test]
    fn join_becomes_master_on_timeout() {
        let mut j = JoinProtocol::start(Duration::from_millis(10));
        let t0 = Instant::now();
        assert_eq!(j.state(), JoinState::Discovering);
        assert!(!j.tick(t0));
        assert!(j.tick(t0 + Duration::from_millis(11)));
        assert_eq!(j.state(), JoinState::BecameMaster);
        j.on_table_built();
        assert_eq!(j.state(), JoinState::Running);
    }

    #[test]
    fn join_answer_prevents_mastership() {
        let mut j = JoinProtocol::start(Duration::from_millis(10));
        j.on_answer();
        assert_eq!(j.state(), JoinState::Joining);
        assert!(!j.tick(Instant::now() + Duration::from_secs(1)));
        j.on_table_built();
        assert_eq!(j.state(), JoinState::Running);
    }

    #[test]
    fn detector_flags_silent_peer() {
        let mut fd = FailureDetector::new(Duration::from_millis(100), 3);
        let t0 = Instant::now();
        fd.track(id(1), t0);
        assert!(fd.tick(t0 + Duration::from_millis(250)).is_empty()); // 2 misses
        let events = fd.tick(t0 + Duration::from_millis(301));
        assert_eq!(events, vec![MembershipEvent::PeerFailed(id(1))]);
        assert!(!fd.is_alive(&id(1)));
        // No duplicate event on next tick.
        assert!(fd.tick(t0 + Duration::from_millis(400)).is_empty());
    }

    #[test]
    fn heard_from_resets_misses() {
        let mut fd = FailureDetector::new(Duration::from_millis(100), 3);
        let t0 = Instant::now();
        fd.track(id(1), t0);
        fd.tick(t0 + Duration::from_millis(250));
        assert!(fd.heard_from(&id(1), t0 + Duration::from_millis(260)).is_none());
        assert!(fd.tick(t0 + Duration::from_millis(500)).is_empty()); // only ~2 misses since 260
        assert!(fd.is_alive(&id(1)));
    }

    #[test]
    fn recovery_event_after_failure() {
        let mut fd = FailureDetector::new(Duration::from_millis(10), 2);
        let t0 = Instant::now();
        fd.track(id(1), t0);
        fd.tick(t0 + Duration::from_millis(100));
        assert!(!fd.is_alive(&id(1)));
        let ev = fd.heard_from(&id(1), t0 + Duration::from_millis(110));
        assert_eq!(ev, Some(MembershipEvent::PeerRecovered(id(1))));
        assert!(fd.is_alive(&id(1)));
    }

    #[test]
    fn alive_peers_lists_only_alive() {
        let mut fd = FailureDetector::new(Duration::from_millis(10), 1);
        let t0 = Instant::now();
        fd.track(id(1), t0);
        fd.track(id(2), t0 + Duration::from_millis(95));
        fd.tick(t0 + Duration::from_millis(100));
        let alive = fd.alive_peers();
        assert_eq!(alive, vec![id(2)].into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn untrack_removes() {
        let mut fd = FailureDetector::new(Duration::from_millis(10), 1);
        fd.track(id(1), Instant::now());
        fd.untrack(&id(1));
        assert!(fd.is_empty());
        assert!(!fd.is_alive(&id(1)));
    }
}
