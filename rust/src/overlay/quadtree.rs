//! Point quadtree of geographic regions (paper §IV-A, Fig. 1).
//!
//! Each internal node has exactly four children; each leaf is a *region*
//! hosting a P2P ring of Rendezvous Points. The master RP "mans" the
//! quadtree and dictates when to divide: a region may split only when each
//! of the four new regions would retain at least `min_rps` members (the
//! paper's replication invariant). Every region master keeps a full copy
//! of the tree, so the structure survives RP failures.

use super::geo::{GeoPoint, Rect};
use super::node_id::NodeId;
use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Stable identifier of a quadtree region: the path from the root encoded
/// as 2 bits per level, plus the depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RegionId {
    /// Quadrant path, 2 bits per level, most-recent level in the low bits.
    pub path: u64,
    /// Depth (0 = root).
    pub depth: u8,
}

impl RegionId {
    pub const ROOT: RegionId = RegionId { path: 0, depth: 0 };

    /// Child region id for quadrant `q` (0..4).
    pub fn child(&self, q: usize) -> RegionId {
        debug_assert!(q < 4);
        RegionId { path: (self.path << 2) | q as u64, depth: self.depth + 1 }
    }

    /// Parent region id (None at root).
    pub fn parent(&self) -> Option<RegionId> {
        if self.depth == 0 {
            None
        } else {
            Some(RegionId { path: self.path >> 2, depth: self.depth - 1 })
        }
    }
}

impl std::fmt::Display for RegionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "R{}/{:o}", self.depth, self.path)
    }
}

/// A member Rendezvous Point as tracked by the quadtree.
#[derive(Debug, Clone, PartialEq)]
pub struct Member {
    pub id: NodeId,
    pub location: GeoPoint,
}

#[derive(Debug, Clone)]
enum NodeKind {
    Leaf { members: Vec<Member>, master: Option<NodeId> },
    Internal { children: [usize; 4] },
}

#[derive(Debug, Clone)]
struct TreeNode {
    region: RegionId,
    bounds: Rect,
    kind: NodeKind,
}

/// The point quadtree. Owned (replicated) by every region master.
#[derive(Debug, Clone)]
pub struct QuadTree {
    nodes: Vec<TreeNode>,
    /// Region may split only when all four children keep >= this many RPs.
    min_rps: usize,
    /// Hard depth cap to bound the tree under adversarial placement.
    max_depth: u8,
    /// Leaf index by region id for O(log) lookup.
    leaves: BTreeMap<RegionId, usize>,
}

impl QuadTree {
    /// New tree over the whole world.
    pub fn new(min_rps: usize) -> Self {
        Self::with_bounds(Rect::world(), min_rps, 16)
    }

    /// New tree over custom bounds (tests) with a depth cap.
    pub fn with_bounds(bounds: Rect, min_rps: usize, max_depth: u8) -> Self {
        let root = TreeNode {
            region: RegionId::ROOT,
            bounds,
            kind: NodeKind::Leaf { members: Vec::new(), master: None },
        };
        let mut leaves = BTreeMap::new();
        leaves.insert(RegionId::ROOT, 0);
        QuadTree { nodes: vec![root], min_rps: min_rps.max(1), max_depth, leaves }
    }

    /// The split threshold (paper's `n`).
    pub fn min_rps(&self) -> usize {
        self.min_rps
    }

    /// Total member count across all regions.
    pub fn len(&self) -> usize {
        self.leaves.values().map(|&i| self.leaf_members(i).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn leaf_members(&self, idx: usize) -> &[Member] {
        match &self.nodes[idx].kind {
            NodeKind::Leaf { members, .. } => members,
            NodeKind::Internal { .. } => unreachable!("leaf index points at internal node"),
        }
    }

    /// Insert an RP. Returns the region it landed in. Splits the region
    /// when it holds enough members that all four quadrants would keep
    /// `min_rps` members ("four new P2P rings", paper Fig. 1).
    pub fn insert(&mut self, id: NodeId, location: GeoPoint) -> Result<RegionId> {
        if !location.is_valid() {
            return Err(Error::Overlay(format!("invalid location {location:?}")));
        }
        let leaf_idx = self.locate_leaf(&location);
        match &mut self.nodes[leaf_idx].kind {
            NodeKind::Leaf { members, master } => {
                if members.iter().any(|m| m.id == id) {
                    return Err(Error::Overlay(format!("{id} already joined")));
                }
                members.push(Member { id, location });
                // First RP in the system/region becomes master (paper §IV-A:
                // "it becomes the master RP of the ring").
                if master.is_none() {
                    *master = Some(id);
                }
            }
            NodeKind::Internal { .. } => unreachable!(),
        }
        self.maybe_split(leaf_idx);
        Ok(self.region_of(&location))
    }

    /// Remove an RP by id. Returns its former region.
    pub fn remove(&mut self, id: &NodeId) -> Option<RegionId> {
        let (leaf_idx, region) = self
            .leaves
            .iter()
            .find(|(_, &i)| self.leaf_members(i).iter().any(|m| &m.id == id))
            .map(|(r, &i)| (i, *r))?;
        if let NodeKind::Leaf { members, master } = &mut self.nodes[leaf_idx].kind {
            members.retain(|m| &m.id != id);
            if *master == Some(*id) {
                // Deterministic interim master; a proper election runs at
                // the membership layer (paper: Hirschberg–Sinclair).
                *master = members.first().map(|m| m.id);
            }
        }
        Some(region)
    }

    /// The leaf region containing a point.
    pub fn region_of(&self, p: &GeoPoint) -> RegionId {
        self.nodes[self.locate_leaf(p)].region
    }

    /// Bounds of a region (leaf or internal).
    pub fn bounds_of(&self, region: RegionId) -> Option<Rect> {
        self.nodes.iter().find(|n| n.region == region).map(|n| n.bounds)
    }

    /// Members of the leaf region containing a point.
    pub fn members_at(&self, p: &GeoPoint) -> &[Member] {
        self.leaf_members(self.locate_leaf(p))
    }

    /// Members of a leaf region by id.
    pub fn members_of(&self, region: RegionId) -> Option<&[Member]> {
        self.leaves.get(&region).map(|&i| self.leaf_members(i))
    }

    /// Master RP of the leaf region containing a point.
    pub fn master_at(&self, p: &GeoPoint) -> Option<NodeId> {
        match &self.nodes[self.locate_leaf(p)].kind {
            NodeKind::Leaf { master, .. } => *master,
            NodeKind::Internal { .. } => unreachable!(),
        }
    }

    /// Master of a specific region.
    pub fn master_of(&self, region: RegionId) -> Option<NodeId> {
        let &i = self.leaves.get(&region)?;
        match &self.nodes[i].kind {
            NodeKind::Leaf { master, .. } => *master,
            NodeKind::Internal { .. } => unreachable!(),
        }
    }

    /// Install a new master for a region (after an election).
    pub fn set_master(&mut self, region: RegionId, id: NodeId) -> Result<()> {
        let &i = self
            .leaves
            .get(&region)
            .ok_or_else(|| Error::Overlay(format!("{region} is not a leaf region")))?;
        match &mut self.nodes[i].kind {
            NodeKind::Leaf { members, master } => {
                if !members.iter().any(|m| m.id == id) {
                    return Err(Error::Overlay(format!("{id} is not a member of {region}")));
                }
                *master = Some(id);
                Ok(())
            }
            NodeKind::Internal { .. } => unreachable!(),
        }
    }

    /// All leaf regions.
    pub fn regions(&self) -> impl Iterator<Item = RegionId> + '_ {
        self.leaves.keys().copied()
    }

    /// All members with their region.
    pub fn members(&self) -> impl Iterator<Item = (RegionId, &Member)> + '_ {
        self.leaves
            .iter()
            .flat_map(move |(r, &i)| self.leaf_members(i).iter().map(move |m| (*r, m)))
    }

    /// All leaf regions whose bounds intersect `rect` (complex-profile
    /// routing fans out to every matching region).
    pub fn regions_intersecting(&self, rect: &Rect) -> Vec<RegionId> {
        self.leaves
            .iter()
            .filter(|(_, &i)| self.nodes[i].bounds.intersects(rect))
            .map(|(r, _)| *r)
            .collect()
    }

    fn locate_leaf(&self, p: &GeoPoint) -> usize {
        let mut idx = 0usize;
        loop {
            match &self.nodes[idx].kind {
                NodeKind::Leaf { .. } => return idx,
                NodeKind::Internal { children } => {
                    let q = self.nodes[idx].bounds.quadrant_of(p);
                    idx = children[q as usize];
                }
            }
        }
    }

    /// Split the leaf at `idx` when the replication invariant allows:
    /// every quadrant must retain at least `min_rps` members.
    fn maybe_split(&mut self, idx: usize) {
        let (region, bounds) = (self.nodes[idx].region, self.nodes[idx].bounds);
        if region.depth >= self.max_depth {
            return;
        }
        let members = match &self.nodes[idx].kind {
            NodeKind::Leaf { members, .. } => members.clone(),
            NodeKind::Internal { .. } => return,
        };
        let quads = bounds.quadrants();
        let mut split: [Vec<Member>; 4] = [vec![], vec![], vec![], vec![]];
        for m in &members {
            let q = bounds.quadrant_of(&m.location) as usize;
            split[q].push(m.clone());
        }
        if split.iter().any(|s| s.len() < self.min_rps) {
            return; // invariant would be violated — do not divide
        }
        // Perform the split: leaf becomes internal, four new leaves appear
        // ("Every time the quadtree splits, the system creates four new
        // P2P rings").
        self.leaves.remove(&region);
        let mut children = [0usize; 4];
        for (q, quad_members) in split.into_iter().enumerate() {
            let child_region = region.child(q);
            let master = quad_members.first().map(|m| m.id);
            let node = TreeNode {
                region: child_region,
                bounds: quads[q],
                kind: NodeKind::Leaf { members: quad_members, master },
            };
            let child_idx = self.nodes.len();
            self.nodes.push(node);
            self.leaves.insert(child_region, child_idx);
            children[q] = child_idx;
        }
        self.nodes[idx].kind = NodeKind::Internal { children };
        // Recurse: a freshly created child may itself be splittable.
        for q in 0..4 {
            self.maybe_split(children[q]);
        }
    }

    /// Check the structural invariants; used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        for (&region, &i) in &self.leaves {
            let node = &self.nodes[i];
            if node.region != region {
                return Err(Error::Overlay("leaf index out of sync".into()));
            }
            let members = self.leaf_members(i);
            for m in members {
                if !node.bounds.contains(&m.location) {
                    return Err(Error::Overlay(format!(
                        "member {} at {:?} outside region {} bounds",
                        m.id, m.location, region
                    )));
                }
            }
            match &node.kind {
                NodeKind::Leaf { master, members } => {
                    if let Some(master) = master {
                        if !members.iter().any(|m| m.id == *master) {
                            return Err(Error::Overlay(format!(
                                "master {master} of {region} not a member"
                            )));
                        }
                    } else if !members.is_empty() {
                        return Err(Error::Overlay(format!("{region} has members but no master")));
                    }
                    // Non-root leaves created by a split must satisfy the
                    // replication invariant at creation; members can later
                    // *leave*, so only check the structural part here.
                }
                NodeKind::Internal { .. } => unreachable!(),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("rp-{n}"))
    }

    #[test]
    fn first_rp_becomes_master() {
        let mut t = QuadTree::new(2);
        let region = t.insert(id(0), GeoPoint::new(10.0, 10.0)).unwrap();
        assert_eq!(t.master_of(region), Some(id(0)));
    }

    #[test]
    fn split_requires_min_rps_per_quadrant() {
        let mut t = QuadTree::with_bounds(Rect::new(0.0, 8.0, 0.0, 8.0), 1, 8);
        // Three RPs all in one quadrant: no split possible.
        t.insert(id(0), GeoPoint::new(1.0, 1.0)).unwrap();
        t.insert(id(1), GeoPoint::new(1.5, 1.5)).unwrap();
        t.insert(id(2), GeoPoint::new(2.0, 2.0)).unwrap();
        assert_eq!(t.regions().count(), 1, "no split while a quadrant would be empty");
        // One RP in each remaining quadrant → split becomes legal.
        t.insert(id(3), GeoPoint::new(1.0, 5.0)).unwrap();
        t.insert(id(4), GeoPoint::new(5.0, 1.0)).unwrap();
        t.insert(id(5), GeoPoint::new(5.0, 5.0)).unwrap();
        assert!(t.regions().count() > 1, "split should have happened");
        t.check_invariants().unwrap();
    }

    #[test]
    fn each_new_region_keeps_master_and_members() {
        let mut t = QuadTree::with_bounds(Rect::new(0.0, 8.0, 0.0, 8.0), 1, 8);
        for (i, (lat, lon)) in
            [(1.0, 1.0), (1.0, 5.0), (5.0, 1.0), (5.0, 5.0)].iter().enumerate()
        {
            t.insert(id(i as u32), GeoPoint::new(*lat, *lon)).unwrap();
        }
        assert_eq!(t.regions().count(), 4);
        for r in t.regions().collect::<Vec<_>>() {
            let members = t.members_of(r).unwrap();
            assert_eq!(members.len(), 1);
            assert_eq!(t.master_of(r), Some(members[0].id));
        }
    }

    #[test]
    fn region_of_follows_splits() {
        let mut t = QuadTree::with_bounds(Rect::new(0.0, 8.0, 0.0, 8.0), 1, 8);
        for (i, (lat, lon)) in
            [(1.0, 1.0), (1.0, 5.0), (5.0, 1.0), (5.0, 5.0)].iter().enumerate()
        {
            t.insert(id(i as u32), GeoPoint::new(*lat, *lon)).unwrap();
        }
        let r = t.region_of(&GeoPoint::new(1.0, 1.0));
        assert_eq!(r.depth, 1);
        assert_eq!(t.members_of(r).unwrap()[0].id, id(0));
    }

    #[test]
    fn duplicate_join_rejected() {
        let mut t = QuadTree::new(2);
        t.insert(id(0), GeoPoint::new(0.0, 0.0)).unwrap();
        assert!(t.insert(id(0), GeoPoint::new(1.0, 1.0)).is_err());
    }

    #[test]
    fn invalid_location_rejected() {
        let mut t = QuadTree::new(2);
        assert!(t.insert(id(0), GeoPoint::new(91.0, 0.0)).is_err());
    }

    #[test]
    fn remove_promotes_new_master() {
        let mut t = QuadTree::new(2);
        t.insert(id(0), GeoPoint::new(1.0, 1.0)).unwrap();
        t.insert(id(1), GeoPoint::new(1.1, 1.1)).unwrap();
        let region = t.remove(&id(0)).unwrap();
        assert_eq!(t.master_of(region), Some(id(1)));
        t.check_invariants().unwrap();
    }

    #[test]
    fn remove_unknown_returns_none() {
        let mut t = QuadTree::new(2);
        assert!(t.remove(&id(9)).is_none());
    }

    #[test]
    fn set_master_validates_membership() {
        let mut t = QuadTree::new(2);
        let region = t.insert(id(0), GeoPoint::new(1.0, 1.0)).unwrap();
        assert!(t.set_master(region, id(5)).is_err());
        t.insert(id(1), GeoPoint::new(1.2, 1.2)).unwrap();
        t.set_master(region, id(1)).unwrap();
        assert_eq!(t.master_of(region), Some(id(1)));
    }

    #[test]
    fn regions_intersecting_finds_overlaps() {
        let mut t = QuadTree::with_bounds(Rect::new(0.0, 8.0, 0.0, 8.0), 1, 8);
        for (i, (lat, lon)) in
            [(1.0, 1.0), (1.0, 5.0), (5.0, 1.0), (5.0, 5.0)].iter().enumerate()
        {
            t.insert(id(i as u32), GeoPoint::new(*lat, *lon)).unwrap();
        }
        // A rect covering only the south-west corner.
        let hits = t.regions_intersecting(&Rect::new(0.0, 1.5, 0.0, 1.5));
        assert_eq!(hits.len(), 1);
        // A rect covering everything.
        let all = t.regions_intersecting(&Rect::new(0.0, 8.0, 0.0, 8.0));
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn region_id_child_parent_round_trip() {
        let r = RegionId::ROOT.child(2).child(3).child(1);
        assert_eq!(r.depth, 3);
        assert_eq!(r.parent().unwrap().parent().unwrap(), RegionId::ROOT.child(2));
        assert_eq!(RegionId::ROOT.parent(), None);
    }

    #[test]
    fn deep_insertion_respects_depth_cap() {
        let mut t = QuadTree::with_bounds(Rect::new(0.0, 1.0, 0.0, 1.0), 1, 2);
        // Pile many RPs into a tiny area — depth cap must hold.
        for i in 0..64 {
            let eps = (i as f64) * 1e-6;
            t.insert(id(i), GeoPoint::new(0.1 + eps, 0.1 + eps)).unwrap();
        }
        assert!(t.regions().all(|r| r.depth <= 2));
        t.check_invariants().unwrap();
    }
}
