//! XOR-metric ring routing table (paper §IV-A: "the one-dimensional
//! identifier space used by the XOR overlay", after Kademlia [21]).
//!
//! Each region of the quadtree runs one such ring. The table keeps up to
//! `k` peers per common-prefix bucket; `closest()` yields candidates for
//! greedy lookup, and [`simulate_lookup`] counts the hops a lookup takes
//! through a set of tables — used by the routing-overhead experiments
//! (paper Figs. 9–10).

use super::node_id::{NodeId, ID_BITS};
use std::collections::BTreeMap;

/// Contact information for a peer (transport address is abstract: the
/// simulated transport uses the id itself; TCP uses `addr`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Contact {
    pub id: NodeId,
    pub addr: String,
}

impl Contact {
    pub fn new(id: NodeId) -> Self {
        Contact { id, addr: String::new() }
    }

    pub fn with_addr(id: NodeId, addr: impl Into<String>) -> Self {
        Contact { id, addr: addr.into() }
    }
}

/// Kademlia-style routing table: bucket `i` holds peers whose XOR distance
/// to `self_id` has `i` leading zero bits (longer prefix ⇒ closer).
#[derive(Debug, Clone)]
pub struct RoutingTable {
    self_id: NodeId,
    bucket_size: usize,
    buckets: Vec<Vec<Contact>>,
}

impl RoutingTable {
    pub fn new(self_id: NodeId, bucket_size: usize) -> Self {
        RoutingTable {
            self_id,
            bucket_size: bucket_size.max(1),
            buckets: vec![Vec::new(); ID_BITS],
        }
    }

    pub fn self_id(&self) -> NodeId {
        self.self_id
    }

    /// Insert or refresh a contact. Returns false when the bucket is full
    /// (Kademlia would ping the oldest; we simply reject, matching
    /// TomP2P's default "drop newest" behaviour).
    pub fn insert(&mut self, contact: Contact) -> bool {
        if contact.id == self.self_id {
            return false;
        }
        let Some(bucket_idx) = self.self_id.bucket_index(&contact.id) else {
            return false;
        };
        let bucket = &mut self.buckets[bucket_idx];
        if let Some(pos) = bucket.iter().position(|c| c.id == contact.id) {
            // Refresh: move to tail (most recently seen).
            let c = bucket.remove(pos);
            bucket.push(Contact { addr: contact.addr, ..c });
            return true;
        }
        if bucket.len() >= self.bucket_size {
            return false;
        }
        bucket.push(contact);
        true
    }

    /// Remove a peer (failure detected).
    pub fn remove(&mut self, id: &NodeId) -> bool {
        if let Some(bucket_idx) = self.self_id.bucket_index(id) {
            let bucket = &mut self.buckets[bucket_idx];
            if let Some(pos) = bucket.iter().position(|c| &c.id == id) {
                bucket.remove(pos);
                return true;
            }
        }
        false
    }

    /// Whether a peer is present.
    pub fn contains(&self, id: &NodeId) -> bool {
        self.self_id
            .bucket_index(id)
            .map(|b| self.buckets[b].iter().any(|c| &c.id == id))
            .unwrap_or(false)
    }

    /// Total number of contacts.
    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All contacts (unordered).
    pub fn contacts(&self) -> impl Iterator<Item = &Contact> {
        self.buckets.iter().flatten()
    }

    /// Up to `k` known contacts closest (XOR) to `target`, closest first.
    /// Includes self-distance consideration only for peers, never self.
    pub fn closest(&self, target: &NodeId, k: usize) -> Vec<Contact> {
        let mut sorted: BTreeMap<_, &Contact> = BTreeMap::new();
        for c in self.contacts() {
            sorted.insert(c.id.distance(target), c);
        }
        sorted.into_values().take(k).cloned().collect()
    }

    /// The single closest known peer to `target`, if any.
    pub fn next_hop(&self, target: &NodeId) -> Option<Contact> {
        self.closest(target, 1).into_iter().next()
    }
}

/// Result of a simulated greedy lookup through a ring of tables.
#[derive(Debug, Clone, PartialEq)]
pub struct LookupResult {
    /// Node that owns the target (closest overall).
    pub owner: NodeId,
    /// Hops taken (0 when the start node already owns the target).
    pub hops: usize,
    /// Ids visited in order, starting after the origin.
    pub path: Vec<NodeId>,
}

/// Simulate a greedy XOR lookup over a set of routing tables (one per
/// live node). Models the paper's "overlay network lookup mechanism":
/// each hop moves strictly closer to the target or stops.
pub fn simulate_lookup(
    tables: &BTreeMap<NodeId, RoutingTable>,
    start: NodeId,
    target: &NodeId,
) -> LookupResult {
    let mut current = start;
    let mut path = Vec::new();
    let mut hops = 0usize;
    loop {
        let table = match tables.get(&current) {
            Some(t) => t,
            None => break,
        };
        let best = table.next_hop(target);
        match best {
            Some(next) if next.id.distance(target) < current.distance(target) => {
                current = next.id;
                path.push(current);
                hops += 1;
                if hops > tables.len() {
                    break; // safety: cannot loop longer than the ring
                }
            }
            _ => break,
        }
    }
    LookupResult { owner: current, hops, path }
}

/// Build fully-converged routing tables for a membership set — what the
/// stabilisation mode (paper §IV-E) converges to. Used by tests, benches
/// and the in-process cluster harness.
pub fn build_converged_tables(
    ids: &[NodeId],
    bucket_size: usize,
) -> BTreeMap<NodeId, RoutingTable> {
    let mut tables = BTreeMap::new();
    for &id in ids {
        let mut t = RoutingTable::new(id, bucket_size);
        for &peer in ids {
            if peer != id {
                t.insert(Contact::new(peer));
            }
        }
        tables.insert(id, t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u32) -> NodeId {
        NodeId::from_name(&format!("peer-{n}"))
    }

    #[test]
    fn insert_and_contains() {
        let mut t = RoutingTable::new(id(0), 4);
        assert!(t.insert(Contact::new(id(1))));
        assert!(t.contains(&id(1)));
        assert!(!t.contains(&id(2)));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn self_insert_rejected() {
        let mut t = RoutingTable::new(id(0), 4);
        assert!(!t.insert(Contact::new(id(0))));
        assert!(t.is_empty());
    }

    #[test]
    fn bucket_capacity_enforced() {
        // Force many ids into the same bucket by brute force: find ids
        // sharing the same bucket index relative to `self`.
        let me = id(0);
        let mut t = RoutingTable::new(me, 2);
        let mut same_bucket = Vec::new();
        let mut n = 1u32;
        let first = loop {
            let cand = id(n);
            n += 1;
            if let Some(b) = me.bucket_index(&cand) {
                break (cand, b);
            }
        };
        same_bucket.push(first.0);
        while same_bucket.len() < 4 {
            let cand = id(n);
            n += 1;
            if me.bucket_index(&cand) == Some(first.1) {
                same_bucket.push(cand);
            }
        }
        assert!(t.insert(Contact::new(same_bucket[0])));
        assert!(t.insert(Contact::new(same_bucket[1])));
        assert!(!t.insert(Contact::new(same_bucket[2])), "bucket of 2 is full");
        // Refreshing an existing contact still succeeds.
        assert!(t.insert(Contact::new(same_bucket[0])));
    }

    #[test]
    fn remove_works() {
        let mut t = RoutingTable::new(id(0), 4);
        t.insert(Contact::new(id(1)));
        assert!(t.remove(&id(1)));
        assert!(!t.remove(&id(1)));
        assert!(t.is_empty());
    }

    #[test]
    fn closest_orders_by_xor_distance() {
        let mut t = RoutingTable::new(id(0), 8);
        for n in 1..32 {
            t.insert(Contact::new(id(n)));
        }
        let target = id(100);
        let closest = t.closest(&target, 5);
        assert_eq!(closest.len(), 5);
        for w in closest.windows(2) {
            assert!(w[0].id.distance(&target) <= w[1].id.distance(&target));
        }
        // The head must be the minimum among contacts actually retained
        // (bucket capacity may have rejected some inserts).
        let best_retained = t
            .contacts()
            .map(|c| c.id)
            .min_by_key(|i| i.distance(&target))
            .unwrap();
        assert_eq!(closest[0].id, best_retained);
    }

    #[test]
    fn lookup_converges_to_owner() {
        let ids: Vec<NodeId> = (0..64).map(id).collect();
        let tables = build_converged_tables(&ids, 8);
        let target = NodeId::from_name("some-key");
        let owner_expected = ids.iter().min_by_key(|i| i.distance(&target)).copied().unwrap();
        for &start in ids.iter().take(8) {
            let res = simulate_lookup(&tables, start, &target);
            assert_eq!(res.owner, owner_expected, "start={start}");
            assert!(res.hops <= 3, "fully-converged tables should route in O(1) hops");
        }
    }

    #[test]
    fn lookup_with_sparse_tables_takes_more_hops() {
        // Each node only knows its 4 nearest neighbours by id order —
        // lookups must still converge, with more hops.
        let ids: Vec<NodeId> = {
            let mut v: Vec<NodeId> = (0..64).map(id).collect();
            v.sort();
            v
        };
        let mut tables = BTreeMap::new();
        for (i, &nid) in ids.iter().enumerate() {
            let mut t = RoutingTable::new(nid, 8);
            for d in 1..=4usize {
                t.insert(Contact::new(ids[(i + d) % ids.len()]));
                t.insert(Contact::new(ids[(i + ids.len() - d) % ids.len()]));
            }
            tables.insert(nid, t);
        }
        let target = NodeId::from_name("sparse-key");
        let res = simulate_lookup(&tables, ids[0], &target);
        // Must terminate at a local minimum that is close to the target.
        assert!(res.hops >= 1);
        let owner_dist = res.owner.distance(&target);
        assert!(owner_dist <= ids[0].distance(&target));
    }

    #[test]
    fn lookup_hops_zero_when_start_owns() {
        let ids: Vec<NodeId> = (0..16).map(id).collect();
        let tables = build_converged_tables(&ids, 8);
        let target = NodeId::from_name("k");
        let owner = ids.iter().min_by_key(|i| i.distance(&target)).copied().unwrap();
        let res = simulate_lookup(&tables, owner, &target);
        assert_eq!(res.hops, 0);
        assert_eq!(res.owner, owner);
    }
}
