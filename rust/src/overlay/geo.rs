//! Geographic primitives: points and axis-aligned rectangles, plus the
//! quadrant arithmetic used by the quadtree.

/// A WGS-84 latitude/longitude point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeoPoint {
    pub lat: f64,
    pub lon: f64,
}

impl GeoPoint {
    pub fn new(lat: f64, lon: f64) -> Self {
        GeoPoint { lat, lon }
    }

    /// Whether the point is a valid WGS-84 coordinate.
    pub fn is_valid(&self) -> bool {
        (-90.0..=90.0).contains(&self.lat) && (-180.0..=180.0).contains(&self.lon)
    }

    /// Squared Euclidean distance in degree space (ordering only).
    pub fn dist2(&self, other: &GeoPoint) -> f64 {
        let dlat = self.lat - other.lat;
        let dlon = self.lon - other.lon;
        dlat * dlat + dlon * dlon
    }
}

/// Axis-aligned bounding box: `[min_lat, max_lat) × [min_lon, max_lon)`
/// with the convention that the world root is inclusive at the top edges.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rect {
    pub min_lat: f64,
    pub max_lat: f64,
    pub min_lon: f64,
    pub max_lon: f64,
}

/// Quadrant order used throughout the overlay: NW, NE, SW, SE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quadrant {
    NorthWest = 0,
    NorthEast = 1,
    SouthWest = 2,
    SouthEast = 3,
}

impl Rect {
    /// The whole WGS-84 world.
    pub fn world() -> Self {
        Rect { min_lat: -90.0, max_lat: 90.0, min_lon: -180.0, max_lon: 180.0 }
    }

    pub fn new(min_lat: f64, max_lat: f64, min_lon: f64, max_lon: f64) -> Self {
        debug_assert!(min_lat < max_lat && min_lon < max_lon);
        Rect { min_lat, max_lat, min_lon, max_lon }
    }

    /// Whether a point lies inside (half-open, top edges inclusive only
    /// for the world bounds).
    pub fn contains(&self, p: &GeoPoint) -> bool {
        p.lat >= self.min_lat
            && (p.lat < self.max_lat || (self.max_lat == 90.0 && p.lat == 90.0))
            && p.lon >= self.min_lon
            && (p.lon < self.max_lon || (self.max_lon == 180.0 && p.lon == 180.0))
    }

    /// Centre point.
    pub fn center(&self) -> GeoPoint {
        GeoPoint::new(
            (self.min_lat + self.max_lat) / 2.0,
            (self.min_lon + self.max_lon) / 2.0,
        )
    }

    /// Which quadrant a contained point falls into.
    pub fn quadrant_of(&self, p: &GeoPoint) -> Quadrant {
        let c = self.center();
        match (p.lat >= c.lat, p.lon >= c.lon) {
            (true, false) => Quadrant::NorthWest,
            (true, true) => Quadrant::NorthEast,
            (false, false) => Quadrant::SouthWest,
            (false, true) => Quadrant::SouthEast,
        }
    }

    /// The sub-rectangle for a quadrant.
    pub fn quadrant_rect(&self, q: Quadrant) -> Rect {
        let c = self.center();
        match q {
            Quadrant::NorthWest => Rect::new(c.lat, self.max_lat, self.min_lon, c.lon),
            Quadrant::NorthEast => Rect::new(c.lat, self.max_lat, c.lon, self.max_lon),
            Quadrant::SouthWest => Rect::new(self.min_lat, c.lat, self.min_lon, c.lon),
            Quadrant::SouthEast => Rect::new(self.min_lat, c.lat, c.lon, self.max_lon),
        }
    }

    /// All four quadrants in [`Quadrant`] order.
    pub fn quadrants(&self) -> [Rect; 4] {
        [
            self.quadrant_rect(Quadrant::NorthWest),
            self.quadrant_rect(Quadrant::NorthEast),
            self.quadrant_rect(Quadrant::SouthWest),
            self.quadrant_rect(Quadrant::SouthEast),
        ]
    }

    /// Whether two rects overlap (half-open semantics).
    pub fn intersects(&self, other: &Rect) -> bool {
        self.min_lat < other.max_lat
            && other.min_lat < self.max_lat
            && self.min_lon < other.max_lon
            && other.min_lon < self.max_lon
    }
}

impl Quadrant {
    pub fn from_index(i: usize) -> Quadrant {
        match i {
            0 => Quadrant::NorthWest,
            1 => Quadrant::NorthEast,
            2 => Quadrant::SouthWest,
            _ => Quadrant::SouthEast,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_contains_extremes() {
        let w = Rect::world();
        assert!(w.contains(&GeoPoint::new(90.0, 180.0)));
        assert!(w.contains(&GeoPoint::new(-90.0, -180.0)));
        assert!(w.contains(&GeoPoint::new(0.0, 0.0)));
    }

    #[test]
    fn quadrants_partition_the_rect() {
        let r = Rect::new(0.0, 10.0, 0.0, 10.0);
        let quads = r.quadrants();
        // Every probe point is in exactly one quadrant.
        for lat in [1.0, 4.9, 5.0, 9.9] {
            for lon in [1.0, 4.9, 5.0, 9.9] {
                let p = GeoPoint::new(lat, lon);
                let n = quads.iter().filter(|q| q.contains(&p)).count();
                assert_eq!(n, 1, "point {p:?} in {n} quadrants");
            }
        }
    }

    #[test]
    fn quadrant_of_matches_quadrant_rect() {
        let r = Rect::new(-10.0, 10.0, -10.0, 10.0);
        for (lat, lon) in [(5.0, -5.0), (5.0, 5.0), (-5.0, -5.0), (-5.0, 5.0)] {
            let p = GeoPoint::new(lat, lon);
            let q = r.quadrant_of(&p);
            assert!(r.quadrant_rect(q).contains(&p), "{p:?} not in its quadrant {q:?}");
        }
    }

    #[test]
    fn paper_coordinates_land_in_northeast_of_world() {
        // Paper's example: Rutgers area, lat 40.0583, lon -74.4056.
        let w = Rect::world();
        let p = GeoPoint::new(40.0583, -74.4056);
        assert!(p.is_valid());
        assert_eq!(w.quadrant_of(&p), Quadrant::NorthWest); // lat>=0, lon<0
    }

    #[test]
    fn intersects_basics() {
        let a = Rect::new(0.0, 10.0, 0.0, 10.0);
        let b = Rect::new(5.0, 15.0, 5.0, 15.0);
        let c = Rect::new(10.0, 20.0, 10.0, 20.0); // touches edge only
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c));
    }

    #[test]
    fn dist2_is_zero_on_self() {
        let p = GeoPoint::new(1.0, 2.0);
        assert_eq!(p.dist2(&p), 0.0);
        assert!(p.dist2(&GeoPoint::new(2.0, 2.0)) > 0.0);
    }
}
