//! Hirschberg–Sinclair leader election (paper §IV-A: "a new master RP
//! election is performed using the Hirschberg and Sinclair algorithm").
//!
//! The algorithm runs on a logical bidirectional ring. In phase `k`, every
//! still-active candidate sends probes `2^k` hops in both directions;
//! a probe is relayed while the probed node's id is smaller and bounced
//! back otherwise. A candidate that receives both of its probes back stays
//! active; a node whose probe reaches itself is the leader (the maximum
//! id). We execute the message rounds faithfully so the O(n log n)
//! message complexity is observable by tests and the bench harness.

use super::node_id::NodeId;

/// Outcome of an election round.
#[derive(Debug, Clone, PartialEq)]
pub struct ElectionResult {
    pub leader: NodeId,
    /// Total point-to-point messages exchanged (probes + replies).
    pub messages: usize,
    /// Number of phases executed.
    pub phases: usize,
}

/// Run Hirschberg–Sinclair on a ring of node ids, ordered as given
/// (position in the slice = position on the ring). Panics on empty input.
pub fn hirschberg_sinclair(ring: &[NodeId]) -> ElectionResult {
    assert!(!ring.is_empty(), "election requires at least one node");
    let n = ring.len();
    if n == 1 {
        return ElectionResult { leader: ring[0], messages: 0, phases: 0 };
    }

    let mut active: Vec<bool> = vec![true; n];
    let mut messages = 0usize;
    let mut phases = 0usize;

    loop {
        let dist = 1usize << phases;
        phases += 1;
        let mut any_survivor = false;
        let mut next_active = vec![false; n];

        for i in 0..n {
            if !active[i] {
                continue;
            }
            // Probe both directions up to `dist` hops; the probe survives
            // while every intermediate (and the endpoint) id is smaller.
            let mut survives = true;
            for dir in [1isize, -1isize] {
                let mut hop = 0usize;
                let mut pos = i as isize;
                let mut bounced = false;
                while hop < dist {
                    pos = (pos + dir).rem_euclid(n as isize);
                    hop += 1;
                    messages += 1; // probe forward one hop
                    if ring[pos as usize] > ring[i] {
                        bounced = true;
                        break;
                    }
                    if pos as usize == i {
                        // Probe circumnavigated: i is the unique maximum.
                        return ElectionResult { leader: ring[i], messages, phases };
                    }
                }
                // Reply travels back the hops the probe actually made.
                messages += hop;
                if bounced {
                    survives = false;
                }
            }
            if survives {
                next_active[i] = true;
                any_survivor = true;
            }
        }

        active = next_active;
        if !any_survivor {
            // Degenerate: all candidates eliminated in the same phase —
            // fall back to the maximum id directly (cannot happen with
            // distinct ids, which NodeId guarantees; defensive only).
            let leader = *ring.iter().max().unwrap();
            return ElectionResult { leader, messages, phases };
        }
        // Safety: dist beyond n/2 and a unique survivor means next phase
        // will circumnavigate; loop continues until the return above.
        if dist > 2 * n {
            let leader = *ring.iter().max().unwrap();
            return ElectionResult { leader, messages, phases };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<NodeId> {
        (0..n).map(|i| NodeId::from_name(&format!("e-{i}"))).collect()
    }

    #[test]
    fn single_node_is_leader() {
        let ring = ids(1);
        let r = hirschberg_sinclair(&ring);
        assert_eq!(r.leader, ring[0]);
        assert_eq!(r.messages, 0);
    }

    #[test]
    fn elects_the_maximum_id() {
        for n in [2, 3, 5, 8, 17, 64] {
            let ring = ids(n);
            let expected = *ring.iter().max().unwrap();
            let r = hirschberg_sinclair(&ring);
            assert_eq!(r.leader, expected, "n={n}");
        }
    }

    #[test]
    fn ring_order_does_not_change_winner() {
        let mut ring = ids(16);
        let expected = *ring.iter().max().unwrap();
        ring.rotate_left(5);
        assert_eq!(hirschberg_sinclair(&ring).leader, expected);
        ring.reverse();
        assert_eq!(hirschberg_sinclair(&ring).leader, expected);
    }

    #[test]
    fn message_complexity_is_n_log_n() {
        // HS guarantees O(n log n); verify we're within 8·n·(log2 n + 2).
        for n in [4usize, 16, 64, 128] {
            let ring = ids(n);
            let r = hirschberg_sinclair(&ring);
            let bound = 8 * n * ((n as f64).log2() as usize + 2);
            assert!(
                r.messages <= bound,
                "n={n}: {} messages exceeds bound {bound}",
                r.messages
            );
        }
    }

    #[test]
    fn phases_grow_logarithmically() {
        let ring = ids(64);
        let r = hirschberg_sinclair(&ring);
        assert!(r.phases <= 9, "phases={}", r.phases);
    }
}
