//! Layer 1 of the paper (§IV-A): the location-aware, self-organising,
//! fault-tolerant P2P overlay.
//!
//! The geographic space is indexed by a point [`quadtree`]; every leaf
//! region hosts an XOR-metric [`ring`] of Rendezvous Points with 160-bit
//! [`node_id`]s. Region masters maintain the quadtree, decide splits, and
//! are re-elected with the Hirschberg–Sinclair algorithm ([`election`])
//! when keep-alives ([`membership`]) detect a failure.

pub mod election;
pub mod geo;
pub mod membership;
pub mod node_id;
pub mod quadtree;
pub mod ring;

pub use geo::{GeoPoint, Rect};
pub use node_id::NodeId;
pub use quadtree::{QuadTree, RegionId};
pub use ring::RoutingTable;
