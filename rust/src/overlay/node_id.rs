//! 160-bit node identifiers with the XOR (Kademlia) metric.
//!
//! The paper (§IV-A) uses 160-bit unique identifiers ("more peers than you
//! can address with IPv6"); we derive them with SHA-1 exactly as
//! Kademlia-family systems do. Content routing places Hilbert-curve
//! indices into the *top* 64 bits of the same space so data keys and node
//! ids share one metric (§IV-B).

use crate::util::hex;
use sha1::{Digest, Sha1};

/// Number of bytes in an id (160 bits).
pub const ID_BYTES: usize = 20;
/// Number of bits in an id.
pub const ID_BITS: usize = ID_BYTES * 8;

/// A 160-bit overlay identifier.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub [u8; ID_BYTES]);

impl NodeId {
    /// All-zero id.
    pub const ZERO: NodeId = NodeId([0; ID_BYTES]);

    /// Derive an id by hashing a name (node names, function names).
    pub fn from_name(name: &str) -> Self {
        let mut h = Sha1::new();
        h.update(name.as_bytes());
        NodeId(h.finalize().into())
    }

    /// Derive an id from raw bytes (hashed).
    pub fn from_bytes_hashed(data: &[u8]) -> Self {
        let mut h = Sha1::new();
        h.update(data);
        NodeId(h.finalize().into())
    }

    /// Build an id whose *top 64 bits* are `index` and the rest zero —
    /// used to embed a Hilbert SFC index into the overlay id space so the
    /// natural XOR-closest node owns the curve segment around it.
    pub fn from_sfc_index(index: u64) -> Self {
        let mut bytes = [0u8; ID_BYTES];
        bytes[..8].copy_from_slice(&index.to_be_bytes());
        NodeId(bytes)
    }

    /// Top 64 bits interpreted as an SFC index.
    pub fn sfc_index(&self) -> u64 {
        u64::from_be_bytes(self.0[..8].try_into().unwrap())
    }

    /// XOR distance to another id.
    pub fn distance(&self, other: &NodeId) -> Distance {
        let mut d = [0u8; ID_BYTES];
        for i in 0..ID_BYTES {
            d[i] = self.0[i] ^ other.0[i];
        }
        Distance(d)
    }

    /// Index of the highest differing bit (0 = most significant) —
    /// the Kademlia bucket index. `None` when ids are equal.
    pub fn bucket_index(&self, other: &NodeId) -> Option<usize> {
        for i in 0..ID_BYTES {
            let x = self.0[i] ^ other.0[i];
            if x != 0 {
                return Some(i * 8 + x.leading_zeros() as usize);
            }
        }
        None
    }

    /// Bit at position `i` (0 = most significant).
    pub fn bit(&self, i: usize) -> bool {
        debug_assert!(i < ID_BITS);
        (self.0[i / 8] >> (7 - i % 8)) & 1 == 1
    }

    /// Hex rendering (full).
    pub fn to_hex(&self) -> String {
        hex::encode(&self.0)
    }

    /// Parse from full hex.
    pub fn from_hex(s: &str) -> Option<Self> {
        let bytes = hex::decode(s)?;
        let arr: [u8; ID_BYTES] = bytes.try_into().ok()?;
        Some(NodeId(arr))
    }
}

impl std::fmt::Debug for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NodeId({}…)", &self.to_hex()[..10])
    }
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", &self.to_hex()[..10])
    }
}

/// XOR distance between two ids; ordered big-endian.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Distance(pub [u8; ID_BYTES]);

impl Distance {
    pub const ZERO: Distance = Distance([0; ID_BYTES]);

    /// Number of leading zero bits (longer common prefix ⇒ closer).
    pub fn leading_zeros(&self) -> usize {
        for (i, &b) in self.0.iter().enumerate() {
            if b != 0 {
                return i * 8 + b.leading_zeros() as usize;
            }
        }
        ID_BITS
    }
}

impl std::fmt::Debug for Distance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Distance(lz={})", self.leading_zeros())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_name_is_deterministic_and_distinct() {
        assert_eq!(NodeId::from_name("rp-1"), NodeId::from_name("rp-1"));
        assert_ne!(NodeId::from_name("rp-1"), NodeId::from_name("rp-2"));
    }

    #[test]
    fn sha1_known_vector() {
        // sha1("abc") = a9993e36...
        let id = NodeId::from_name("abc");
        assert!(id.to_hex().starts_with("a9993e36"));
    }

    #[test]
    fn distance_is_symmetric_and_zero_on_self() {
        let a = NodeId::from_name("a");
        let b = NodeId::from_name("b");
        assert_eq!(a.distance(&b), b.distance(&a));
        assert_eq!(a.distance(&a), Distance::ZERO);
    }

    #[test]
    fn xor_metric_triangle_equality_property() {
        // d(a,c) = d(a,b) XOR d(b,c) — the defining Kademlia property.
        let a = NodeId::from_name("a");
        let b = NodeId::from_name("b");
        let c = NodeId::from_name("c");
        let ab = a.distance(&b);
        let bc = b.distance(&c);
        let ac = a.distance(&c);
        let mut x = [0u8; ID_BYTES];
        for i in 0..ID_BYTES {
            x[i] = ab.0[i] ^ bc.0[i];
        }
        assert_eq!(Distance(x), ac);
    }

    #[test]
    fn bucket_index_matches_leading_zeros() {
        let a = NodeId::from_name("node-a");
        let b = NodeId::from_name("node-b");
        let bucket = a.bucket_index(&b).unwrap();
        assert_eq!(bucket, a.distance(&b).leading_zeros());
        assert!(a.bucket_index(&a).is_none());
    }

    #[test]
    fn sfc_index_round_trip() {
        for idx in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
            assert_eq!(NodeId::from_sfc_index(idx).sfc_index(), idx);
        }
    }

    #[test]
    fn sfc_index_order_preserved_by_id_order() {
        // Embedding in the top bits preserves ordering of SFC indices.
        let a = NodeId::from_sfc_index(100);
        let b = NodeId::from_sfc_index(200);
        assert!(a < b);
    }

    #[test]
    fn bit_access() {
        let id = NodeId::from_sfc_index(1u64 << 63); // top bit set
        assert!(id.bit(0));
        assert!(!id.bit(1));
    }

    #[test]
    fn hex_round_trip() {
        let id = NodeId::from_name("round-trip");
        assert_eq!(NodeId::from_hex(&id.to_hex()).unwrap(), id);
        assert!(NodeId::from_hex("abcd").is_none()); // wrong length
    }
}
